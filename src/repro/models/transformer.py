"""Unified decoder-only transformer covering all five assigned LM archs.

One config expresses: llama-style GQA (smollm), qk-norm GQA (qwen3),
local/global alternating + softcaps + sandwich norms (gemma2), and
shared+routed MoE (qwen2-moe, qwen3-moe). Layers are scanned (compile time
independent of depth); activations/params carry logical sharding hints;
the MoE block optionally runs expert-parallel under shard_map.

Functional style: ``init_params`` builds a dict pytree; ``forward`` /
``prefill`` / ``decode_step`` are pure.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from .layers import rms_norm, apply_rope, gated_act, dense_init, embed_init
from ..distributed.sharding import shard_hint, get_mesh
from ..kernels.flash_attention import flash_attention, flash_decode


@dataclasses.dataclass(frozen=True)
class MoESettings:
    n_experts: int
    top_k: int
    d_expert: int
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    norm_topk: bool = True
    aux_coef: float = 1e-2
    pad_experts_to: int = 0   # >n_experts: pad weight arrays so EP divides
                              # the mesh (padded experts never receive tokens)

    @property
    def e_padded(self) -> int:
        return max(self.pad_experts_to, self.n_experts)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "swiglu"
    qk_norm: bool = False
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    window: int = 0                  # local-layer sliding window (gemma2: 4096)
    layer_pattern: str = "global"    # "global" | "local_global"
    post_norms: bool = False         # gemma2 sandwich norms
    embed_scale: bool = False        # gemma2 sqrt(d) embedding scale
    tie_embeddings: bool = True
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    moe: Optional[MoESettings] = None
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.bfloat16
    use_flash: bool = False          # Pallas kernels (TPU backend)
    remat: bool = True
    moe_shard_map: bool = False      # expert-parallel shard_map MoE
    moe_fsdp: bool = False           # expert weights additionally sharded over
                                     # 'data' (expert_ff dim), gathered per layer
    moe_psum_bf16: bool = False      # cast the EP combine psum to bf16 (halves
                                     # the per-layer [T,d] f32 wire bytes)

    @property
    def layers_per_step(self) -> int:
        return 2 if self.layer_pattern == "local_global" else 1

    @property
    def n_steps(self) -> int:
        assert self.n_layers % self.layers_per_step == 0
        return self.n_layers // self.layers_per_step

    def window_of(self, pos_in_step: int) -> int:
        if self.layer_pattern == "local_global":
            return self.window if pos_in_step == 0 else 0
        return self.window

    def param_count(self) -> int:
        c = self
        attn = c.d_model * c.head_dim * (c.n_heads * 2 + c.n_kv_heads * 2)
        if c.moe:
            ffn = c.moe.n_experts * 3 * c.d_model * c.moe.d_expert
            ffn += c.d_model * c.moe.n_experts
            if c.moe.shared_d_ff:
                ffn += 3 * c.d_model * c.moe.shared_d_ff + c.d_model
        else:
            ffn = 3 * c.d_model * c.d_ff
        per_layer = attn + ffn + 2 * c.d_model * (2 if c.post_norms else 1)
        head = 0 if c.tie_embeddings else c.d_model * c.vocab
        return c.n_layers * per_layer + c.vocab * c.d_model + head + c.d_model

    def active_param_count(self) -> int:
        """MoE: params touched per token (6·N_active·D convention)."""
        if not self.moe:
            return self.param_count()
        c = self
        attn = c.d_model * c.head_dim * (c.n_heads * 2 + c.n_kv_heads * 2)
        ffn = c.moe.top_k * 3 * c.d_model * c.moe.d_expert
        ffn += c.d_model * c.moe.n_experts
        if c.moe.shared_d_ff:
            ffn += 3 * c.d_model * c.moe.shared_d_ff
        head = 0 if c.tie_embeddings else c.d_model * c.vocab
        return c.n_layers * (attn + ffn) + c.vocab * c.d_model + head


# ---------------------------------------------------------------------------
# parameter construction
# ---------------------------------------------------------------------------
class TransformerLM:
    def __init__(self, cfg: TransformerConfig):
        self.cfg = cfg

    # -- init ----------------------------------------------------------------
    def init_params(self, key) -> dict:
        c = self.cfg
        pd = c.param_dtype
        keys = jax.random.split(key, 16)
        H, G, hd, d = c.n_heads, c.n_kv_heads, c.head_dim, c.d_model

        def layer_params(k):
            ks = jax.random.split(k, 12)
            p = {
                "wq": dense_init(ks[0], (d, H * hd), dtype=pd),
                "wk": dense_init(ks[1], (d, G * hd), dtype=pd),
                "wv": dense_init(ks[2], (d, G * hd), dtype=pd),
                "wo": dense_init(ks[3], (H * hd, d), dtype=pd),
                "pre_attn": jnp.zeros((d,), pd),
                "pre_mlp": jnp.zeros((d,), pd),
            }
            if c.post_norms:
                p["post_attn"] = jnp.zeros((d,), pd)
                p["post_mlp"] = jnp.zeros((d,), pd)
            if c.qk_norm:
                p["q_norm"] = jnp.zeros((hd,), pd)
                p["k_norm"] = jnp.zeros((hd,), pd)
            if c.moe:
                m = c.moe
                p["router"] = dense_init(ks[4], (d, m.n_experts), dtype=jnp.float32)
                p["we_gate"] = dense_init(ks[5], (m.e_padded, d, m.d_expert), in_axis=1, dtype=pd)
                p["we_up"] = dense_init(ks[6], (m.e_padded, d, m.d_expert), in_axis=1, dtype=pd)
                p["we_down"] = dense_init(ks[7], (m.e_padded, m.d_expert, d), in_axis=1, dtype=pd)
                if m.shared_d_ff:
                    p["ws_gate"] = dense_init(ks[8], (d, m.shared_d_ff), dtype=pd)
                    p["ws_up"] = dense_init(ks[9], (d, m.shared_d_ff), dtype=pd)
                    p["ws_down"] = dense_init(ks[10], (m.shared_d_ff, d), dtype=pd)
                    p["ws_gate_proj"] = dense_init(ks[11], (d, 1), dtype=pd)
            else:
                p["w_gate"] = dense_init(ks[4], (d, c.d_ff), dtype=pd)
                p["w_up"] = dense_init(ks[5], (d, c.d_ff), dtype=pd)
                p["w_down"] = dense_init(ks[6], (c.d_ff, d), dtype=pd)
            return p

        lkeys = jax.random.split(keys[0], c.n_steps * c.layers_per_step)
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs).reshape((c.n_steps, c.layers_per_step) + xs[0].shape),
            *[layer_params(k) for k in lkeys],
        )
        params = {
            "embed": embed_init(keys[1], (c.vocab, d), dtype=pd),
            "layers": stacked,
            "final_norm": jnp.zeros((d,), pd),
        }
        if not c.tie_embeddings:
            params["lm_head"] = dense_init(keys[2], (d, c.vocab), dtype=pd)
        return params

    def param_axes(self, params) -> dict:
        """Pytree of logical-axis tuples mirroring ``params`` (for pjit)."""
        c = self.cfg

        def axes_of(path, leaf):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            base = {
                "embed": ("vocab", "d_model"),
                "lm_head": ("d_model", "vocab"),
                "final_norm": ("d_model",),
                "wq": ("d_model", "heads"),
                "wk": ("d_model", "kv_heads"),
                "wv": ("d_model", "kv_heads"),
                "wo": ("heads", "d_model"),
                "w_gate": ("d_model", "d_ff"),
                "w_up": ("d_model", "d_ff"),
                "w_down": ("d_ff", "d_model"),
                "router": ("d_model", None),
                "we_gate": ("experts", "d_model", "expert_ff"),
                "we_up": ("experts", "d_model", "expert_ff"),
                "we_down": ("experts", "expert_ff", "d_model"),
                "ws_gate": ("d_model", "d_ff"),
                "ws_up": ("d_model", "d_ff"),
                "ws_down": ("d_ff", "d_model"),
                "ws_gate_proj": ("d_model", None),
                "pre_attn": (None,), "pre_mlp": (None,),
                "post_attn": (None,), "post_mlp": (None,),
                "q_norm": (None,), "k_norm": (None,),
            }[name]
            # layer-stacked params get two leading replicated dims
            if any(getattr(pp, "key", None) == "layers" for pp in path):
                return (None, None) + base
            return base

        return jax.tree_util.tree_map_with_path(axes_of, params)

    # -- blocks ----------------------------------------------------------------
    def _attention(self, lp, x, positions, window: int, *, cache=None,
                   cache_pos=None, kv_len=None):
        c = self.cfg
        H, G, hd = c.n_heads, c.n_kv_heads, c.head_dim
        B, S, d = x.shape
        h = rms_norm(x, lp["pre_attn"], c.norm_eps)
        q = (h @ lp["wq"]).reshape(B, S, H, hd)
        k = (h @ lp["wk"]).reshape(B, S, G, hd)
        v = (h @ lp["wv"]).reshape(B, S, G, hd)
        if c.qk_norm:
            q = rms_norm(q, lp["q_norm"], c.norm_eps)
            k = rms_norm(k, lp["k_norm"], c.norm_eps)
        q = apply_rope(q.swapaxes(1, 2), positions[:, None, :], c.rope_theta)
        k = apply_rope(k.swapaxes(1, 2), positions[:, None, :], c.rope_theta)
        v = v.swapaxes(1, 2)
        q = shard_hint(q, "batch", "heads", "seq", None)
        k = shard_hint(k, "batch", "kv_heads", "seq", None)
        sm_scale = hd ** -0.5
        if cache is None:
            out = flash_attention(q, k, v, causal=True, window=window,
                                  softcap=c.attn_softcap, sm_scale=sm_scale,
                                  use_kernel=c.use_flash)
            new_cache = (k, v)
        else:
            ck, cv = cache  # [B, G, Sc, hd]
            bidx = jnp.arange(B)
            ck = ck.at[bidx, :, cache_pos, :].set(k[:, :, 0, :])
            cv = cv.at[bidx, :, cache_pos, :].set(v[:, :, 0, :])
            out = flash_decode(q[:, :, 0, :], ck, cv, kv_len, window=0,
                               softcap=c.attn_softcap, sm_scale=sm_scale,
                               use_kernel=c.use_flash)[:, :, None, :]
            new_cache = (ck, cv)
        out = out.swapaxes(1, 2).reshape(B, S, H * hd)
        out = out @ lp["wo"]
        if c.post_norms:
            out = rms_norm(out, lp["post_attn"], c.norm_eps)
        return out, new_cache

    def _dense_mlp(self, lp, x):
        c = self.cfg
        h = rms_norm(x, lp["pre_mlp"], c.norm_eps)
        h = shard_hint(h, "batch", "seq", "d_model")
        out = gated_act(h @ lp["w_gate"], h @ lp["w_up"], c.act) @ lp["w_down"]
        if c.post_norms:
            out = rms_norm(out, lp["post_mlp"], c.norm_eps)
        return out, jnp.float32(0.0)

    # -- MoE -------------------------------------------------------------------
    def _route(self, lp, h2d):
        """Router: returns (idx int32[T,k], gates f32[T,k], aux_loss)."""
        m = self.cfg.moe
        logits = h2d.astype(jnp.float32) @ lp["router"]
        probs = jax.nn.softmax(logits, axis=-1)                 # [T, E]
        gates, idx = lax.top_k(probs, m.top_k)                  # [T, k]
        if m.norm_topk:
            gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        # Switch-style load-balance loss
        T = h2d.shape[0]
        f = jnp.zeros((m.n_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
        f = f / (T * m.top_k)
        p_mean = probs.mean(axis=0)
        aux = m.n_experts * jnp.sum(f * p_mean)
        return idx, gates, aux

    @staticmethod
    def _experts_apply(x2d, idx, gates, we_gate, we_up, we_down, base_expert,
                       capacity: int, act: str):
        """Scan over (local) experts: capacity-gather -> FFN -> scatter-add.

        x2d [T, d]; idx/gates [T, k]; we_* [E_loc, ...]; returns [T, d].
        """
        T, d = x2d.shape
        E_loc = we_gate.shape[0]
        x_pad = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], axis=0)
        out0 = jnp.zeros_like(x_pad)

        def body(carry, ew):
            w1, w3, w2, e_id = ew
            match = idx == e_id                                  # [T, k]
            gate = (gates * match).sum(-1).astype(x2d.dtype)     # [T]
            tok = match.any(-1)
            pos = jnp.cumsum(tok.astype(jnp.int32)) - 1
            keep = tok & (pos < capacity)
            slot = jnp.where(keep, pos, capacity)
            slot_ids = jnp.full((capacity + 1,), T, jnp.int32)
            slot_ids = slot_ids.at[slot].set(jnp.arange(T, dtype=jnp.int32))
            slot_ids = slot_ids[:capacity]
            xe = x_pad[slot_ids]                                 # [C, d]
            he = gated_act(xe @ w1, xe @ w3, act) @ w2           # [C, d]
            gpad = jnp.concatenate([gate, jnp.zeros((1,), gate.dtype)])
            carry = carry.at[slot_ids].add(he * gpad[slot_ids][:, None])
            return carry, None

        e_ids = base_expert + jnp.arange(E_loc, dtype=jnp.int32)
        out, _ = lax.scan(body, out0, (we_gate, we_up, we_down, e_ids))
        return out[:T]

    def _moe_mlp(self, lp, x):
        c = self.cfg
        m = c.moe
        B, S, d = x.shape
        h = rms_norm(x, lp["pre_mlp"], c.norm_eps)
        h2d = h.reshape(B * S, d)
        idx, gates, aux = self._route(lp, h2d)

        mesh = get_mesh()
        use_sm = (c.moe_shard_map and mesh is not None
                  and "model" in mesh.axis_names
                  and mesh.shape["model"] > 1
                  and m.e_padded % mesh.shape["model"] == 0)
        if use_sm:
            ep = mesh.shape["model"]
            dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
            dp = 1
            for a in dp_axes:
                dp *= mesh.shape[a]
            T_loc = (B // dp) * S
            cap = max(8, int(T_loc * m.top_k / m.n_experts * m.capacity_factor))

            fsdp = (c.moe_fsdp and "data" in mesh.axis_names
                    and mesh.shape["data"] > 1
                    and m.d_expert % mesh.shape["data"] == 0)

            def local_moe(h2d_, idx_, gates_, w1, w3, w2):
                if fsdp:   # FSDP: gather the ff shards just-in-time
                    w1 = lax.all_gather(w1, "data", axis=2, tiled=True)
                    w3 = lax.all_gather(w3, "data", axis=2, tiled=True)
                    w2 = lax.all_gather(w2, "data", axis=1, tiled=True)
                base = lax.axis_index("model") * (m.e_padded // ep)
                part = self._experts_apply(
                    h2d_.reshape(-1, d), idx_.reshape(-1, m.top_k),
                    gates_.reshape(-1, m.top_k), w1, w3, w2,
                    base, cap, c.act)
                if c.moe_psum_bf16:
                    part = part.astype(jnp.bfloat16)
                return lax.psum(part, "model").astype(h2d_.dtype).reshape(h2d_.shape)

            bspec = P(dp_axes if dp_axes else None)
            if fsdp:
                wspecs = (P("model", None, "data"), P("model", None, "data"),
                          P("model", "data", None))
            else:
                wspecs = (P("model"), P("model"), P("model"))
            out2d = shard_map(
                local_moe, mesh=mesh,
                in_specs=(bspec, bspec, bspec) + wspecs,
                out_specs=bspec,
                check_vma=False,
            )(h2d.reshape(B * S, d), idx, gates,
              lp["we_gate"], lp["we_up"], lp["we_down"])
        else:
            cap = max(8, int(B * S * m.top_k / m.n_experts * m.capacity_factor))
            out2d = self._experts_apply(h2d, idx, gates, lp["we_gate"],
                                        lp["we_up"], lp["we_down"],
                                        jnp.int32(0), cap, c.act)
        out = out2d.reshape(B, S, d)
        if m.shared_d_ff:
            g = jax.nn.sigmoid(h @ lp["ws_gate_proj"])
            shared = gated_act(h @ lp["ws_gate"], h @ lp["ws_up"], c.act) @ lp["ws_down"]
            out = out + g * shared
        if c.post_norms:
            out = rms_norm(out, lp["post_mlp"], c.norm_eps)
        return out, aux

    def _mlp(self, lp, x):
        return self._moe_mlp(lp, x) if self.cfg.moe else self._dense_mlp(lp, x)

    # -- full forward (training / prefill) --------------------------------------
    def forward(self, params, tokens, *, return_cache: bool = False):
        """tokens int32[B, S] -> (logits f32[B, S, V], aux_loss, cache|None)."""
        c = self.cfg
        B, S = tokens.shape
        x = params["embed"][tokens].astype(c.dtype)
        if c.embed_scale:
            x = x * jnp.sqrt(jnp.float32(c.d_model)).astype(c.dtype)
        x = shard_hint(x, "batch", "seq", "d_model")
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

        def step(x, lps):
            aux_t = jnp.float32(0.0)
            caches = []
            for i in range(c.layers_per_step):
                lp = jax.tree_util.tree_map(lambda a: a[i], lps)
                attn, kv = self._attention(lp, x, positions, c.window_of(i))
                x2 = x + attn
                mlp, aux = self._mlp(lp, x2)
                x = x2 + mlp
                x = shard_hint(x, "batch", "seq", "d_model")
                aux_t += aux
                caches.append(kv)
            return x, (aux_t, caches if return_cache else None)

        body = jax.checkpoint(step) if c.remat else step
        x, (auxes, caches) = lax.scan(body, x, params["layers"])
        x = rms_norm(x, params["final_norm"], c.norm_eps)
        head = params["embed"].T if c.tie_embeddings else params["lm_head"]
        logits = (x @ head.astype(c.dtype)).astype(jnp.float32)
        if c.final_softcap:
            logits = c.final_softcap * jnp.tanh(logits / c.final_softcap)
        logits = shard_hint(logits, "batch", "seq", "vocab")
        return logits, auxes.sum(), caches

    def loss_fn(self, params, tokens, targets, mask):
        logits, aux, _ = self.forward(params, tokens)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
        if self.cfg.moe:
            loss = loss + self.cfg.moe.aux_coef * aux / self.cfg.n_layers
        return loss

    # -- KV-cache serving --------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> dict:
        c = self.cfg
        G, hd = c.n_kv_heads, c.head_dim
        caches = {"pos": jnp.zeros((batch,), jnp.int32)}
        ks, vs = [], []
        for i in range(c.layers_per_step):
            w = c.window_of(i)
            Sc = min(w, max_len) if w > 0 else max_len
            shape = (c.n_steps, batch, G, Sc, hd)
            ks.append(jnp.zeros(shape, c.dtype))
            vs.append(jnp.zeros(shape, c.dtype))
        caches["k"] = tuple(ks)
        caches["v"] = tuple(vs)
        return caches

    def decode_step(self, params, cache, tokens):
        """One token per sequence. tokens int32[B] -> (logits [B, V], cache)."""
        c = self.cfg
        B = tokens.shape[0]
        pos = cache["pos"]                               # [B]
        x = params["embed"][tokens][:, None, :].astype(c.dtype)
        if c.embed_scale:
            x = x * jnp.sqrt(jnp.float32(c.d_model)).astype(c.dtype)
        x = shard_hint(x, "batch", None, "d_model")
        positions = pos[:, None]

        def step(carry, scanned):
            x = carry
            lps, layer_ks, layer_vs = scanned
            new_ks, new_vs = [], []
            for i in range(c.layers_per_step):
                lp = jax.tree_util.tree_map(lambda a: a[i], lps)
                ck, cv = layer_ks[i], layer_vs[i]
                Sc = ck.shape[2]
                w = c.window_of(i)
                cpos = pos % Sc                          # ring for local layers
                klen = jnp.minimum(pos + 1, Sc)
                attn, (ck, cv) = self._attention(
                    lp, x, positions, 0, cache=(ck, cv), cache_pos=cpos,
                    kv_len=klen)
                x2 = x + attn
                mlp, _ = self._mlp(lp, x2)
                x = x2 + mlp
                new_ks.append(ck)
                new_vs.append(cv)
            return x, (tuple(new_ks), tuple(new_vs))

        x, (nk, nv) = lax.scan(step, x, (params["layers"], cache["k"], cache["v"]))
        x = rms_norm(x, params["final_norm"], c.norm_eps)
        head = params["embed"].T if c.tie_embeddings else params["lm_head"]
        logits = (x[:, 0, :] @ head.astype(c.dtype)).astype(jnp.float32)
        if c.final_softcap:
            logits = c.final_softcap * jnp.tanh(logits / c.final_softcap)
        new_cache = {"pos": pos + 1, "k": nk, "v": nv}
        return logits, new_cache
