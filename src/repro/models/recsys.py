"""RecSys architectures: FM, DIN, BST, MIND (assigned configs).

Shared substrate: sparse embedding tables + EmbeddingBag built from
``jnp.take`` + masked reduction / ``jax.ops.segment_sum`` (JAX has no native
EmbeddingBag — DESIGN.md §5). Tables are row-sharded over the ``model`` mesh
axis ("table_rows"); lookups become XLA gathers with collective plumbing
inserted by GSPMD.

Shapes contract (see configs/): every model exposes
  train_step inputs:  features dict -> logits [B]   (BCE)
  serve inputs:       same, batch sized per serve shape
  retrieval (MIND):   user batch x [n_cand] item embeddings -> top-k
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .layers import dense_init, embed_init
from ..distributed.sharding import shard_hint
from ..kernels.fm_pairwise import fm_pairwise


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str                      # fm | din | bst | mind
    embed_dim: int
    n_sparse: int = 39             # categorical fields (fm)
    field_vocab: int = 100_000     # rows per field table (fm)
    item_vocab: int = 1_000_000    # item table rows (din/bst/mind)
    cate_vocab: int = 10_000       # category table rows (din)
    seq_len: int = 100             # behavior history length
    n_heads: int = 8               # bst
    n_blocks: int = 1              # bst
    mlp: tuple = (200, 80)
    attn_mlp: tuple = (80, 40)     # din
    n_interests: int = 4           # mind
    capsule_iters: int = 3         # mind
    dtype: object = jnp.float32
    use_kernel: bool = False       # Pallas fm_pairwise


def embedding_bag(table, ids, mask=None, mode: str = "sum"):
    """EmbeddingBag from take + masked reduce. ids [..., L] -> [..., D]."""
    emb = jnp.take(table, ids, axis=0)                      # [..., L, D]
    if mask is not None:
        emb = emb * mask[..., None]
    out = emb.sum(axis=-2)
    if mode == "mean":
        denom = (mask.sum(-1, keepdims=True) if mask is not None
                 else jnp.float32(ids.shape[-1]))
        out = out / jnp.maximum(denom, 1.0)
    return out


def embedding_bag_csr(table, flat_ids, segment_ids, n_segments: int):
    """Ragged CSR variant via segment_sum (tested against the padded path)."""
    emb = jnp.take(table, flat_ids, axis=0)
    return jax.ops.segment_sum(emb, segment_ids, num_segments=n_segments)


def _mlp_params(key, sizes, d_in):
    ks = jax.random.split(key, len(sizes) + 1)
    dims = [d_in] + list(sizes) + [1]
    return [
        {"w": dense_init(ks[i], (dims[i], dims[i + 1])),
         "b": jnp.zeros((dims[i + 1],))}
        for i in range(len(dims) - 1)
    ]


def _mlp_apply(layers, x, final_act=None):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1:
            x = jax.nn.relu(x)
    return x if final_act is None else final_act(x)


# ---------------------------------------------------------------------------
class FMModel:
    """Factorization Machine (Rendle ICDM'10), O(nk) sum-square interaction."""

    def __init__(self, cfg: RecsysConfig):
        self.cfg = cfg

    def init_params(self, key):
        c = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "tables": embed_init(k1, (c.n_sparse, c.field_vocab, c.embed_dim)),
            "linear": embed_init(k2, (c.n_sparse, c.field_vocab, 1)),
            "bias": jnp.zeros(()),
        }

    def param_axes(self, params):
        return {"tables": (None, "table_rows", None),
                "linear": (None, "table_rows", None), "bias": ()}

    def forward(self, params, feats):
        """feats["sparse_ids"] int32[B, F] -> logits [B]."""
        ids = feats["sparse_ids"]
        B, F = ids.shape
        f_idx = jnp.arange(F)
        emb = params["tables"][f_idx[None, :], ids]        # [B, F, D]
        emb = shard_hint(emb, "batch", None, None)
        lin = params["linear"][f_idx[None, :], ids][..., 0].sum(-1)
        pair = fm_pairwise(emb, use_kernel=self.cfg.use_kernel)
        return params["bias"] + lin + pair


# ---------------------------------------------------------------------------
class DINModel:
    """Deep Interest Network (arXiv:1706.06978): target attention over history."""

    def __init__(self, cfg: RecsysConfig):
        self.cfg = cfg

    def init_params(self, key):
        c = self.cfg
        ks = jax.random.split(key, 6)
        d = c.embed_dim
        att_in = 4 * (2 * d)  # [h, t, h-t, h*t] on concat(item,cate) embeddings
        return {
            "item_table": embed_init(ks[0], (c.item_vocab, d)),
            "cate_table": embed_init(ks[1], (c.cate_vocab, d)),
            "att_mlp": _mlp_params(ks[2], c.attn_mlp, att_in),
            "mlp": _mlp_params(ks[3], c.mlp, 3 * (2 * d)),
        }

    def param_axes(self, params):
        ax = jax.tree_util.tree_map(lambda _: (None,), params)
        ax["item_table"] = ("table_rows", None)
        ax["cate_table"] = ("table_rows", None)
        return ax

    def forward(self, params, feats):
        """hist_items/hist_cates int32[B, L], hist_mask f32[B, L],
        target_item/target_cate int32[B] -> logits [B]."""
        c = self.cfg
        hi = jnp.take(params["item_table"], feats["hist_items"], axis=0)
        hc = jnp.take(params["cate_table"], feats["hist_cates"], axis=0)
        h = jnp.concatenate([hi, hc], axis=-1)                # [B, L, 2D]
        ti = jnp.take(params["item_table"], feats["target_item"], axis=0)
        tc = jnp.take(params["cate_table"], feats["target_cate"], axis=0)
        t = jnp.concatenate([ti, tc], axis=-1)[:, None, :]    # [B, 1, 2D]
        tt = jnp.broadcast_to(t, h.shape)
        att_in = jnp.concatenate([h, tt, h - tt, h * tt], axis=-1)
        score = _mlp_apply(params["att_mlp"], att_in)[..., 0]  # [B, L]
        score = jnp.where(feats["hist_mask"] > 0, score, -1e30)
        w = jax.nn.softmax(score, axis=-1) * (feats["hist_mask"].sum(-1, keepdims=True) > 0)
        pooled = (w[..., None] * h).sum(axis=1)                # [B, 2D]
        x = jnp.concatenate([pooled, t[:, 0], pooled * t[:, 0]], axis=-1)
        return _mlp_apply(params["mlp"], x)[..., 0]


# ---------------------------------------------------------------------------
class BSTModel:
    """Behavior Sequence Transformer (arXiv:1905.06874)."""

    def __init__(self, cfg: RecsysConfig):
        self.cfg = cfg

    def init_params(self, key):
        c = self.cfg
        d = c.embed_dim
        ks = jax.random.split(key, 8 + 4 * c.n_blocks)
        p = {
            "item_table": embed_init(ks[0], (c.item_vocab, d)),
            "pos_table": embed_init(ks[1], (c.seq_len + 1, d)),
            "blocks": [],
            "mlp": _mlp_params(ks[2], c.mlp, (c.seq_len + 1) * d),
        }
        for b in range(c.n_blocks):
            kb = jax.random.split(ks[4 + b], 6)
            p["blocks"].append({
                "wq": dense_init(kb[0], (d, d)), "wk": dense_init(kb[1], (d, d)),
                "wv": dense_init(kb[2], (d, d)), "wo": dense_init(kb[3], (d, d)),
                "ff1": dense_init(kb[4], (d, 4 * d)), "ff2": dense_init(kb[5], (4 * d, d)),
                "ln1": jnp.zeros((d,)), "ln2": jnp.zeros((d,)),
            })
        return p

    def param_axes(self, params):
        ax = jax.tree_util.tree_map(lambda _: (None,), params)
        ax["item_table"] = ("table_rows", None)
        return ax

    def _block(self, bp, x, mask):
        c = self.cfg
        d = c.embed_dim
        hd = d // c.n_heads
        B, L, _ = x.shape

        def split(z):
            return z.reshape(B, L, c.n_heads, hd).swapaxes(1, 2)

        from .layers import rms_norm
        h = rms_norm(x, bp["ln1"])
        q, k, v = split(h @ bp["wq"]), split(h @ bp["wk"]), split(h @ bp["wv"])
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(hd))
        s = jnp.where(mask[:, None, None, :] > 0, s, -1e30)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", a, v).swapaxes(1, 2).reshape(B, L, d)
        x = x + o @ bp["wo"]
        h = rms_norm(x, bp["ln2"])
        return x + jax.nn.leaky_relu(h @ bp["ff1"]) @ bp["ff2"]

    def forward(self, params, feats):
        """hist_items int32[B, L], hist_mask [B, L], target_item int32[B]."""
        c = self.cfg
        hist = jnp.take(params["item_table"], feats["hist_items"], axis=0)
        tgt = jnp.take(params["item_table"], feats["target_item"], axis=0)
        x = jnp.concatenate([hist, tgt[:, None, :]], axis=1)   # [B, L+1, D]
        x = x + params["pos_table"][None]
        mask = jnp.concatenate(
            [feats["hist_mask"], jnp.ones((x.shape[0], 1), x.dtype)], axis=1)
        x = x * mask[..., None]
        for bp in params["blocks"]:
            x = self._block(bp, x, mask)
        B = x.shape[0]
        return _mlp_apply(params["mlp"], x.reshape(B, -1))[..., 0]


# ---------------------------------------------------------------------------
class MINDModel:
    """Multi-Interest Network with Dynamic routing (arXiv:1904.08030)."""

    def __init__(self, cfg: RecsysConfig):
        self.cfg = cfg

    def init_params(self, key):
        c = self.cfg
        ks = jax.random.split(key, 4)
        d = c.embed_dim
        return {
            "item_table": embed_init(ks[0], (c.item_vocab, d)),
            "s_matrix": dense_init(ks[1], (d, d)),  # shared bilinear (B2I)
        }

    def param_axes(self, params):
        return {"item_table": ("table_rows", None), "s_matrix": (None, None)}

    def interests(self, params, hist_ids, hist_mask, key=None):
        """Capsule B2I dynamic routing -> [B, K, D] interest capsules."""
        c = self.cfg
        e = jnp.take(params["item_table"], hist_ids, axis=0)   # [B, L, D]
        eh = (e @ params["s_matrix"]) * hist_mask[..., None]   # behavior caps
        B, L, D = eh.shape
        K = c.n_interests
        # fixed (non-learned) routing-logit init, shared across batch
        b_init = jax.random.normal(jax.random.PRNGKey(0), (K, L)) * 1.0
        blog = jnp.broadcast_to(b_init[None], (B, K, L))

        def squash(v):
            n2 = jnp.sum(v * v, axis=-1, keepdims=True)
            return (n2 / (1 + n2)) * v / jnp.sqrt(n2 + 1e-9)

        caps = None
        for _ in range(c.capsule_iters):
            w = jax.nn.softmax(blog, axis=1)                   # over K
            w = w * hist_mask[:, None, :]
            caps = squash(jnp.einsum("bkl,bld->bkd", w, eh))
            blog = blog + jnp.einsum("bkd,bld->bkl", caps, eh)
        return caps

    def forward(self, params, feats):
        """Training score: label-aware attention (pow 2) to the target item."""
        caps = self.interests(params, feats["hist_items"], feats["hist_mask"])
        tgt = jnp.take(params["item_table"], feats["target_item"], axis=0)
        s = jnp.einsum("bkd,bd->bk", caps, tgt)
        w = jax.nn.softmax(s * s, axis=-1)                      # label-aware pow-2
        u = jnp.einsum("bk,bkd->bd", w, caps)
        return jnp.einsum("bd,bd->b", u, tgt)

    def retrieve(self, params, feats, cand_emb, k: int = 100):
        """Score 1 user against n_cand items: batched dot + max over interests."""
        caps = self.interests(params, feats["hist_items"], feats["hist_mask"])
        s = jnp.einsum("bkd,nd->bkn", caps, cand_emb)          # [B, K, N]
        s = shard_hint(s, "batch", None, "candidates")
        score = s.max(axis=1)                                   # [B, N]
        return jax.lax.top_k(score, k)


def bce_loss(logits, labels):
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
