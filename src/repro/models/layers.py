"""Shared neural layers: norms, RoPE, activations, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) * 2 / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, D] or [..., D] with positions [..., S] / [...]."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                       # [D/2]
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def gated_act(gate, up, kind: str):
    if kind == "swiglu":
        return jax.nn.silu(gate) * up
    if kind == "geglu":
        return jax.nn.gelu(gate, approximate=True) * up
    raise ValueError(kind)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)
