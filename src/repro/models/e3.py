"""E(3) machinery for MACE: real spherical harmonics (l<=2) and real Gaunt
coefficients computed by spherical quadrature (no e3nn dependency).

The coupling tensor G[i, j, k] = ∫ Y_i Y_j Y_k dΩ over the 9 real SH basis
functions (l=0,1,2 flattened as [00, 1-1, 10, 11, 2-2, 2-1, 20, 21, 22]) is
exact here: Gauss-Legendre x uniform-phi quadrature integrates the degree<=6
polynomial integrands exactly. Contracting two equivariant feature vectors
with G yields an equivariant product — the same function space as the
Clebsch-Gordan tensor product used by MACE (arXiv:2206.07697), in the real
basis.
"""
from __future__ import annotations

import functools

import numpy as np
import jax.numpy as jnp

# real SH normalization constants
_C00 = 0.28209479177387814
_C1 = 0.4886025119029199
_C2A = 1.0925484305920792
_C20 = 0.31539156525252005
_C22 = 0.5462742152960396

N_LM = 9                       # (l_max+1)^2 for l_max = 2
L_OF = np.array([0, 1, 1, 1, 2, 2, 2, 2, 2])  # l of each flattened component
L_SLICES = {0: slice(0, 1), 1: slice(1, 4), 2: slice(4, 9)}


def real_sph_harm(rhat):
    """rhat [..., 3] unit vectors -> Y [..., 9] (jnp or np)."""
    xp = jnp if not isinstance(rhat, np.ndarray) else np
    x, y, z = rhat[..., 0], rhat[..., 1], rhat[..., 2]
    one = xp.ones_like(x)
    return xp.stack(
        [
            _C00 * one,
            _C1 * y, _C1 * z, _C1 * x,
            _C2A * x * y, _C2A * y * z, _C20 * (3 * z * z - 1),
            _C2A * x * z, _C22 * (x * x - y * y),
        ],
        axis=-1,
    )


@functools.lru_cache(maxsize=1)
def gaunt_tensor() -> np.ndarray:
    """G[i, j, k] = ∫ Y_i Y_j Y_k dΩ, shape [9, 9, 9] (numpy, float64)."""
    nt, nphi = 24, 48
    ct, wt = np.polynomial.legendre.leggauss(nt)       # cos(theta) nodes
    phi = (np.arange(nphi) + 0.5) * (2 * np.pi / nphi)
    wphi = 2 * np.pi / nphi
    st = np.sqrt(1 - ct**2)
    # grid of unit vectors [nt*nphi, 3]
    x = st[:, None] * np.cos(phi)[None, :]
    y = st[:, None] * np.sin(phi)[None, :]
    z = np.broadcast_to(ct[:, None], x.shape)
    pts = np.stack([x, y, z], axis=-1).reshape(-1, 3)
    w = (wt[:, None] * wphi * np.ones_like(phi)[None, :]).reshape(-1)
    Y = real_sph_harm(pts)                              # [P, 9]
    return np.einsum("p,pi,pj,pk->ijk", w, Y, Y, Y)


def tensor_product(a, b, gaunt):
    """Equivariant product: a, b [..., C, 9] x G [9,9,9] -> [..., C, 9]."""
    return jnp.einsum("...ci,...cj,ijk->...ck", a, b, gaunt)


def rotation_wigner_l1(R):
    """Real-SH l=1 components transform as (y, z, x): D1 = P R P^T."""
    P = np.array([[0, 1, 0], [0, 0, 1], [1, 0, 0]], dtype=np.float64)
    return P @ R @ P.T


def bessel_rbf(r, n_rbf: int, r_cut: float):
    """Bessel radial basis (MACE/NequIP): sqrt(2/rc)·sin(nπr/rc)/r, n=1..n_rbf."""
    eps = 1e-9
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    rr = jnp.maximum(r[..., None], eps)
    return jnp.sqrt(2.0 / r_cut) * jnp.sin(n * jnp.pi * rr / r_cut) / rr


def poly_cutoff(r, r_cut: float, p: int = 6):
    """Polynomial cutoff envelope (DimeNet eq. 8); smooth -> 0 at r_cut."""
    u = jnp.clip(r / r_cut, 0.0, 1.0)
    return (1.0
            - (p + 1) * (p + 2) / 2 * u**p
            + p * (p + 2) * u ** (p + 1)
            - p * (p + 1) / 2 * u ** (p + 2))
