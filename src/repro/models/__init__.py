from .transformer import TransformerConfig, MoESettings, TransformerLM  # noqa: F401
from .mace import MACEConfig, MACEModel, GraphBatch  # noqa: F401
from .recsys import (  # noqa: F401
    RecsysConfig, FMModel, DINModel, BSTModel, MINDModel,
    embedding_bag, embedding_bag_csr, bce_loss,
)
