"""MACE: higher-order equivariant message passing (arXiv:2206.07697).

Faithful-at-l_max=2 implementation in pure JAX (DESIGN.md §5):
  * edge embedding: Bessel RBF x polynomial cutoff x real spherical harmonics;
  * density (A-features): A_i = Σ_{j∈N(i)} R_cl(r_ij) · TP(h_j, Y(r̂_ij)),
    realized with the real Gaunt coupling tensor and `jax.ops.segment_sum`
    (JAX's sparse message-passing primitive — BCOO has no SpMM path here);
  * correlation order 3 (the paper's ν=3 B-basis) via iterated equivariant
    products: B1 = A, B2 = TP(A,A), B3 = TP(B2,A), mixed per-l by learned
    channel matrices — same function space as the symmetric contraction;
  * residual update + gated nonlinearity on scalars; invariant readout.

Tasks: "energy" (per-graph energy + optional forces via autograd) and
"node_class" (Cora/ogbn-products-style node classification; positions for
such graphs are synthesized upstream — see DESIGN.md §Arch-applicability).

Graph batch layout (padded, fixed shapes; see data/graphs.py):
  positions [N,3]  node_feat [N,F] (or species int [N])  node_mask [N]
  senders/receivers int32[E]  edge_mask [E]  graph_ids int32[N]  n_graphs
Padding edges point at node N-1 with mask 0; masked contributions are zeroed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from .layers import dense_init
from .e3 import (N_LM, L_SLICES, real_sph_harm, gaunt_tensor, tensor_product,
                 bessel_rbf, poly_cutoff)
from ..distributed.sharding import shard_hint


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    d_hidden: int = 128            # channels C
    l_max: int = 2
    correlation_order: int = 3
    n_rbf: int = 8
    r_cut: float = 5.0
    n_species: int = 16            # for molecular inputs
    d_feat: int = 0                # >0: dense node features (citation graphs)
    n_classes: int = 0             # >0: node classification head
    task: str = "energy"           # "energy" | "node_class"
    dtype: object = jnp.float32


@dataclasses.dataclass(frozen=True)
class GraphBatch:
    positions: jnp.ndarray     # [N, 3]
    node_feat: jnp.ndarray     # [N, F] float or [N] int32 species
    node_mask: jnp.ndarray     # [N] float
    senders: jnp.ndarray       # [E] int32 (message source)
    receivers: jnp.ndarray     # [E] int32
    edge_mask: jnp.ndarray     # [E] float
    graph_ids: jnp.ndarray     # [N] int32
    n_graphs: int


class MACEModel:
    def __init__(self, cfg: MACEConfig):
        self.cfg = cfg
        self.gaunt = jnp.asarray(gaunt_tensor(), jnp.float32)

    # -- params ----------------------------------------------------------------
    def init_params(self, key) -> dict:
        c = self.cfg
        C = c.d_hidden
        ks = iter(jax.random.split(key, 4 + c.n_layers * 8))
        params: dict = {}
        if c.d_feat > 0:
            params["embed"] = dense_init(next(ks), (c.d_feat, C))
        else:
            params["embed"] = 0.1 * jax.random.normal(next(ks), (c.n_species, C))
        layers = []
        n_l = len(L_SLICES)
        for _ in range(c.n_layers):
            lp = {
                # radial MLP: n_rbf -> C per output l
                "rad1": dense_init(next(ks), (c.n_rbf, 64)),
                "rad2": dense_init(next(ks), (64, C * n_l)),
                # neighbor-feature mix before the edge TP
                "w_self": dense_init(next(ks), (C, C)),
                # per-correlation-order, per-l channel mixing
                "w_b1": dense_init(next(ks), (n_l, C, C)),
                "w_b2": dense_init(next(ks), (n_l, C, C)),
                "w_b3": dense_init(next(ks), (n_l, C, C)),
                # residual + update
                "w_res": dense_init(next(ks), (C, C)),
                "gate": dense_init(next(ks), (C, C)),
            }
            layers.append(lp)
        params["layers"] = layers
        if c.task == "energy":
            params["read1"] = dense_init(next(ks), (C, 64))
            params["read2"] = dense_init(next(ks), (64, 1))
        else:
            params["read1"] = dense_init(next(ks), (C, 64))
            params["read2"] = dense_init(next(ks), (64, c.n_classes))
        return params

    # -- helpers -----------------------------------------------------------------
    def _mix_per_l(self, w, feat):
        """w [n_l, C, C] x feat [N, C, 9] -> [N, C, 9] (per-l channel mix)."""
        outs = []
        for li, (l, sl) in enumerate(sorted(L_SLICES.items())):
            outs.append(jnp.einsum("cd,ncm->ndm", w[li], feat[:, :, sl]))
        return jnp.concatenate(outs, axis=-1)

    def _layer(self, lp, h, edges):
        """h [N, C, 9] -> [N, C, 9]."""
        c = self.cfg
        senders, receivers, Y, rad, edge_mask, N = edges
        C = c.d_hidden
        # neighbor features, channel-mixed
        h_src = jnp.einsum("cd,ncm->ndm", lp["w_self"], h)[senders]   # [E, C, 9]
        # edge TP with spherical harmonics (Y as a 1-channel irrep vector)
        msg = tensor_product(h_src, jnp.broadcast_to(Y[:, None, :], h_src.shape),
                             self.gaunt)                              # [E, C, 9]
        # radial modulation per output l
        r = jax.nn.silu(rad @ lp["rad1"]) @ lp["rad2"]                # [E, C*n_l]
        r = r.reshape(-1, C, len(L_SLICES))
        rw = jnp.concatenate(
            [jnp.repeat(r[:, :, li : li + 1], sl.stop - sl.start, axis=2)
             for li, (l, sl) in enumerate(sorted(L_SLICES.items()))], axis=2)
        msg = msg * rw * edge_mask[:, None, None]
        # density: sum over neighbors (the GNN scatter — segment_sum)
        A = jax.ops.segment_sum(msg, receivers, num_segments=N)       # [N, C, 9]
        A = shard_hint(A, "nodes", None, None)
        # higher-order products (correlation order 3)
        B1 = A
        B2 = tensor_product(A, A, self.gaunt)
        B3 = tensor_product(B2, A, self.gaunt)
        m = (self._mix_per_l(lp["w_b1"], B1)
             + self._mix_per_l(lp["w_b2"], B2)
             + self._mix_per_l(lp["w_b3"], B3))
        # update: residual + scalar-gated nonlinearity
        out = m + jnp.einsum("cd,ncm->ndm", lp["w_res"], h)
        gate = jax.nn.silu(out[:, :, 0] @ lp["gate"])                 # [N, C]
        out = out * gate[:, :, None]
        return out

    # -- forward -------------------------------------------------------------------
    def forward(self, params, batch: GraphBatch):
        c = self.cfg
        N = batch.positions.shape[0]
        # initial scalars
        if c.d_feat > 0:
            h0 = batch.node_feat @ params["embed"]                    # [N, C]
        else:
            h0 = params["embed"][batch.node_feat]
        h = jnp.zeros((N, c.d_hidden, N_LM), c.dtype).at[:, :, 0].set(h0)
        h = h * batch.node_mask[:, None, None]
        # edge geometry
        vec = batch.positions[batch.receivers] - batch.positions[batch.senders]
        dist = jnp.linalg.norm(vec + 1e-12, axis=-1)
        rhat = vec / jnp.maximum(dist[:, None], 1e-9)
        Y = real_sph_harm(rhat)                                       # [E, 9]
        rad = bessel_rbf(dist, c.n_rbf, c.r_cut) * poly_cutoff(dist, c.r_cut)[:, None]
        edges = (batch.senders, batch.receivers, Y, rad, batch.edge_mask, N)
        for lp in params["layers"]:
            h = self._layer(lp, h, edges)
            h = h * batch.node_mask[:, None, None]
        inv = h[:, :, 0]                                              # invariants
        feat = jax.nn.silu(inv @ params["read1"])
        out = feat @ params["read2"]
        if c.task == "energy":
            node_e = out[:, 0] * batch.node_mask
            return jax.ops.segment_sum(node_e, batch.graph_ids,
                                       num_segments=batch.n_graphs)
        return out                                                    # [N, n_classes]

    # -- losses ----------------------------------------------------------------------
    def energy_force_loss(self, params, batch: GraphBatch, targets,
                          force_targets=None, force_w: float = 1.0):
        def energy(pos):
            return self.forward(params, dataclasses.replace(batch, positions=pos)).sum()

        if force_targets is not None:
            e, neg_f = jax.value_and_grad(energy)(batch.positions)
            pred_e = self.forward(params, batch)
            loss = jnp.mean((pred_e - targets) ** 2)
            loss += force_w * jnp.mean(
                ((-neg_f - force_targets) * batch.node_mask[:, None]) ** 2)
            return loss
        pred_e = self.forward(params, batch)
        return jnp.mean((pred_e - targets) ** 2)

    def node_class_loss(self, params, batch: GraphBatch, labels, label_mask):
        logits = self.forward(params, batch)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
        w = label_mask * batch.node_mask
        return (nll * w).sum() / jnp.maximum(w.sum(), 1.0)
