from .fault import (  # noqa: F401
    StepMonitor, HeartbeatRegistry, ElasticPolicy, FaultInjector,
    ReplicaFault, TrainDriver,
)
