"""Fault tolerance & elasticity for 1000+-node runs (simulated, API-complete).

Components a production launcher wires together:
  * StepMonitor     — per-step wall-time EWMA; flags stragglers by z-score.
  * HeartbeatRegistry — host liveness; a missed deadline marks the host dead.
  * ElasticPolicy   — given surviving hosts, proposes the largest valid mesh
                      (powers-of-two data axis, fixed model axis) to restart on.
  * FaultInjector   — deterministic fault schedule for tests/drills: step-based
                      (training, ``check``) and time-window replica faults
                      (serving, ``down`` — see ReplicaFault / ISSUE 8).
  * TrainDriver     — the restart loop: run -> fault -> restore latest ckpt ->
                      (possibly smaller mesh) -> continue. Used by tests and
                      launch/train.py --drill.

The serving cluster (``serve/cluster.py``) reuses StepMonitor (per-replica
EWMA service time feeds its queue-pressure estimator), HeartbeatRegistry
(replica liveness on the cluster's virtual microsecond clock), and
FaultInjector time windows (replica kill/stall drills).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np


class StepMonitor:
    """EWMA step-time tracker with straggler z-score detection."""

    def __init__(self, alpha: float = 0.1, z_threshold: float = 3.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.z = z_threshold
        self.warmup = warmup
        self.mean: Optional[float] = None
        self.var: float = 0.0
        self.n = 0
        self.stragglers: list[tuple[int, float]] = []

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self.n += 1
        if self.mean is None:
            self.mean = dt
            return False
        is_straggler = False
        if self.n > self.warmup and self.var > 0:
            zscore = (dt - self.mean) / (self.var ** 0.5)
            if zscore > self.z:
                is_straggler = True
                self.stragglers.append((step, dt))
        delta = dt - self.mean
        self.mean += self.alpha * delta
        self.var = (1 - self.alpha) * (self.var + self.alpha * delta * delta)
        return is_straggler


class HeartbeatRegistry:
    def __init__(self, timeout_s: float = 60.0, clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout_s
        self.clock = clock
        self.last: dict[int, float] = {}

    def beat(self, host: int):
        self.last[host] = self.clock()

    def dead_hosts(self) -> list[int]:
        now = self.clock()
        return [h for h, t in self.last.items() if now - t > self.timeout]

    def alive_hosts(self) -> list[int]:
        dead = set(self.dead_hosts())
        return [h for h in self.last if h not in dead]


@dataclasses.dataclass
class ElasticPolicy:
    """Shrink the data axis to the largest power of two that fits the
    surviving hosts; the model axis is fixed by the sharded state layout."""
    chips_per_host: int
    model_axis: int
    min_data_axis: int = 1

    def propose_mesh(self, n_alive_hosts: int) -> Optional[tuple[int, int]]:
        chips = n_alive_hosts * self.chips_per_host
        data = chips // self.model_axis
        if data < self.min_data_axis:
            return None
        data = 1 << (data.bit_length() - 1)        # floor power of two
        return (data, self.model_axis)


@dataclasses.dataclass(frozen=True)
class ReplicaFault:
    """One scheduled serving fault: ``replica`` is down over
    ``[t_down_us, t_up_us)`` on the cluster's virtual clock.

    ``kind="kill"`` loses the replica's in-memory state (queue, prefix/session
    caches — the restarted process re-admits with cold caches); ``"stall"``
    models a long pause (GC, preemption): the replica stops answering but its
    state survives recovery.
    """

    replica: int
    t_down_us: float
    t_up_us: float = float("inf")
    kind: str = "kill"

    def __post_init__(self):
        if self.kind not in ("kill", "stall"):
            raise ValueError(f"ReplicaFault.kind must be 'kill' or 'stall', "
                             f"got {self.kind!r}")
        if not self.t_down_us < self.t_up_us:
            raise ValueError(f"ReplicaFault window must be non-empty: "
                             f"[{self.t_down_us}, {self.t_up_us})")


class FaultInjector:
    """Deterministic fault schedule. Two independent APIs:

    * step-based (training): ``check(step)`` raises at scheduled steps —
      the TrainDriver restart loop catches it;
    * time-window (serving): ``down(replica, t_us)`` reports whether a
      scheduled ReplicaFault window covers ``t_us`` — the serving cluster
      polls it as ground truth while its HeartbeatRegistry provides the
      dispatcher's (delayed) view.
    """

    def __init__(self, fail_at_steps: list[int],
                 kill_hosts: Optional[list[int]] = None,
                 replica_faults: Optional[list[ReplicaFault]] = None):
        self.fail_at = set(fail_at_steps)
        self.kill_hosts = kill_hosts or []
        self.replica_faults = list(replica_faults or [])
        self.fired: list[int] = []

    def check(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.append(step)
            raise RuntimeError(f"injected node failure at step {step} "
                               f"(hosts {self.kill_hosts})")

    def down(self, replica: int, t_us: float) -> Optional[ReplicaFault]:
        """The fault window covering (replica, t_us), or None if it is up."""
        for f in self.replica_faults:
            if f.replica == replica and f.t_down_us <= t_us < f.t_up_us:
                return f
        return None

    def faults_for(self, replica: int) -> list[ReplicaFault]:
        return [f for f in self.replica_faults if f.replica == replica]


class TrainDriver:
    """Checkpoint-restart loop around a step function.

    step_fn(state, step) -> state;  save_fn(state, step);  restore_fn() ->
    (state, step);  on_fault(step, error) -> optional remesh hook.
    """

    def __init__(self, step_fn, save_fn, restore_fn, *, ckpt_every: int = 50,
                 max_restarts: int = 10, on_fault=None,
                 monitor: Optional[StepMonitor] = None):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.on_fault = on_fault
        self.monitor = monitor or StepMonitor()
        self.restarts = 0

    def run(self, state, start_step: int, total_steps: int):
        step = start_step
        while step < total_steps:
            try:
                t0 = time.monotonic()
                state = self.step_fn(state, step)
                self.monitor.record(step, time.monotonic() - t0)
                step += 1
                if step % self.ckpt_every == 0:
                    self.save_fn(state, step)
            except RuntimeError as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                if self.on_fault is not None:
                    self.on_fault(step, e)
                state, step = self.restore_fn()
        return state, step
