"""Fault-tolerant checkpointing: atomic, async-capable, mesh-elastic.

Layout per step:  <dir>/step_<N>.tmp/  ->  atomic os.replace  ->  <dir>/step_<N>/
   arrays.npz     every leaf, keys are "/"-joined tree paths
   manifest.json  treedef structure + shapes/dtypes + user metadata

Restore is *elastic*: arrays are loaded host-side and ``jax.device_put`` with
whatever shardings the (possibly different) target mesh prescribes — a run
checkpointed on 512 chips restarts on 256 by construction, because leaves are
stored as full logical arrays. (On a real multi-host fleet each host gathers
only its addressable shards; the manifest format is unchanged — noted in
DESIGN.md.)
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import numpy as np
import jax


def _key_str(p):
    for attr in ("key", "idx", "name"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append("/".join(_key_str(p) for p in path))
        leaves.append(leaf)
    return names, leaves, jax.tree_util.tree_structure(tree)


def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    names, leaves, _ = _flatten_with_names(tree)
    arrays = {n: np.asarray(l) for n, l in zip(names, leaves)}
    dtypes = {n: str(a.dtype) for n, a in arrays.items()}
    # numpy can't serialize ml_dtypes (bfloat16 etc.): store a raw-bits view
    store = {
        n: (a.view(np.uint16) if a.dtype.itemsize == 2 and "float" in str(a.dtype)
            and str(a.dtype) not in ("float16",) else a)
        for n, a in arrays.items()
    }
    np.savez(os.path.join(tmp, "arrays.npz"), **store)
    manifest = {
        "step": step,
        "names": names,
        "shapes": {n: list(a.shape) for n, a in arrays.items()},
        "dtypes": dtypes,
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                       # atomic publish
    return final


def restore_checkpoint(directory: str, template: Any, step: Optional[int] = None,
                       shardings: Any = None):
    """-> (tree, step). ``template`` fixes the treedef; ``shardings`` (same
    structure or None) re-places leaves for the current mesh (elastic)."""
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    step = step if step is not None else steps[-1]
    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    names, leaves, treedef = _flatten_with_names(template)
    new_leaves = []
    flat_sh = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: x is None) if shardings is not None
        else [None] * len(names))
    if len(flat_sh) != len(names):
        flat_sh = [None] * len(names)
    import ml_dtypes
    for n, tmpl, sh in zip(names, leaves, flat_sh):
        arr = data[n]
        want = np.dtype(tmpl.dtype) if not hasattr(tmpl.dtype, "name") \
            else tmpl.dtype
        if arr.dtype == np.uint16 and str(want) == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        else:
            arr = arr.astype(want)
        if sh is not None:
            new_leaves.append(jax.device_put(arr, sh))
        else:
            new_leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step


class CheckpointManager:
    """Retention + optional async save on a background thread."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None):
        # materialize on host BEFORE handing to the thread (donated buffers)
        host_tree = jax.tree_util.tree_map(np.asarray, tree)
        if self.async_save:
            self.wait()

            def run():
                try:
                    save_checkpoint(self.directory, step, host_tree, metadata)
                    self._gc()
                except BaseException as e:  # surfaced on next wait()
                    self._error = e

            self._thread = threading.Thread(target=run, daemon=True)
            self._thread.start()
        else:
            save_checkpoint(self.directory, step, host_tree, metadata)
            self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore(self, template, step=None, shardings=None):
        self.wait()
        return restore_checkpoint(self.directory, template, step, shardings)

    def latest_step(self) -> Optional[int]:
        if not os.path.isdir(self.directory):
            return None
        steps = [int(d.split("_")[1]) for d in os.listdir(self.directory)
                 if d.startswith("step_") and not d.endswith(".tmp")]
        return max(steps) if steps else None

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
