"""fm [Rendle ICDM'10]: n_sparse=39 embed_dim=10, pairwise interactions via
the O(nk) sum-square trick (Criteo-style field layout, 1M rows/field)."""
from .recsys_common import RecsysArch
from ..models.recsys import RecsysConfig

ARCH = RecsysArch(
    arch_id="fm",
    cfg=RecsysConfig(name="fm", kind="fm", embed_dim=10, n_sparse=39,
                     field_vocab=1_000_000),
    smoke_cfg=RecsysConfig(name="fm-smoke", kind="fm", embed_dim=8,
                           n_sparse=13, field_vocab=500),
)
