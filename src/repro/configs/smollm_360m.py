"""smollm-360m [hf:HuggingFaceTB/SmolLM-360M]: llama-arch small.
32L d_model=960 15H (GQA kv=5) head_dim=64 d_ff=2560 vocab=49152."""
import jax.numpy as jnp

from .lm_common import LMArch
from ..models.transformer import TransformerConfig

ARCH = LMArch(
    arch_id="smollm-360m",
    cfg=TransformerConfig(
        name="smollm-360m", n_layers=32, d_model=960, n_heads=15,
        n_kv_heads=5, head_dim=64, d_ff=2560, vocab=49152,
        act="swiglu", tie_embeddings=True, rope_theta=10000.0,
    ),
    smoke_cfg=TransformerConfig(
        name="smollm-360m-smoke", n_layers=2, d_model=96, n_heads=3,
        n_kv_heads=1, head_dim=32, d_ff=256, vocab=512,
        act="swiglu", tie_embeddings=True,
        dtype=jnp.float32, param_dtype=jnp.float32, remat=False,
    ),
    supports_long=False,
    # §Perf it2 winner: at 360M any TP loses; pure DP + ZeRO-1
    # (collective 2.49s -> 0.061s, roofline frac 0.018 -> 0.74)
    rule_overrides={"heads": None, "kv_heads": None, "d_ff": None, "seq": None},
)
