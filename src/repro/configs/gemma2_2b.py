"""gemma2-2b [arXiv:2408.00118]: local+global alternating, logit softcaps,
sandwich norms, GeGLU. 26L d_model=2304 8H (GQA kv=4) head_dim=256 d_ff=9216
vocab=256000, window=4096, attn softcap 50, final softcap 30."""
import jax.numpy as jnp

from .lm_common import LMArch
from ..models.transformer import TransformerConfig

ARCH = LMArch(
    arch_id="gemma2-2b",
    cfg=TransformerConfig(
        name="gemma2-2b", n_layers=26, d_model=2304, n_heads=8,
        n_kv_heads=4, head_dim=256, d_ff=9216, vocab=256000,
        act="geglu", layer_pattern="local_global", window=4096,
        post_norms=True, attn_softcap=50.0, final_softcap=30.0,
        embed_scale=True, tie_embeddings=True, rope_theta=10000.0,
    ),
    smoke_cfg=TransformerConfig(
        name="gemma2-2b-smoke", n_layers=4, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=384, vocab=512,
        act="geglu", layer_pattern="local_global", window=16,
        post_norms=True, attn_softcap=50.0, final_softcap=30.0,
        embed_scale=True, tie_embeddings=True,
        dtype=jnp.float32, param_dtype=jnp.float32, remat=False,
    ),
    supports_long=True,   # local layers are sub-quadratic; global cache seq-sharded
    # §Perf it2 winner: 8 heads / 16-way axis shard unevenly (104GiB f32
    # gathers); pure DP + ZeRO-1 -> compute-bound (frac 0.036 -> 1.0)
    rule_overrides={"heads": None, "kv_heads": None, "d_ff": None, "seq": None},
)
