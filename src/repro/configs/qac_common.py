"""The paper's own system as a config: docid-striped QAC serving at eBay scale.

Index sizing mirrors Table 2 EBAY x a production-year growth factor:
10M completions, 1M unique terms, ~3.1 postings/completion. The index stripes
over ``model``; request batches shard over (pod, data) — DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .base import Cell, Lowerable, batch_axes, ns, replicated, sds
from ..compat import default_use_kernel
from ..core.types import MAX_TERMS, MAX_TERM_CHARS
from ..core.rmq import IB_LEVELS
from ..core.striped import StripedQACIndex
from ..core.dictionary import TermDictionary
from ..core.strings import n_chunks
from ..serve.qac import qac_serve_striped

QAC_SHAPES = {
    "serve_online": dict(kind="serve", batch=4_096),
    "serve_bulk": dict(kind="serve", batch=65_536),
}


@dataclasses.dataclass
class QACArch:
    arch_id: str = "qac-ebay"
    n_completions: int = 10_000_000
    n_terms: int = 1_000_000
    postings_per_comp: float = 3.1
    k: int = 10
    # kernel-routing toggle for the batched engines: None resolves
    # platform-aware (Pallas on TPU, XLA reference elsewhere)
    use_kernel: bool | None = None
    # heap_topk override for the single-term engine: None lets the engine
    # decide from the static VMEM fit (this config's eBay-scale RMQ tables
    # exceed the budget, so its stripes take the per-pop batched-RMQ route;
    # smaller cells may force the fused kernel with True)
    heap_kernel: bool | None = None
    # postings device layout for the kernel routes (ISSUE 7): "auto"
    # prefers raw CSR when it fits the heap-kernel VMEM ceiling and falls
    # back to the compressed stream; "ef"/"bitpack" force in-kernel decode
    # (and size packed specs into index_specs); "raw" disables it
    postings_codec: str | None = "auto"
    # heap-kernel VMEM ceiling in bytes; None resolves to the platform
    # default (repro.compat.default_heap_kernel_max_bytes)
    heap_kernel_max_bytes: int | None = None
    # online serving runtime (serve/runtime.py): micro-batch formation +
    # the keystroke-locality caches. slack_us is the batching deadline per
    # request (arrival + slack), a budget spent buying batch occupancy —
    # NOT the end-to-end SLA, which also pays queueing + engine service.
    online_max_batch: int = 256
    online_slack_us: float = 20_000.0
    online_cache_entries: int = 1 << 17
    online_session_entries: int = 1 << 17
    # multi-replica serving cluster (serve/cluster.py): dispatcher + SLA
    # admission control. The pressure ladder (degrade -> shed_bulk -> shed)
    # is in estimated-wait microseconds; 50ms is the paper-motivated
    # interactive SLA, so degrade kicks in at half of it and full shed at
    # twice it. heartbeat_timeout trades detection latency against false
    # deaths from long GC pauses.
    cluster_replicas: int = 4
    cluster_max_queue: int = 1024
    cluster_degrade_pressure_us: float = 25_000.0
    cluster_shed_bulk_pressure_us: float = 50_000.0
    cluster_shed_pressure_us: float = 100_000.0
    cluster_degraded_k: int = 4
    cluster_heartbeat_timeout_us: float = 200_000.0
    # freshness tier (serve/freshness.py): the in-memory delta absorbing
    # live inserts between rebuilds. swap_threshold counts visible delta
    # changes before a rebuild-and-swap; capacity bounds the delta so it
    # can never overflow between swaps (threshold <= capacity is enforced
    # by FreshnessConfig.__post_init__).
    freshness_delta_capacity: int = 4096
    freshness_swap_threshold: int = 1024
    # observability (serve + obs, ISSUE 10): trace 1/N of requests (the
    # acceptance bench holds p99 overhead <= 10% at 16) and evaluate SLO
    # burn against the paper-motivated 50ms interactive SLA at three-nines.
    obs_trace_sample_every: int = 16
    obs_slo_target_us: float = 50_000.0
    obs_slo_objective: float = 0.999

    family = "qac"

    def runtime_config(self):
        """The arch's online-runtime knobs as a ``RuntimeConfig``."""
        from ..serve.runtime import RuntimeConfig

        return RuntimeConfig(
            max_batch=self.online_max_batch,
            slack_us=self.online_slack_us,
            cache_entries=self.online_cache_entries,
            session_entries=self.online_session_entries,
        )

    def cluster_config(self, n_replicas: int | None = None):
        """The arch's dispatcher/admission knobs as a ``ClusterConfig``;
        ``n_replicas`` overrides the preset count (experiment sweeps)."""
        from ..serve.cluster import ClusterConfig

        return ClusterConfig(
            n_replicas=(self.cluster_replicas if n_replicas is None
                        else n_replicas),
            max_queue=self.cluster_max_queue,
            degrade_pressure_us=self.cluster_degrade_pressure_us,
            shed_bulk_pressure_us=self.cluster_shed_bulk_pressure_us,
            shed_pressure_us=self.cluster_shed_pressure_us,
            degraded_k=self.cluster_degraded_k,
            heartbeat_timeout_us=self.cluster_heartbeat_timeout_us,
        )

    def freshness_config(self):
        """The arch's delta-tier/swap knobs as a ``FreshnessConfig``
        (validated there: k >= 1, capacity >= k, threshold in
        [1, capacity])."""
        from ..serve.freshness import FreshnessConfig

        return FreshnessConfig(
            k=self.k,
            delta_capacity=self.freshness_delta_capacity,
            swap_threshold=self.freshness_swap_threshold,
        )

    def obs_config(self):
        """The arch's observability knobs as an ``ObsConfig`` — tracer
        sampling stride + the SLO the burn-rate monitor evaluates."""
        from ..obs import ObsConfig

        return ObsConfig(
            trace_sample_every=self.obs_trace_sample_every,
            slo_target_us=self.obs_slo_target_us,
            slo_objective=self.obs_slo_objective,
        )

    def cells(self):
        return [Cell(self.arch_id, s, spec["kind"])
                for s, spec in QAC_SHAPES.items()]

    def index_specs(self, n_stripes: int):
        N, V, M = self.n_completions, self.n_terms, MAX_TERMS
        n_loc = N // n_stripes
        p_pad = int(N * self.postings_per_comp / n_stripes * 1.1)
        p_pad = ((p_pad + 127) // 128) * 128
        vpad = V + 2
        n_pad = ((vpad + 127) // 128) * 128
        nb = n_pad // 128
        levels = max(1, int(np.ceil(np.log2(nb))) + 1)
        S = n_stripes
        pk_specs = {}
        if self.postings_codec not in (None, "auto", "raw"):
            # packed-postings specs (ISSUE 7): the block directory is exact
            # (NB = ceil(p_pad / 128)); the word stream is provisioned at a
            # 16-bpi ceiling — real builds land well under (EF ~11 bpi) and
            # build_striped zero-pads to whatever it actually emits
            nb_pk = -(-p_pad // 128)
            w_pad = ((p_pad // 2) + 127) // 128 * 128
            pk_specs = dict(
                pp_words=sds((S, w_pad), jnp.int32),
                pp_base=sds((S, nb_pk), jnp.int32),
                pp_meta=sds((S, nb_pk), jnp.int32),
                pp_wordoff=sds((S, nb_pk), jnp.int32),
                pp_codec=self.postings_codec,
            )
        striped = StripedQACIndex(
            postings=sds((S, p_pad), jnp.int32),
            offsets=sds((S, vpad), jnp.int32),
            minimal=sds((S, vpad), jnp.int32),
            fwd_terms=sds((S, n_loc, M), jnp.int32),
            fwd_nterms=sds((S, n_loc), jnp.int32),
            rmq_values=sds((S, n_pad), jnp.int32),
            rmq_st=sds((S, levels, nb), jnp.int32),
            rmq_ib=sds((S, IB_LEVELS, n_pad), jnp.int8),
            n_stripes=S, n_terms=V, n_local_docs=n_loc, postings_pad=p_pad,
            max_terms=M, rmq_levels=levels, rmq_blocks=nb,
            **pk_specs,
        )
        C = n_chunks(MAX_TERM_CHARS)
        dictionary = TermDictionary(
            chars=sds((V, MAX_TERM_CHARS), jnp.uint8),
            keys=sds((V, C), jnp.int32),
            n_terms=V, max_chars=MAX_TERM_CHARS,
        )
        return striped, dictionary

    def lowerable(self, shape: str, mesh: Mesh) -> Lowerable:
        s = QAC_SHAPES[shape]
        B = s["batch"]
        S = mesh.shape["model"]
        bax = batch_axes(mesh)
        striped_s, dict_s = self.index_specs(S)
        striped_sh = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P("model")), striped_s)
        dict_sh = jax.tree_util.tree_map(lambda _: replicated(mesh), dict_s)
        q_specs = (
            sds((B, MAX_TERMS), jnp.int32),        # prefix_ids
            sds((B,), jnp.int32),                  # prefix_len
            sds((B, MAX_TERM_CHARS), jnp.uint8),   # suffix_chars
            sds((B,), jnp.int32),                  # suffix_len
        )
        q_sh = tuple(ns(mesh, bax, *([None] * (len(x.shape) - 1)))
                     for x in q_specs)
        k = self.k
        use_kernel = (default_use_kernel() if self.use_kernel is None
                      else self.use_kernel)

        heap_kernel = self.heap_kernel
        postings_codec = self.postings_codec
        heap_kernel_max_bytes = self.heap_kernel_max_bytes

        def fn(striped, dictionary, pids, plen, schars, slen):
            # §Perf it1 winner: butterfly merge (k·log2(S) vs k·S wire ints)
            return qac_serve_striped(striped, dictionary, pids, plen, schars,
                                     slen, k=k, mesh=mesh, merge="butterfly",
                                     use_kernel=use_kernel,
                                     heap_kernel=heap_kernel,
                                     postings_codec=postings_codec,
                                     heap_kernel_max_bytes=heap_kernel_max_bytes)

        # "model flops": integer comparisons dominate; report probe count
        probes = B * (MAX_TERMS * 31 + k * 4)
        # traffic: per query ~2 driver tiles + probe gathers + fwd rows + dict
        per_q = 2 * 128 * 4 + MAX_TERMS * 31 * 4 + 128 * MAX_TERMS * 4 + 2048
        mbytes = float(B * per_q)
        return Lowerable(
            fn=fn, arg_specs=(striped_s, dict_s) + q_specs,
            in_shardings=(striped_sh, dict_sh) + q_sh,
            out_shardings=ns(mesh, bax, None),
            model_flops=float(probes),
            model_bytes=mbytes,
            note=f"striped QAC serve batch={B}, {S} stripes, k={k}",
        )
