"""RecSys ArchDef: 4 assigned serving/training shapes per arch.

Embedding tables row-shard over ``model`` ("table_rows"); batches shard over
(pod, data). ``retrieval_cand`` shards the 1M-candidate axis over ``model``
(MIND scores candidates against interest capsules; other archs score the
batch-of-candidates through the ranking path — offline bulk semantics).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from .base import Cell, Lowerable, batch_axes, ns, replicated, sds, mesh_wrapped
from ..models.recsys import (RecsysConfig, FMModel, DINModel, BSTModel,
                             MINDModel)
from ..optim.adamw import AdamWConfig
from ..train.steps import init_train_state, make_recsys_train_step, TrainState
from ..distributed.sharding import mesh_context

RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65_536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262_144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_cand=1_048_576),
}

MODEL_CLS = {"fm": FMModel, "din": DINModel, "bst": BSTModel, "mind": MINDModel}


@dataclasses.dataclass
class RecsysArch:
    arch_id: str
    cfg: RecsysConfig
    smoke_cfg: RecsysConfig
    sparse_tables: bool = False   # fm: lazy sparse-row AdamW (§Perf)

    family = "recsys"

    def cells(self):
        return [Cell(self.arch_id, s, spec["kind"])
                for s, spec in RECSYS_SHAPES.items()]

    def feat_specs(self, batch: int):
        c = self.cfg
        if c.kind == "fm":
            return {"sparse_ids": sds((batch, c.n_sparse), jnp.int32)}
        f = {
            "hist_items": sds((batch, c.seq_len), jnp.int32),
            "hist_mask": sds((batch, c.seq_len), jnp.float32),
            "target_item": sds((batch,), jnp.int32),
        }
        if c.kind == "din":
            f["hist_cates"] = sds((batch, c.seq_len), jnp.int32)
            f["target_cate"] = sds((batch,), jnp.int32)
        return f

    def _flops(self, batch: int) -> float:
        c = self.cfg
        d = c.embed_dim
        if c.kind == "fm":
            return 2.0 * batch * c.n_sparse * d * 2
        L = c.seq_len
        if c.kind == "din":
            att = L * (8 * d) * 80 + L * 80 * 40
            mlp = (6 * d) * 200 + 200 * 80
            return 2.0 * batch * (att + mlp)
        if c.kind == "bst":
            blk = c.n_blocks * (4 * (L + 1) * d * d + 2 * (L + 1) ** 2 * d
                                + 8 * (L + 1) * d * d)
            mlp = (L + 1) * d * 1024 + 1024 * 512 + 512 * 256
            return 2.0 * batch * (blk + mlp)
        # mind: routing iters x (K x L x D) + retrieval handled separately
        return 2.0 * batch * c.capsule_iters * c.n_interests * L * d * 2

    def _traffic(self, batch: int, train: bool, params_s) -> float:
        c = self.cfg
        import numpy as _np
        pbytes = sum(float(_np.prod(l.shape)) * 4 for l in
                     jax.tree_util.tree_leaves(params_s))
        n_rows = batch * (c.n_sparse if c.kind == "fm" else c.seq_len + 1)
        gather = 2.0 * n_rows * c.embed_dim * 4
        if train:
            # dense AdamW touches EVERY table row each step: 34x param bytes.
            # (The §Perf hillclimb replaces this with sparse updates.)
            return 34.0 * pbytes + 3 * gather
        return gather + pbytes * 0.01  # serving reads MLP params only

    def lowerable(self, shape: str, mesh: Mesh) -> Lowerable:
        s = RECSYS_SHAPES[shape]
        c = self.cfg
        cls = MODEL_CLS[c.kind]
        model = cls(c)
        bax = batch_axes(mesh)
        rules = {"batch": bax, "table_rows": "model", "candidates": "model"}
        with mesh_context(mesh, rules):
            params_s = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
            axes = model.param_axes(params_s)
            from ..distributed.sharding import tree_shardings
            p_sh = tree_shardings(axes, mesh, rules)
            B = s["batch"]
            bspec = bax if B % _size(mesh, bax) == 0 else None

            if s["kind"] == "train":
                state_s = jax.eval_shape(init_train_state, params_s)
                state_sh = TrainState(
                    params=p_sh, opt={"mu": p_sh, "nu": p_sh,
                                      "step": replicated(mesh)}, ef={})
                feats = self.feat_specs(B)
                batch_s = {"feats": feats, "labels": sds((B,), jnp.float32)}
                b_sh = jax.tree_util.tree_map(
                    lambda v: ns(mesh, bspec, *([None] * (len(v.shape) - 1))),
                    batch_s)
                use_sparse = self.sparse_tables and c.kind == "fm"
                if use_sparse:
                    from ..train.steps import make_fm_sparse_train_step
                    step = make_fm_sparse_train_step(
                        model, AdamWConfig(total_steps=10_000))
                    # touched-rows traffic: 12x (p/mu/nu gather+scatter) + grads
                    u = B * c.n_sparse
                    mbytes = 14.0 * u * c.embed_dim * 4 + 2.0 * u * 4
                    note = f"train batch={B}, LAZY sparse-row AdamW"
                else:
                    step = make_recsys_train_step(
                        model, AdamWConfig(total_steps=10_000))
                    mbytes = self._traffic(B, True, params_s)
                    note = f"train batch={B}, tables row-sharded"
                met = {"grad_norm": replicated(mesh), "lr": replicated(mesh),
                       "loss": replicated(mesh)}
                return Lowerable(
                    fn=mesh_wrapped(step, mesh, rules),
                    arg_specs=(state_s, batch_s),
                    in_shardings=(state_sh, b_sh), out_shardings=(state_sh, met),
                    donate_argnums=(0,),
                    model_flops=3.0 * self._flops(B),  # fwd + bwd ~ 3x fwd
                    model_bytes=mbytes,
                    note=note,
                )

            if s["kind"] == "serve":
                feats = self.feat_specs(B)
                f_sh = jax.tree_util.tree_map(
                    lambda v: ns(mesh, bspec, *([None] * (len(v.shape) - 1))),
                    feats)

                def fn(params, f):
                    return model.forward(params, f)

                return Lowerable(
                    fn=mesh_wrapped(fn, mesh, rules),
                    arg_specs=(params_s, feats),
                    in_shardings=(p_sh, f_sh),
                    out_shardings=ns(mesh, bspec),
                    model_flops=self._flops(B),
                    model_bytes=self._traffic(B, False, params_s),
                    note=f"serve batch={B}",
                )

            # retrieval
            NC = s["n_cand"]
            if c.kind == "mind":
                feats = self.feat_specs(s["batch"])
                f_sh = jax.tree_util.tree_map(
                    lambda v: ns(mesh, *([None] * len(v.shape))), feats)
                cand = sds((NC, c.embed_dim), jnp.float32)

                def fn(params, f, ce):
                    return model.retrieve(params, f, ce, k=100)

                return Lowerable(
                    fn=mesh_wrapped(fn, mesh, rules),
                    arg_specs=(params_s, feats, cand),
                    in_shardings=(p_sh, f_sh, ns(mesh, "model", None)),
                    out_shardings=[ns(mesh, None, None), ns(mesh, None, None)],
                    model_flops=2.0 * NC * c.n_interests * c.embed_dim,
                    model_bytes=2.0 * NC * c.embed_dim * 4,
                    note=f"retrieval 1x{NC} candidates (model-sharded)",
                )
            # other archs: offline scoring of NC candidates (bulk ranking)
            feats = self.feat_specs(NC)
            f_sh = jax.tree_util.tree_map(
                lambda v: ns(mesh, bax, *([None] * (len(v.shape) - 1))), feats)

            def fn(params, f):
                return model.forward(params, f)

            return Lowerable(
                fn=mesh_wrapped(fn, mesh, rules),
                arg_specs=(params_s, feats),
                in_shardings=(p_sh, f_sh), out_shardings=ns(mesh, bax),
                model_flops=self._flops(NC),
                model_bytes=self._traffic(NC, False, params_s),
                note=f"retrieval-as-bulk-ranking {NC} candidates",
            )


def _size(mesh, axes):
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return max(out, 1)
