"""qac-ebay: the paper's system at production scale (the 11th config)."""
from .qac_common import QACArch

ARCH = QACArch(arch_id="qac-ebay")
