"""bst [arXiv:1905.06874] (Alibaba): embed_dim=32 seq_len=20 n_blocks=1
n_heads=8 mlp=1024-512-256, transformer over the behavior sequence."""
from .recsys_common import RecsysArch
from ..models.recsys import RecsysConfig

ARCH = RecsysArch(
    arch_id="bst",
    cfg=RecsysConfig(name="bst", kind="bst", embed_dim=32, seq_len=20,
                     n_blocks=1, n_heads=8, mlp=(1024, 512, 256),
                     item_vocab=10_000_000),
    smoke_cfg=RecsysConfig(name="bst-smoke", kind="bst", embed_dim=16,
                           seq_len=8, n_blocks=1, n_heads=4,
                           mlp=(64, 32), item_vocab=2_000),
)
