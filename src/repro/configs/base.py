"""Arch/shape cell machinery shared by every config.

An ArchDef yields, per (arch x shape) cell, everything the dry-run needs:
the step callable, ShapeDtypeStruct argument specs, and in/out shardings for
the target mesh — with NO device allocation (jax.eval_shape end-to-end).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str                     # train | prefill | decode | serve | retrieval
    skip: Optional[str] = None    # reason if inapplicable (still reported)


@dataclasses.dataclass
class Lowerable:
    """One dry-run unit: jit(fn, in_shardings, out_shardings).lower(*specs)."""
    fn: Callable
    arg_specs: tuple
    in_shardings: Any
    out_shardings: Any
    static_argnums: tuple = ()
    donate_argnums: tuple = ()
    # analytic model FLOPs for §Roofline (6ND etc.); None = n/a
    model_flops: Optional[float] = None
    # analytic minimum HBM traffic in bytes (global, per step); None = n/a
    model_bytes: Optional[float] = None
    note: str = ""


def mesh_wrapped(fn, mesh, rules):
    """Make fn trace inside the mesh context (jit traces lazily, AFTER the
    arch-def's ``with mesh_context`` block has exited — without this,
    shard_hint/get_mesh see no mesh during lowering)."""
    import functools as _ft
    from ..distributed.sharding import mesh_context as _mc

    @_ft.wraps(fn)
    def wrapped(*a, **k):
        with _mc(mesh, rules):
            return fn(*a, **k)

    return wrapped


def batch_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def ns(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def tree_of(sharding, tree):
    """Broadcast one sharding over a pytree of specs."""
    return jax.tree_util.tree_map(lambda _: sharding, tree)


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def pad_to(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult
