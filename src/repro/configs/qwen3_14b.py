"""qwen3-14b [hf:Qwen/Qwen3-14B family]: qk_norm, GQA.
40L d_model=5120 40H (GQA kv=8) head_dim=128 d_ff=17408 vocab=151936."""
import jax.numpy as jnp

from .lm_common import LMArch
from ..models.transformer import TransformerConfig

ARCH = LMArch(
    arch_id="qwen3-14b",
    cfg=TransformerConfig(
        name="qwen3-14b", n_layers=40, d_model=5120, n_heads=40,
        n_kv_heads=8, head_dim=128, d_ff=17408, vocab=151936,
        act="swiglu", qk_norm=True, tie_embeddings=False,
        rope_theta=1_000_000.0,
    ),
    smoke_cfg=TransformerConfig(
        name="qwen3-14b-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=320, vocab=512,
        act="swiglu", qk_norm=True, tie_embeddings=False,
        dtype=jnp.float32, param_dtype=jnp.float32, remat=False,
    ),
    supports_long=False,
    # §Perf it3 winner: full FSDP (batch over data x model = 256 exactly,
    # weights gathered JIT), no microbatching (frac 0.089 -> 0.527)
    train_microbatches=1,
    rule_overrides={"batch": ("data", "model"), "heads": "data",
                    "kv_heads": "data", "d_ff": "data", "seq": None},
    decode_rule_overrides={"batch": ("pod", "data"), "heads": None,
                           "kv_heads": None, "d_ff": "model"},
    # prefill B=32 cannot cover 256 devices via batch: SP+KV-gather instead
    prefill_rule_overrides={"batch": ("pod", "data"), "heads": None,
                            "kv_heads": None, "d_ff": "model", "seq": "model"},
)
