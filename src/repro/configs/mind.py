"""mind [arXiv:1904.08030]: embed_dim=64 n_interests=4 capsule_iters=3,
multi-interest retrieval. Item table 10M rows (production-scale)."""
from .recsys_common import RecsysArch
from ..models.recsys import RecsysConfig

ARCH = RecsysArch(
    arch_id="mind",
    cfg=RecsysConfig(name="mind", kind="mind", embed_dim=64, seq_len=50,
                     item_vocab=10_000_000, n_interests=4, capsule_iters=3),
    smoke_cfg=RecsysConfig(name="mind-smoke", kind="mind", embed_dim=16,
                           seq_len=12, item_vocab=2_000, n_interests=4,
                           capsule_iters=3),
)
