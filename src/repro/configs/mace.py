"""mace [arXiv:2206.07697]: n_layers=2 d_hidden=128 l_max=2
correlation_order=3 n_rbf=8, E(3)-equivariant (ACE basis)."""
from .gnn_common import GNNArch
from ..models.mace import MACEConfig

ARCH = GNNArch(
    arch_id="mace",
    base_cfg=MACEConfig(name="mace", n_layers=2, d_hidden=128, l_max=2,
                        correlation_order=3, n_rbf=8, n_species=16),
    smoke_cfg=MACEConfig(name="mace-smoke", n_layers=2, d_hidden=16, l_max=2,
                         correlation_order=3, n_rbf=4, n_species=8),
)
