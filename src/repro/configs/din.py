"""din [arXiv:1706.06978]: embed_dim=18 seq_len=100 attn_mlp=80-40
mlp=200-80, target attention over user history."""
from .recsys_common import RecsysArch
from ..models.recsys import RecsysConfig

ARCH = RecsysArch(
    arch_id="din",
    cfg=RecsysConfig(name="din", kind="din", embed_dim=18, seq_len=100,
                     attn_mlp=(80, 40), mlp=(200, 80),
                     item_vocab=10_000_000, cate_vocab=10_000),
    smoke_cfg=RecsysConfig(name="din-smoke", kind="din", embed_dim=8,
                           seq_len=16, attn_mlp=(32, 16), mlp=(32, 16),
                           item_vocab=2_000, cate_vocab=50),
)
