"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]: 4-way shared expert (5632 ff,
gated) + 60 routed experts top-4 (1408 ff each), norm_topk off.
24L d_model=2048 16H (kv 16) head_dim=128 d_ff(expert)=1408 vocab=151936."""
import jax.numpy as jnp

from .lm_common import LMArch
from ..models.transformer import TransformerConfig, MoESettings

ARCH = LMArch(
    arch_id="qwen2-moe-a2.7b",
    cfg=TransformerConfig(
        name="qwen2-moe-a2.7b", n_layers=24, d_model=2048, n_heads=16,
        n_kv_heads=16, head_dim=128, d_ff=5632, vocab=151936,
        act="swiglu", tie_embeddings=False, rope_theta=1_000_000.0,
        # §Perf it1+it3 winners: pad expert arrays 60->64 so EP divides the
        # mesh (4 dead experts = 6.7% waste), attention/shared expert in
        # pure DP (collective 52.5s -> 1.30s, frac 0.006 -> 0.258)
        moe=MoESettings(n_experts=60, top_k=4, d_expert=1408,
                        shared_d_ff=5632, norm_topk=False,
                        pad_experts_to=64),
        moe_shard_map=True,
    ),
    smoke_cfg=TransformerConfig(
        name="qwen2-moe-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, head_dim=32, d_ff=256, vocab=512,
        act="swiglu", tie_embeddings=False,
        moe=MoESettings(n_experts=6, top_k=2, d_expert=64, shared_d_ff=128,
                        norm_topk=False, capacity_factor=4.0),
        dtype=jnp.float32, param_dtype=jnp.float32, remat=False,
    ),
    supports_long=False,
    rule_overrides={"experts": "model", "expert_ff": None,
                    "heads": None, "kv_heads": None, "d_ff": None},
)
