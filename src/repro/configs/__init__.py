"""Arch registry: ``--arch <id>`` resolves here. One module per assigned
architecture (exact public-literature configs) + the paper's own system."""
from __future__ import annotations

from .base import Cell, Lowerable  # noqa: F401
from .smollm_360m import ARCH as _smollm
from .qwen3_14b import ARCH as _qwen3
from .gemma2_2b import ARCH as _gemma2
from .qwen2_moe_a2_7b import ARCH as _qwen2moe
from .qwen3_moe_235b_a22b import ARCH as _qwen3moe
from .mace import ARCH as _mace
from .mind import ARCH as _mind
from .bst import ARCH as _bst
from .din import ARCH as _din
from .fm import ARCH as _fm
from .qac_ebay import ARCH as _qac

ARCHS = {
    a.arch_id: a
    for a in [_smollm, _qwen3, _gemma2, _qwen2moe, _qwen3moe,
              _mace, _mind, _bst, _din, _fm, _qac]
}


def get_arch(arch_id: str):
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch '{arch_id}'; have {sorted(ARCHS)}")
    return ARCHS[arch_id]


def list_archs():
    return sorted(ARCHS)


def all_cells(include_qac: bool = True):
    cells = []
    for aid in list_archs():
        if not include_qac and aid == "qac-ebay":
            continue
        cells.extend(get_arch(aid).cells())
    return cells
