"""GNN (MACE) ArchDef: 4 assigned graph shapes.

Sharding: edges (the big axis) over every mesh axis; node state over
(pod, data) when large. The message gather h[senders] across node shards is
where full-graph GNNs become collective-bound — visible in §Roofline.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from .base import Cell, Lowerable, batch_axes, ns, replicated, sds, pad_to, mesh_wrapped
from ..models.mace import MACEConfig, MACEModel
from ..optim.adamw import AdamWConfig
from ..train.steps import init_train_state, make_gnn_train_step, TrainState
from ..distributed.sharding import mesh_context

# shape table (assigned): padded sizes are chosen divisible by 512
GNN_SHAPES = {
    "full_graph_sm": dict(kind="train", n_nodes=2_708, n_edges=10_556,
                          d_feat=1_433, n_classes=7, task="node_class",
                          pad_nodes=3_072, pad_edges=10_752, n_graphs=1),
    "minibatch_lg": dict(kind="train", n_nodes=232_965, n_edges=114_615_892,
                         batch_nodes=1_024, fanout=(15, 10), d_feat=602,
                         n_classes=41, task="node_class",
                         pad_nodes=172_032, pad_edges=169_984, n_graphs=1),
    "ogb_products": dict(kind="train", n_nodes=2_449_029, n_edges=61_859_140,
                         d_feat=100, n_classes=47, task="node_class",
                         pad_nodes=2_457_600, pad_edges=61_865_984, n_graphs=1),
    "molecule": dict(kind="train", n_nodes=30, n_edges=64, batch=128,
                     task="energy", pad_nodes=3_840, pad_edges=8_192,
                     n_graphs=128),
}


@dataclasses.dataclass
class GNNArch:
    arch_id: str
    base_cfg: MACEConfig
    smoke_cfg: MACEConfig

    family = "gnn"

    def cells(self):
        return [Cell(self.arch_id, s, spec["kind"])
                for s, spec in GNN_SHAPES.items()]

    def cfg_for(self, shape: str) -> MACEConfig:
        s = GNN_SHAPES[shape]
        if s["task"] == "node_class":
            return dataclasses.replace(
                self.base_cfg, d_feat=s["d_feat"], n_classes=s["n_classes"],
                task="node_class")
        return dataclasses.replace(self.base_cfg, d_feat=0, task="energy")

    def batch_specs(self, shape: str):
        s = GNN_SHAPES[shape]
        N, E = s["pad_nodes"], s["pad_edges"]
        specs = {
            "positions": sds((N, 3), jnp.float32),
            "node_mask": sds((N,), jnp.float32),
            "senders": sds((E,), jnp.int32),
            "receivers": sds((E,), jnp.int32),
            "edge_mask": sds((E,), jnp.float32),
            "graph_ids": sds((N,), jnp.int32),
        }
        if s["task"] == "node_class":
            specs["node_feat"] = sds((N, s["d_feat"]), jnp.float32)
            specs["labels"] = sds((N,), jnp.int32)
            specs["label_mask"] = sds((N,), jnp.float32)
        else:
            specs["node_feat"] = sds((N,), jnp.int32)
            specs["targets"] = sds((s["n_graphs"],), jnp.float32)
        return specs

    def lowerable(self, shape: str, mesh: Mesh) -> Lowerable:
        s = GNN_SHAPES[shape]
        cfg = self.cfg_for(shape)
        model = MACEModel(cfg)
        bax = batch_axes(mesh)
        all_ax = tuple(mesh.axis_names)
        N, E = s["pad_nodes"], s["pad_edges"]
        n_dev = 1
        for a in mesh.axis_names:
            n_dev *= mesh.shape[a]
        # shard nodes/edges over every axis when divisible, else batch axes
        node_ax = all_ax if N % n_dev == 0 else (bax if N % _size(mesh, bax) == 0 else ())
        edge_ax = all_ax if E % n_dev == 0 else (bax if E % _size(mesh, bax) == 0 else ())

        with mesh_context(mesh, {"nodes": node_ax or None, "edges": edge_ax or None}):
            params_s = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
            state_s = jax.eval_shape(functools.partial(init_train_state), params_s)
            p_sh = jax.tree_util.tree_map(lambda _: replicated(mesh), params_s)
            state_sh = TrainState(
                params=p_sh,
                opt={"mu": p_sh, "nu": p_sh, "step": replicated(mesh)},
                ef={},
            )
            batch_s = self.batch_specs(shape)

            def field_sh(name, spec):
                ax = node_ax if spec.shape[0] == N else (
                    edge_ax if spec.shape[0] == E else ())
                return ns(mesh, ax if ax else None,
                          *([None] * (len(spec.shape) - 1)))

            b_sh = {k: field_sh(k, v) for k, v in batch_s.items()}
            step = make_gnn_train_step(
                model, AdamWConfig(total_steps=10_000), task=s["task"],
                n_graphs=s["n_graphs"])
            met = {"grad_norm": replicated(mesh), "lr": replicated(mesh),
                   "loss": replicated(mesh)}
            # analytic FLOPs: per edge, TP (9*9*9*C mults x3 orders) + radial
            C = cfg.d_hidden
            per_edge = cfg.n_layers * C * (3 * 9 * 9 * 9 + 2 * cfg.n_rbf * 64)
            per_node = cfg.n_layers * C * C * 9 * 5
            flops = 2.0 * (E * per_edge + N * per_node)
            # traffic: edge message stream rw x layers x fwd+bwd, node state,
            # features, dense AdamW on all params (34x)
            import numpy as _np
            pbytes = sum(_np.prod(l.shape) * 4 for l in
                         jax.tree_util.tree_leaves(params_s))
            feat_b = (N * s["d_feat"] * 4 if s["task"] == "node_class" else N * 4)
            mbytes = (34.0 * pbytes
                      + 3.0 * cfg.n_layers * (4 * E * C * 9 * 4 + 4 * N * C * 9 * 4)
                      + 2 * feat_b + 3 * E * 12)
            return Lowerable(
                fn=mesh_wrapped(step, mesh,
                                {"nodes": node_ax or None, "edges": edge_ax or None}),
                arg_specs=(state_s, batch_s),
                in_shardings=(state_sh, b_sh),
                out_shardings=(state_sh, met),
                donate_argnums=(0,),
                model_flops=flops,
                model_bytes=mbytes,
                note=f"{s['task']} N={N} E={E} nodes->{node_ax} edges->{edge_ax}",
            )


def _size(mesh, axes):
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return max(out, 1)
