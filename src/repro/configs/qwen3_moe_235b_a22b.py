"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-235B-A22B]: 128 experts top-8, qk_norm.
94L d_model=4096 64H (GQA kv=4) head_dim=128 d_ff(expert)=1536 vocab=151936."""
import jax.numpy as jnp

from .lm_common import LMArch
from ..models.transformer import TransformerConfig, MoESettings

ARCH = LMArch(
    arch_id="qwen3-moe-235b-a22b",
    cfg=TransformerConfig(
        name="qwen3-moe-235b-a22b", n_layers=94, d_model=4096, n_heads=64,
        n_kv_heads=4, head_dim=128, d_ff=1536, vocab=151936,
        act="swiglu", qk_norm=True, tie_embeddings=False,
        rope_theta=1_000_000.0,
        moe=MoESettings(n_experts=128, top_k=8, d_expert=1536,
                        shared_d_ff=0, norm_topk=True),
        moe_shard_map=True,
        moe_fsdp=True,
    ),
    smoke_cfg=TransformerConfig(
        name="qwen3-moe-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, vocab=512,
        act="swiglu", qk_norm=True, tie_embeddings=False,
        moe=MoESettings(n_experts=8, top_k=2, d_expert=64,
                        capacity_factor=4.0),
        dtype=jnp.float32, param_dtype=jnp.float32, remat=False,
    ),
    supports_long=False,
    # §Perf it1+it3 winners: no microbatching (FSDP already shards memory),
    # kv projections replicated (4 kv heads shard unevenly 16 ways)
    # (collective 30.6s -> 19.1s, frac 0.090 -> 0.145)
    train_microbatches=1,
    rule_overrides={"expert_ff": "data", "kv_heads": None},
    # big-model serving: shard attention projections too (params >> HBM)
    decode_rule_overrides={"heads": "model"},
)
