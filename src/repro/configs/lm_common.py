"""LM-family ArchDef: shapes, specs, and shardings for the 5 transformer archs.

Shapes (assigned): train_4k (train), prefill_32k (prefill), decode_32k and
long_500k (serve_step: one token against a KV cache). long_500k runs only for
archs with a sub-quadratic path (gemma2 local/global); pure full-attention
archs skip it (DESIGN.md §5).

Sharding plans (DESIGN.md §6):
  train/prefill: batch->(pod,data); heads/kv_heads/d_ff/experts/vocab->model;
                 ZeRO-1 moments additionally over data.
  decode:        batch->(pod,data); KV-cache seq->model  (sequence-parallel
                 decode: GSPMD lowers the attention softmax over the sharded
                 cache to local partial-softmax + small cross-shard LSE merge);
                 heads replicated; experts->model.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .base import Cell, Lowerable, batch_axes, ns, replicated, sds, mesh_wrapped
from ..models.transformer import TransformerConfig, MoESettings, TransformerLM
from ..optim.adamw import AdamWConfig
from ..train.steps import init_train_state, make_lm_train_step, TrainState
from ..serve.lm import prefill_step
from ..distributed.sharding import (
    AxisRules, DEFAULT_LM_RULES, mesh_context, tree_shardings, zero1_shardings,
    logical_sharding,
)

LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

DECODE_RULES: AxisRules = dict(DEFAULT_LM_RULES)
DECODE_RULES.update({
    "heads": None, "kv_heads": None, "d_ff": "model",
    "kv_seq": "model", "vocab": "model", "experts": "model",
})


@dataclasses.dataclass
class LMArch:
    arch_id: str
    cfg: TransformerConfig
    smoke_cfg: TransformerConfig
    supports_long: bool = False
    train_microbatches: int = 1
    rule_overrides: dict = None          # per-arch logical-axis remaps
    decode_rule_overrides: dict = None   # extra remaps for decode cells only
    prefill_rule_overrides: dict = None  # extra remaps for prefill cells only

    family = "lm"

    # -- analytic minimum HBM traffic (global bytes per step) ---------------
    def _traffic(self, kind: str, B: int, S: int) -> float:
        """Traffic model (documented in EXPERIMENTS.md §Roofline):
        train:   params bf16 read fwd+bwd+recompute (3x2P) + update rw (2x2P)
                 + fp32 moments rw (4x4P) + fp32 grads rw (2x4P) = 34P
                 + activation stream ~2x per layer (remat) + logits 3x f32
        prefill: params 2P + activation stream 1x + kv write
        decode:  params 2P (every weight read once per token — the serving
                 bound) + full KV cache read + logits
        """
        c = self.cfg
        P = c.param_count()
        d = c.d_model
        if c.moe:
            f_eff = c.moe.top_k * c.moe.d_expert + c.moe.shared_d_ff
        else:
            f_eff = c.d_ff
        tok = B * S
        act_layer = tok * (4 * d + 2 * f_eff) * 2          # bf16 stream
        logits = 3.0 * tok * c.vocab * 4
        kv = tok * 2 * c.n_kv_heads * c.head_dim * 2
        if kind == "train":
            return 34.0 * P + 2 * c.n_layers * act_layer + logits
        if kind == "prefill":
            return 2.0 * P + c.n_layers * act_layer + kv + 3.0 * B * c.vocab * 4
        # decode: S == cache length
        cache = 0
        for i in range(c.layers_per_step):
            w = c.window_of(i)
            Sc = min(w, S) if w > 0 else S
            cache += (c.n_layers // c.layers_per_step) * B * Sc \
                * 2 * c.n_kv_heads * c.head_dim * 2
        return 2.0 * P + cache + 3.0 * B * c.vocab * 4

    def cells(self):
        out = []
        for shape, spec in LM_SHAPES.items():
            skip = None
            if shape == "long_500k" and not self.supports_long:
                skip = ("pure full-attention arch: no sub-quadratic path for "
                        "524k decode (DESIGN.md §5)")
            out.append(Cell(self.arch_id, shape, spec["kind"], skip))
        return out

    # ------------------------------------------------------------------
    def _model(self, shape: str) -> TransformerLM:
        cfg = self.cfg
        if LM_SHAPES[shape]["kind"] != "train":
            cfg = dataclasses.replace(cfg, remat=False)
        return TransformerLM(cfg)

    def _param_specs(self, model):
        return jax.eval_shape(model.init_params, jax.random.PRNGKey(0))

    def lowerable(self, shape: str, mesh: Mesh) -> Lowerable:
        spec = LM_SHAPES[shape]
        kind = spec["kind"]
        B, S = spec["batch"], spec["seq"]
        model = self._model(shape)
        c = model.cfg
        bax = batch_axes(mesh)
        bsz = 1
        for a in bax:
            bsz *= mesh.shape[a]
        bax = bax if B % bsz == 0 else None
        n_chips = 1
        for a in mesh.axis_names:
            n_chips *= mesh.shape[a]

        if kind == "train":
            rules = dict(DEFAULT_LM_RULES, **(self.rule_overrides or {}))
            with mesh_context(mesh, rules):
                params_s = self._param_specs(model)
                axes = model.param_axes(params_s)
                p_sh = tree_shardings(axes, mesh, rules)
                state_s = jax.eval_shape(
                    functools.partial(init_train_state, compress="pod" in mesh.axis_names and mesh.shape.get("pod", 1) > 1),
                    params_s)
                opt_mom_sh = zero1_shardings(params_s, p_sh, mesh)
                state_sh = TrainState(
                    params=p_sh,
                    opt={"mu": opt_mom_sh, "nu": opt_mom_sh,
                         "step": replicated(mesh)},
                    ef=jax.tree_util.tree_map(lambda _: replicated(mesh), state_s.ef)
                    if state_s.ef else {},
                )
                if state_s.ef:
                    state_sh = dataclasses.replace(state_sh, ef=opt_mom_sh)
                batch_s = {
                    "tokens": sds((B, S), jnp.int32),
                    "targets": sds((B, S), jnp.int32),
                    "mask": sds((B, S), jnp.float32),
                }
                b_sh = {k: ns(mesh, bax, None) for k in batch_s}
                step = make_lm_train_step(
                    model, AdamWConfig(total_steps=10_000),
                    microbatches=self.train_microbatches,
                    compress_pod=mesh.shape.get("pod", 1) > 1)
                met_sh = {"grad_norm": replicated(mesh), "lr": replicated(mesh),
                          "loss": replicated(mesh)}
                return Lowerable(
                    fn=mesh_wrapped(step, mesh, rules),
                    arg_specs=(state_s, batch_s),
                    in_shardings=(state_sh, b_sh),
                    out_shardings=(state_sh, met_sh),
                    donate_argnums=(0,),
                    model_flops=6.0 * c.active_param_count() * B * S,
                    model_bytes=self._traffic("train", B, S),
                    note=f"train {B}x{S}, mb={self.train_microbatches}, ZeRO-1",
                )

        if kind == "prefill":
            rules = dict(DEFAULT_LM_RULES)
            rules.update(self.rule_overrides or {})
            rules.update(self.prefill_rule_overrides or {})
            with mesh_context(mesh, rules):
                params_s = self._param_specs(model)
                p_sh = tree_shardings(model.param_axes(params_s), mesh, rules)
                toks = sds((B, S), jnp.int32)
                fn = functools.partial(prefill_step, model)
                return Lowerable(
                    fn=mesh_wrapped(fn, mesh, rules),
                    arg_specs=(params_s, toks),
                    in_shardings=(p_sh, ns(mesh, bax, None)),
                    out_shardings=ns(mesh, bax, "model"),
                    model_flops=2.0 * c.active_param_count() * B * S,
                    model_bytes=self._traffic("prefill", B, S),
                    note=f"prefill {B}x{S}",
                )

        # decode
        rules = dict(DECODE_RULES)
        rules.update(self.rule_overrides or {})
        rules.update(self.decode_rule_overrides or {})
        with mesh_context(mesh, rules):
            params_s = self._param_specs(model)
            p_sh = tree_shardings(model.param_axes(params_s), mesh, rules)
            cache_s = jax.eval_shape(lambda: model.init_cache(B, S))
            kv_sh = tuple(
                ns(mesh, None, bax, None,
                   "model" if k.shape[3] % mesh.shape["model"] == 0 else None,
                   None)
                for k in cache_s["k"]
            )
            cache_sh = {"pos": ns(mesh, bax), "k": kv_sh, "v": kv_sh}
            toks = sds((B,), jnp.int32)

            def fn(params, cache, tokens):
                return model.decode_step(params, cache, tokens)

            return Lowerable(
                fn=mesh_wrapped(fn, mesh, rules),
                arg_specs=(params_s, cache_s, toks),
                in_shardings=(p_sh, cache_sh, ns(mesh, bax)),
                out_shardings=(ns(mesh, bax, "model"), cache_sh),
                donate_argnums=(1,),
                model_flops=2.0 * c.active_param_count() * B,
                model_bytes=self._traffic("decode", B, S),
                note=f"decode batch={B}, cache={S} (seq-sharded)",
            )

    # -- smoke (CPU) ------------------------------------------------------
    def smoke_model(self) -> TransformerLM:
        return TransformerLM(self.smoke_cfg)
