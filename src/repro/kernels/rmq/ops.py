"""Public jit'd wrapper: batched RMQ against a RangeMin structure."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...compat import pallas_interpret_default
from .kernel import rmq_query_kernel, BLOCK
from .ref import rmq_query_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def rmq_query(values, st_pos, p, q, *, use_kernel: bool = True,
              interpret: bool | None = None):
    """Batched (pos, val) of argmin over values[p[i]..q[i]].

    values: int32[n_pad] (INF padded to a BLOCK multiple); st_pos: sparse
    table positions [levels, nb]. p, q: int32[B] inclusive ranges.
    ``interpret=None`` resolves platform-aware: real lowering on TPU,
    interpret mode elsewhere.
    """
    if interpret is None:
        interpret = pallas_interpret_default()
    n_pad = values.shape[0]
    nb = n_pad // BLOCK
    st_val = values[st_pos]                         # [levels, nb]
    pc = jnp.clip(p, 0, n_pad - 1)
    qc = jnp.clip(q, 0, n_pad - 1)
    pq = jnp.stack([pc, qc], axis=1).astype(jnp.int32)
    pq = jnp.where((p > q)[:, None], jnp.stack([jnp.ones_like(pc), jnp.zeros_like(qc)], 1), pq)
    blocks = values.reshape(nb, BLOCK)
    lblock = blocks[pq[:, 0] // BLOCK]
    rblock = blocks[pq[:, 1] // BLOCK]
    if use_kernel:
        out = rmq_query_kernel(pq, lblock, rblock, st_pos, st_val,
                               interpret=interpret)
        return out[:, 0], out[:, 1]
    pos, val = rmq_query_ref(pq, lblock, rblock, st_pos, st_val, nb)
    return pos, val
