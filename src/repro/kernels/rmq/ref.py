"""Pure-jnp oracle for the batched two-level RMQ kernel.

Semantics: for each row b, argmin over values[p[b] .. q[b]] inclusive given
  lblock[b]: the 128-wide block containing p (pre-gathered)
  rblock[b]: the 128-wide block containing q
  st_pos/st_val: sparse table over block minima (positions are global).
Returns (pos, val); invalid ranges (p > q) give (0, INF).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

INF = 2**31 - 1
BLOCK = 128


def rmq_query_ref(pq, lblock, rblock, st_pos, st_val, n_blocks):
    def one(pq_row, lb, rb):
        p, q = pq_row[0], pq_row[1]
        bp, bq = p // BLOCK, q // BLOCK
        lane = jnp.arange(BLOCK, dtype=jnp.int32)
        same = bp == bq
        lmask = (lane >= p % BLOCK) & (lane <= jnp.where(same, q % BLOCK, BLOCK - 1))
        lvals = jnp.where(lmask, lb, INF)
        a1 = jnp.argmin(lvals)
        c1_pos, c1_val = bp * BLOCK + a1, lvals[a1]
        rmask = lane <= q % BLOCK
        rvals = jnp.where(rmask, rb, INF)
        a2 = jnp.argmin(rvals)
        c2_pos = bq * BLOCK + a2
        c2_val = jnp.where(same, INF, rvals[a2])
        cnt = bq - bp - 1
        has_mid = cnt > 0
        j = jnp.where(has_mid, 31 - lax.clz(jnp.maximum(cnt, 1)), 0)
        jc = jnp.minimum(j, st_pos.shape[0] - 1)
        lo_b = jnp.minimum(bp + 1, n_blocks - 1)
        hi_b = jnp.clip(bq - (1 << jc), 0, n_blocks - 1)
        c3_pos, c3_val = st_pos[jc, lo_b], jnp.where(has_mid, st_val[jc, lo_b], INF)
        c4_pos, c4_val = st_pos[jc, hi_b], jnp.where(has_mid, st_val[jc, hi_b], INF)
        pos = jnp.stack([c1_pos, c2_pos, c3_pos, c4_pos])
        val = jnp.stack([c1_val, c2_val, c3_val, c4_val])
        val = jnp.where(p > q, INF, val)
        best = jnp.argmin(val)
        return pos[best], val[best]

    return jax.vmap(one)(pq, lblock, rblock)


def rmq_window_batch(values_flat, ib_flat, st_flat, p, q, *, n: int,
                     levels: int, n_blocks: int, nb_stride: int, n_pad: int):
    """(pos, val) of argmin over values[p[i]..q[i]] inclusive — the XLA
    in-block-window formulation (``RangeMin.query_batch`` contract: ``val``
    bit-identical to the scalar query, ``pos`` whenever ``val < INF``).

    The ONE transcription of the two-overlapping-window math shared by this
    oracle and the Pallas kernel body (which calls it on its VMEM-resident
    flat tables). All inputs are flat 1-D: ``ib_flat`` is the ``[7, n_pad]``
    table row-major (any int dtype; widened here), ``st_flat`` the sparse
    table with row stride ``nb_stride`` (= ``n_blocks``, or the lane-padded
    width when the kernel pads the table columns).
    """
    p = jnp.clip(p, 0, max(n - 1, 0)).astype(jnp.int32)
    qc = jnp.clip(q, 0, max(n - 1, 0)).astype(jnp.int32)
    invalid = (p > qc) | (n == 0)
    bp, bq = p // BLOCK, qc // BLOCK
    same = bp == bq
    lo1 = p
    hi1 = jnp.maximum(jnp.where(same, qc, bp * BLOCK + (BLOCK - 1)), p)
    lo2, hi2 = bq * BLOCK, qc
    j1 = 31 - lax.clz(jnp.maximum(hi1 - lo1 + 1, 1))
    j2 = 31 - lax.clz(jnp.maximum(hi2 - lo2 + 1, 1))
    s1 = hi1 - (1 << j1) + 1
    s2 = hi2 - (1 << j2) + 1
    ib_idx = jnp.concatenate([
        jnp.maximum(j1 - 1, 0) * n_pad + lo1,
        jnp.maximum(j1 - 1, 0) * n_pad + s1,
        jnp.maximum(j2 - 1, 0) * n_pad + lo2,
        jnp.maximum(j2 - 1, 0) * n_pad + s2,
    ])
    offs = jnp.where(jnp.concatenate([j1, j1, j2, j2]) == 0, 0,
                     ib_flat[ib_idx].astype(jnp.int32))
    pos_w = jnp.concatenate([lo1, s1, lo2, s2]) + offs
    cnt = bq - bp - 1
    has_mid = cnt > 0
    jm = jnp.where(has_mid, 31 - lax.clz(jnp.maximum(cnt, 1)), 0)
    jc = jnp.minimum(jm, levels - 1)
    lo_b = jnp.minimum(bp + 1, n_blocks - 1)
    hi_b = jnp.clip(bq - (1 << jc), 0, n_blocks - 1)
    pos_st = st_flat[jnp.concatenate([jc * nb_stride + lo_b,
                                      jc * nb_stride + hi_b])]
    m = p.shape[0]
    vals6 = values_flat[jnp.concatenate([pos_w, pos_st])]
    v1a, v1b = vals6[:m], vals6[m:2 * m]
    v2a, v2b = vals6[2 * m:3 * m], vals6[3 * m:4 * m]
    c3_val, c4_val = vals6[4 * m:5 * m], vals6[5 * m:]
    p1a, p1b = pos_w[:m], pos_w[m:2 * m]
    p2a, p2b = pos_w[2 * m:3 * m], pos_w[3 * m:]
    c3_pos, c4_pos = pos_st[:m], pos_st[m:]
    c1_pos = jnp.where(v1b < v1a, p1b, p1a)
    c1_val = jnp.minimum(v1a, v1b)
    c2_pos = jnp.where(v2b < v2a, p2b, p2a)
    c2_val = jnp.where(same, INF, jnp.minimum(v2a, v2b))
    c3_val = jnp.where(has_mid, c3_val, INF)
    c4_val = jnp.where(has_mid, c4_val, INF)
    p12 = jnp.where(c2_val < c1_val, c2_pos, c1_pos)
    v12 = jnp.minimum(c1_val, c2_val)
    p34 = jnp.where(c4_val < c3_val, c4_pos, c3_pos)
    v34 = jnp.minimum(c3_val, c4_val)
    pos = jnp.where(v34 < v12, p34, p12)
    val = jnp.where(invalid, INF, jnp.minimum(v12, v34))
    return pos.astype(jnp.int32), val.astype(jnp.int32)
