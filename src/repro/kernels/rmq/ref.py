"""Pure-jnp oracle for the batched two-level RMQ kernel.

Semantics: for each row b, argmin over values[p[b] .. q[b]] inclusive given
  lblock[b]: the 128-wide block containing p (pre-gathered)
  rblock[b]: the 128-wide block containing q
  st_pos/st_val: sparse table over block minima (positions are global).
Returns (pos, val); invalid ranges (p > q) give (0, INF).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

INF = 2**31 - 1
BLOCK = 128


def rmq_query_ref(pq, lblock, rblock, st_pos, st_val, n_blocks):
    def one(pq_row, lb, rb):
        p, q = pq_row[0], pq_row[1]
        bp, bq = p // BLOCK, q // BLOCK
        lane = jnp.arange(BLOCK, dtype=jnp.int32)
        same = bp == bq
        lmask = (lane >= p % BLOCK) & (lane <= jnp.where(same, q % BLOCK, BLOCK - 1))
        lvals = jnp.where(lmask, lb, INF)
        a1 = jnp.argmin(lvals)
        c1_pos, c1_val = bp * BLOCK + a1, lvals[a1]
        rmask = lane <= q % BLOCK
        rvals = jnp.where(rmask, rb, INF)
        a2 = jnp.argmin(rvals)
        c2_pos = bq * BLOCK + a2
        c2_val = jnp.where(same, INF, rvals[a2])
        cnt = bq - bp - 1
        has_mid = cnt > 0
        j = jnp.where(has_mid, 31 - lax.clz(jnp.maximum(cnt, 1)), 0)
        jc = jnp.minimum(j, st_pos.shape[0] - 1)
        lo_b = jnp.minimum(bp + 1, n_blocks - 1)
        hi_b = jnp.clip(bq - (1 << jc), 0, n_blocks - 1)
        c3_pos, c3_val = st_pos[jc, lo_b], jnp.where(has_mid, st_val[jc, lo_b], INF)
        c4_pos, c4_val = st_pos[jc, hi_b], jnp.where(has_mid, st_val[jc, hi_b], INF)
        pos = jnp.stack([c1_pos, c2_pos, c3_pos, c4_pos])
        val = jnp.stack([c1_val, c2_val, c3_val, c4_val])
        val = jnp.where(p > q, INF, val)
        best = jnp.argmin(val)
        return pos[best], val[best]

    return jax.vmap(one)(pq, lblock, rblock)
