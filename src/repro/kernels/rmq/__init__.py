from .ops import rmq_query  # noqa: F401
