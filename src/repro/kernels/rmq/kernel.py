"""Pallas TPU kernel: batched two-level range-minimum query (paper §3.2).

The VPU-native succinct-RMQ replacement (DESIGN.md §2): per query, the two
partial blocks are one 128-lane masked min each (pre-gathered to [B, 128] by
XLA — dynamic row gather is cheaper outside the kernel), and the middle
section is two overlapping sparse-table windows, gathered from a VMEM-resident
table. The batch dimension is tiled; the sparse table block is broadcast to
every grid step (index_map pins it to block 0).

VMEM: 2·bt·128·4 + 2·levels·nb·4 bytes; nb = n/128, so a 10M-docid corpus
gives levels≈17, nb≈78k -> 5.4 MiB: fits, and bigger corpora tile the table.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

INF = 2**31 - 1
BLOCK = 128


def _kernel(pq_ref, lb_ref, rb_ref, stp_ref, stv_ref, out_ref,
            *, bt, levels, n_blocks):
    lane = jax.lax.broadcasted_iota(jnp.int32, (bt, BLOCK), 1)
    p = pq_ref[:, 0][:, None]                     # [bt, 1]
    q = pq_ref[:, 1][:, None]
    bp, bq = p // BLOCK, q // BLOCK
    same = bp == bq
    # left partial block
    lmask = (lane >= p % BLOCK) & (lane <= jnp.where(same, q % BLOCK, BLOCK - 1))
    lvals = jnp.where(lmask, lb_ref[...], INF)
    a1 = jnp.argmin(lvals, axis=1)[:, None]
    c1_pos = bp * BLOCK + a1
    c1_val = jnp.take_along_axis(lvals, a1, axis=1)
    # right partial block
    rmask = lane <= q % BLOCK
    rvals = jnp.where(rmask, rb_ref[...], INF)
    a2 = jnp.argmin(rvals, axis=1)[:, None]
    c2_pos = bq * BLOCK + a2
    c2_val = jnp.where(same, INF, jnp.take_along_axis(rvals, a2, axis=1))
    # sparse-table middle
    cnt = bq - bp - 1
    has_mid = cnt > 0
    j = jnp.where(has_mid, 31 - lax.clz(jnp.maximum(cnt, 1)), 0)
    jc = jnp.minimum(j, levels - 1)               # [bt, 1]
    lo_b = jnp.minimum(bp + 1, n_blocks - 1)
    hi_b = jnp.clip(bq - (1 << jc), 0, n_blocks - 1)
    flat_lo = (jc * n_blocks + lo_b)[:, 0]
    flat_hi = (jc * n_blocks + hi_b)[:, 0]
    stp = stp_ref[...].reshape(-1)
    stv = stv_ref[...].reshape(-1)
    c3_pos = stp[flat_lo][:, None]
    c3_val = jnp.where(has_mid, stv[flat_lo][:, None], INF)
    c4_pos = stp[flat_hi][:, None]
    c4_val = jnp.where(has_mid, stv[flat_hi][:, None], INF)
    pos = jnp.concatenate([c1_pos, c2_pos, c3_pos, c4_pos], axis=1)  # [bt, 4]
    val = jnp.concatenate([c1_val, c2_val, c3_val, c4_val], axis=1)
    val = jnp.where(p > q, INF, val)
    best = jnp.argmin(val, axis=1)[:, None]
    out_ref[:, 0] = jnp.take_along_axis(pos, best, axis=1)[:, 0]
    out_ref[:, 1] = jnp.take_along_axis(val, best, axis=1)[:, 0]


def rmq_query_kernel(pq, lblock, rblock, st_pos, st_val, *, block_b: int = 128,
                     interpret: bool = True):
    B = pq.shape[0]
    levels, n_blocks = st_pos.shape
    bt = min(block_b, B)
    assert B % bt == 0
    kernel = functools.partial(_kernel, bt=bt, levels=levels, n_blocks=n_blocks)
    return pl.pallas_call(
        kernel,
        grid=(B // bt,),
        in_specs=[
            pl.BlockSpec((bt, 2), lambda i: (i, 0)),
            pl.BlockSpec((bt, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((bt, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((levels, n_blocks), lambda i: (0, 0)),
            pl.BlockSpec((levels, n_blocks), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 2), jnp.int32),
        interpret=interpret,
    )(pq, lblock, rblock, st_pos, st_val)
