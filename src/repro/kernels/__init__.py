"""Pallas TPU kernels for the perf-critical hot spots.

Each subpackage ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public wrapper choosing kernel vs XLA fallback) and ref.py (pure-jnp oracle).
On this CPU container kernels are validated with interpret=True; on TPU the
same BlockSpecs compile natively.
"""
