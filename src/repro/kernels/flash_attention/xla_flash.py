"""Scan-based online-softmax attention in plain XLA (no Pallas).

This is the memory-correct fallback for platforms where the Pallas kernel
cannot lower (the CPU dry-run) and the tail for very long sequences: a
lax.scan over KV blocks with the FlashAttention-2 running-max recurrence.
Peak memory is O(B·H·Sq·D + block·D) instead of O(Sq·Skv); each scan body is
jax.checkpoint'ed so the backward pass recomputes the [Sq, block] score tile
rather than saving it.

Under GSPMD this composes with head/batch sharding (the scan is local); do
NOT shard the KV sequence axis through this path — that is what the decode
(split-KV) route is for.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

NEG = -1e30


def xla_flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                        kv_len=None, sm_scale=None, block_k: int = 1024):
    B, H, Sq, D = q.shape
    _, G, Skv, _ = k.shape
    rep = H // G
    scale = sm_scale if sm_scale is not None else D ** -0.5
    bk = min(block_k, Skv)
    pad = (-Skv) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nk = (Skv + pad) // bk
    qg = q.reshape(B, G, rep, Sq, D).astype(jnp.float32) * scale
    # [nk, B, G, bk, D] scan layout
    ks = k.reshape(B, G, nk, bk, D).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(B, G, nk, bk, D).transpose(2, 0, 1, 3, 4)
    row = jnp.arange(Sq, dtype=jnp.int32) + (Skv - Sq)          # causal offset
    if kv_len is None:
        klen = jnp.full((B,), Skv, jnp.int32)
    else:
        klen = kv_len.astype(jnp.int32)

    def body(carry, blk):
        m, l, acc, kb = carry[0], carry[1], carry[2], carry[3]
        kblk, vblk = blk
        s = jnp.einsum("bgrqd,bgkd->bgrqk", qg, kblk.astype(jnp.float32))
        if softcap and softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        col = kb * bk + jnp.arange(bk, dtype=jnp.int32)          # [bk]
        mask = jnp.ones((Sq, bk), bool)
        if causal:
            mask &= col[None, :] <= row[:, None]
        if window and window > 0:
            mask &= col[None, :] > row[:, None] - window
        mask = mask[None, None, None] & (col[None, None, None, None, :]
                                         < klen[:, None, None, None, None])
        s = jnp.where(mask, s, NEG)
        m_new = jnp.maximum(m, s.max(-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bgrqk,bgkd->bgrqd", p,
                                       vblk.astype(jnp.float32))
        return (m_new, l, acc, kb + 1), None

    m0 = jnp.full((B, G, rep, Sq, 1), NEG, jnp.float32)
    l0 = jnp.zeros((B, G, rep, Sq, 1), jnp.float32)
    a0 = jnp.zeros((B, G, rep, Sq, D), jnp.float32)
    (m, l, acc, _), _ = lax.scan(jax.checkpoint(body),
                                 (m0, l0, a0, jnp.int32(0)), (ks, vs))
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(B, H, Sq, D).astype(q.dtype)
