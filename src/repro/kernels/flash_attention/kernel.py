"""Pallas TPU flash attention (FlashAttention-2 schedule, TPU tiling).

One kernel covers every assigned LM arch: causal, sliding-window (gemma2
local layers), attention-logit softcap (gemma2), GQA head grouping (all),
and per-batch kv-length masking (decode with a partially filled cache).

Grid: (B, H, nq, nk), nk innermost with "arbitrary" semantics; (acc, m, l)
live in VMEM scratch and persist across the nk loop. Blocks:
  q   (1, 1, bq, D)   index (b, h, iq, 0)
  k,v (1, 1, bk, D)   index (b, h // rep, ik, 0)     <- GQA: kv block reused
  out (1, 1, bq, D)   index (b, h, iq, 0)            by rep consecutive heads
MXU alignment: bq, bk multiples of 128; D = head_dim (64/128/256).
VMEM: (bq + 2*bk + 2*bq)·D·4B + bq·bk·4B ≈ 0.6 MiB at bq=bk=128, D=128.

Out-of-band blocks (fully masked by causality/window) are skipped with
pl.when — on TPU the DMA for the block still occurs but no FLOPs; the ops.py
wrapper additionally shrinks the grid for the pure-causal case.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...compat import tpu_compiler_params

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, klen_ref, o_ref, acc, m_sc, l_sc,
            *, scale, causal, window, softcap, nk, bq, bk, sq, skv, use_klen):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)

    iq = pl.program_id(2)
    off = skv - sq                                  # causal offset (decode)
    row = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + off
    col = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # block-level skip: is any (row, col) pair in this tile live?
    blk_row_max = iq * bq + bq - 1 + off
    blk_row_min = iq * bq + off
    blk_col_min = ik * bk
    blk_col_max = ik * bk + bk - 1
    live = jnp.bool_(True)
    if causal:
        live &= blk_col_min <= blk_row_max
    if window > 0:
        live &= blk_col_max > blk_row_min - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)          # [bk, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= col <= row
        if window > 0:
            mask &= col > row - window
        if use_klen:
            mask &= col < klen_ref[0]
        mask &= row < skv                            # query padding rows
        s = jnp.where(mask, s, NEG)
        m_prev = m_sc[...]                           # [bq, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)              # [bq, 1]
        l_sc[...] = l_sc[...] * alpha + p.sum(axis=1, keepdims=True)
        acc[...] = acc[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc[...] / denom).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, kv_len=None, *, causal=True, window=0,
                           softcap=0.0, sm_scale=None, block_q=128,
                           block_k=128, interpret=True):
    B, H, Sq, D = q.shape
    _, G, Skv, _ = k.shape
    rep = H // G
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    nq, nk = Sq // bq, Skv // bk
    scale = sm_scale if sm_scale is not None else D ** -0.5
    use_klen = kv_len is not None
    if kv_len is None:
        kv_len = jnp.full((B,), Skv, jnp.int32)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        nk=nk, bq=bq, bk=bk, sq=Sq, skv=Skv, use_klen=use_klen)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // rep, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // rep, ik, 0)),
            pl.BlockSpec((1,), lambda b, h, iq, ik: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v, kv_len)
