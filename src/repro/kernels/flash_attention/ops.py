"""Public attention ops: flash_attention (train/prefill) and flash_decode.

``use_kernel=False`` (the CPU/dry-run default set by model configs) routes to
the XLA reference; on TPU the Pallas path compiles natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_kernel
from .ref import flash_attention_ref
from .xla_flash import xla_flash_attention

# above this many score elements the XLA fallback uses the scan-based
# online-softmax path (O(S·D) memory) instead of materialized scores
_XLA_FLASH_THRESHOLD = 2048 * 2048


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "sm_scale", "use_kernel", "interpret",
    "block_q", "block_k"))
def flash_attention(q, k, v, kv_len=None, *, causal=True, window=0,
                    softcap=0.0, sm_scale=None, use_kernel=False,
                    interpret=True, block_q=128, block_k=128):
    """q [B,H,Sq,D] x k,v [B,G,Skv,D] -> [B,H,Sq,D]."""
    if not use_kernel:
        if q.shape[2] * k.shape[2] >= _XLA_FLASH_THRESHOLD:
            return xla_flash_attention(q, k, v, causal=causal, window=window,
                                       softcap=softcap, kv_len=kv_len,
                                       sm_scale=sm_scale)
        return flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap, kv_len=kv_len,
                                   sm_scale=sm_scale)
    return flash_attention_kernel(q, k, v, kv_len, causal=causal,
                                  window=window, softcap=softcap,
                                  sm_scale=sm_scale, block_q=block_q,
                                  block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "window", "softcap", "sm_scale", "use_kernel", "interpret", "block_k"))
def flash_decode(q, k, v, kv_len, *, window=0, softcap=0.0, sm_scale=None,
                 use_kernel=False, interpret=True, block_k=512):
    """Single-token decode: q [B,H,D] x cache k,v [B,G,Skv,D] -> [B,H,D].

    Implemented as Sq=8-padded flash attention (TPU sublane alignment) with
    kv-length masking; only the last query row is real.
    """
    B, H, D = q.shape
    qq = jnp.zeros((B, H, 8, D), q.dtype).at[:, :, -1, :].set(q)
    out = flash_attention(qq, k, v, kv_len, causal=True, window=window,
                          softcap=softcap, sm_scale=sm_scale,
                          use_kernel=use_kernel, interpret=interpret,
                          block_q=8, block_k=block_k)
    return out[:, :, -1, :]
