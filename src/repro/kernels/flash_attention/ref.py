"""Pure-jnp oracle for flash attention (all LM-arch variants).

Semantics shared with the kernel:
  q: [B, H, Sq, D]; k,v: [B, G, Skv, D] with H = G * rep (GQA)
  causal: offset-aware — query row i attends to kv col j iff
          j <= i + (Skv - Sq) (so decode with Sq=1 sees the whole cache)
  window: if w > 0, additionally j > i + (Skv - Sq) - w   (sliding window)
  softcap: if c > 0, scores = c * tanh(scores / c)         (gemma2)
  kv_len: [B] valid kv length per batch row (cols >= kv_len are masked)
"""
from __future__ import annotations

import jax.numpy as jnp

NEG = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0,
                        kv_len=None, sm_scale=None):
    B, H, Sq, D = q.shape
    G = k.shape[1]
    rep = H // G
    scale = sm_scale if sm_scale is not None else D ** -0.5
    kk = jnp.repeat(k, rep, axis=1)
    vv = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    if softcap and softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    Skv = k.shape[2]
    row = jnp.arange(Sq)[:, None] + (Skv - Sq)
    col = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= col <= row
    if window and window > 0:
        mask &= col > row - window
    m = mask[None, None]
    if kv_len is not None:
        m = m & (col[None, None] < kv_len[:, None, None, None])
    s = jnp.where(m, s, NEG)
    w = jnp.exp(s - s.max(axis=-1, keepdims=True))
    w = jnp.where(m, w, 0.0)
    denom = jnp.maximum(w.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bhkd->bhqd", w / denom, vv.astype(jnp.float32))
    return out.astype(q.dtype)
