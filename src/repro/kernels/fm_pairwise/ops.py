"""Public jit'd wrapper for the FM pairwise interaction."""
from __future__ import annotations

import functools

import jax

from .kernel import fm_pairwise_kernel
from .ref import fm_pairwise_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def fm_pairwise(emb, *, use_kernel: bool = False, interpret: bool = True):
    """emb float[B, F, D] -> float32[B] second-order FM term."""
    if not use_kernel:
        return fm_pairwise_ref(emb)
    return fm_pairwise_kernel(emb, interpret=interpret)
