from .ops import fm_pairwise  # noqa: F401
