"""Pure-jnp oracle for the FM pairwise-interaction kernel.

FM second-order term (Rendle, ICDM'10) with the O(nk) sum-square identity:
   sum_{i<j} <v_i, v_j> = 0.5 * sum_d [ (sum_f v_fd)^2 - sum_f v_fd^2 ]
emb: float[B, F, D] (per-sample field embeddings, x-weighted) -> float[B].
"""
from __future__ import annotations

import jax.numpy as jnp


def fm_pairwise_ref(emb):
    e = emb.astype(jnp.float32)
    s = e.sum(axis=1)                 # [B, D]
    sq = (e * e).sum(axis=1)          # [B, D]
    return 0.5 * (s * s - sq).sum(axis=1)
