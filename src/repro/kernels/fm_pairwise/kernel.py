"""Pallas TPU kernel: fused FM pairwise interaction (sum-square trick).

The recsys serving hot op after the embedding gather: one pass over the
[bt, F, D] tile fuses both reductions — no [B, D] intermediates in HBM.
Grid tiles the batch; F and D stay whole (F <= 64, D <= 128 for all assigned
recsys archs, so a (bt=256, F, D) tile is bt·F·D·4 ≈ 4 MiB at the maximum).
Output block is (bt, 128) with the scalar broadcast into lane 0 — keeping the
store lane-aligned; ops.py slices lane 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(emb_ref, out_ref):
    e = emb_ref[...].astype(jnp.float32)          # [bt, F, D]
    s = e.sum(axis=1)                             # [bt, D]
    sq = (e * e).sum(axis=1)
    r = 0.5 * (s * s - sq).sum(axis=1)            # [bt]
    out_ref[...] = jnp.broadcast_to(r[:, None], out_ref.shape)


def fm_pairwise_kernel(emb, *, block_b: int = 256, interpret: bool = True):
    B, F, D = emb.shape
    bt = min(block_b, B)
    assert B % bt == 0
    return pl.pallas_call(
        _kernel,
        grid=(B // bt,),
        in_specs=[pl.BlockSpec((bt, F, D), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bt, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 128), jnp.float32),
        interpret=interpret,
    )(emb)[:, 0]
