from .ops import heap_topk  # noqa: F401
