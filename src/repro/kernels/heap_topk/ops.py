"""Public jit'd wrapper: the whole bounded-trip single-term engine.

``heap_topk`` runs all ``trips`` heap pops of the paper's §3.3 single-term
engine in ONE dispatch: either the Pallas kernel (heap state in VMEM scratch,
in-kernel RMQ + iterator gathers — zero HBM heap traffic) or the XLA batched
reference (ref.py, the PR-2 per-pop batched-RMQ formulation). The two are
bit-identical in ``out`` and ``done``; ``core.search`` routes between them
and the per-pop batched-RMQ path (ROADMAP kernel-routing policy).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...compat import pallas_interpret_default
from .kernel import heap_topk_kernel, BLOCK
from .ref import heap_topk_ref


def _pad_lanes(a, mult=BLOCK, fill=0):
    """Pad a 1-D array to a lane multiple (VMEM-friendly 2-D reshape)."""
    pad = (-a.shape[0]) % mult
    if pad:
        a = jnp.pad(a, (0, pad), constant_values=fill)
    return a.reshape(1, -1)


@functools.partial(jax.jit, static_argnames=("k", "trips", "n", "n_terms",
                                             "use_kernel", "interpret",
                                             "block_b"))
def heap_topk(values, st_pos, ib, offsets, postings, term_lo, term_hi, *,
              k: int, trips: int, n: int, n_terms: int,
              use_kernel: bool = True, interpret: bool | None = None,
              block_b: int = 128, packed=None):
    """Bounded-trip single-term top-k -> (out int32[B, k], done bool[B]).

    values/st_pos/ib: the ``RangeMin`` arrays over the ``minimal`` array
    (``n`` its true length); offsets/postings: the inverted index; term
    ranges [term_lo, term_hi) per lane. ``done`` is True iff k docids were
    emitted or the heap is exhausted — the caller ORs in its bad-range and
    full-budget conditions. ``interpret=None`` resolves platform-aware.

    ``packed`` (``codecs.PackedPostings``, a pytree arg whose n_post/codec
    metadata are static) selects the compressed route: the kernel keeps the
    word stream + block directory in VMEM instead of raw postings and
    decodes per gather (its ref fallback decodes identically) —
    bit-identical to the raw route for any index where
    ``unpack_postings(packed) == postings``.
    """
    if interpret is None:
        interpret = pallas_interpret_default()
    if not use_kernel or n == 0:
        return heap_topk_ref(values, st_pos, ib, offsets, postings,
                             term_lo, term_hi, k=k, trips=trips, n=n,
                             n_terms=n_terms, packed=packed)
    B = term_lo.shape[0]
    n_post = postings.shape[0] if packed is None else packed.n_post
    bt = min(block_b, B)
    pad = (-B) % bt
    tl = term_lo.astype(jnp.int32)
    hi_incl = term_hi.astype(jnp.int32) - 1
    if pad:  # dead lanes: empty range -> INF out, done immediately
        tl = jnp.pad(tl, (0, pad), constant_values=1)
        hi_incl = jnp.pad(hi_incl, (0, pad), constant_values=-1)
    tlh = jnp.stack([tl, hi_incl], axis=1)
    levels, nb = st_pos.shape
    st_p = st_pos
    if nb % BLOCK:  # lane-pad columns; flat gathers use the padded stride
        st_p = jnp.pad(st_pos, ((0, 0), (0, (-nb) % BLOCK)))
    if packed is None:
        post_in = _pad_lanes(postings, fill=2**31 - 1)
        pk_in, pk_ef = None, False
    else:
        # zero pads are dead: lookups clamp the block id to the real NB
        post_in = None
        pk_in = (_pad_lanes(packed.words), _pad_lanes(packed.base),
                 _pad_lanes(packed.meta), _pad_lanes(packed.wordoff))
        pk_ef = packed.has_ef
    out, done = heap_topk_kernel(
        tlh,
        values.reshape(1, -1),
        st_p,
        ib.astype(jnp.int32),
        _pad_lanes(offsets),
        post_in,
        k=k, trips=trips, n=n, n_terms=n_terms, n_post=n_post,
        block_b=bt, interpret=interpret, packed=pk_in, packed_ef=pk_ef)
    return out[:B], done[:B, 0].astype(jnp.bool_)
