"""Pure-jnp oracle for the on-chip single-term top-k kernel.

The PR-2 batched formulation of the bounded-trip single-term engine
(paper §3.3), expressed directly on the raw index/RMQ arrays: each trip pops
the per-lane min slot, issues one batched RMQ over the 2B split subranges
(the two-overlapping-window ``ib`` formulation of ``RangeMin.query_batch``),
and gathers the offsets/postings iterator state. This is the ONE copy of
the engine loop: the kernel's parity oracle, the off-TPU path of
``ops.heap_topk``, AND (via the ``rmq_fn`` hook, which lets
``core.search`` route each pop's RMQ through the batched-RMQ Pallas
kernel) the body behind ``single_term_topk_bounded_batch``'s non-fused
routes.

Semantics: term ranges [term_lo, term_hi) per lane; returns
(out int32[B, k] ascending INF-padded, done bool[B]) where ``done`` is True
iff k docids were emitted or the heap is exhausted (the caller ORs in its
``bad``-range and full-budget conditions).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from ...core.codecs import packed_lookup
from ..rmq.ref import rmq_window_batch  # noqa: F401  (re-export: kernel.py)

INF = 2**31 - 1


def _rmq_batch_ref(values, ib, st_pos, n, p, q):
    levels, n_blocks = st_pos.shape
    return rmq_window_batch(values, ib.reshape(-1), st_pos.reshape(-1), p, q,
                            n=n, levels=levels, n_blocks=n_blocks,
                            nb_stride=n_blocks, n_pad=values.shape[0])


def heap_topk_ref(values, st_pos, ib, offsets, postings, term_lo, term_hi,
                  *, k: int, trips: int, n: int, n_terms: int, rmq_fn=None,
                  packed=None):
    """The batched bounded-trip engine on raw arrays -> (out, done).

    ``rmq_fn(p, q) -> (pos, val)`` overrides the split-subrange RMQ (same
    contract as ``RangeMin.query_batch``); None uses the in-module XLA
    window formulation. ``packed`` (a ``codecs.PackedPostings``) swaps the
    raw postings gathers for ``codecs.packed_lookup`` decode — the XLA
    formulation of the compressed kernel route, bit-identical to raw
    because ``packed_lookup(ptr) == postings[min(ptr, n_post-1)]``.
    """
    if rmq_fn is None:
        rmq_fn = lambda p, q: _rmq_batch_ref(values, ib, st_pos, n, p, q)
    if packed is not None:
        lookup = lambda ptrs: packed_lookup(
            packed.words, packed.base, packed.meta, packed.wordoff, ptrs,
            n_post=packed.n_post, ef=packed.has_ef)
    else:
        lookup = lambda ptrs: postings[
            jnp.minimum(ptrs, postings.shape[0] - 1)]
    B = term_lo.shape[0]
    rows = jnp.arange(B)
    cap = 2 * trips + 1
    hi_incl = term_hi - 1
    pos0, val0 = rmq_fn(term_lo, hi_incl)
    kind = jnp.zeros((B, cap), jnp.int32)
    lo_a = jnp.zeros((B, cap), jnp.int32).at[:, 0].set(term_lo)
    hi_a = jnp.full((B, cap), -1, jnp.int32).at[:, 0].set(hi_incl)
    pos_a = jnp.zeros((B, cap), jnp.int32).at[:, 0].set(pos0)
    val_a = jnp.full((B, cap), INF, jnp.int32).at[:, 0].set(
        jnp.where(term_lo <= hi_incl, val0, INF))
    out = jnp.full((B, k), INF, jnp.int32)
    n_out = jnp.zeros((B,), jnp.int32)
    prev = jnp.full((B,), -1, jnp.int32)

    def body(i, state):
        kind, lo_a, hi_a, pos_a, val_a, out, n_out, prev = state
        nf = 1 + 2 * i
        best = jnp.argmin(val_a, axis=1)
        bval = val_a[rows, best]
        found = bval < INF
        is_range = kind[rows, best] == 0
        emit = found & (bval != prev)
        out = out.at[rows, jnp.where(emit, n_out, k)].set(bval, mode="drop")
        n_out = n_out + emit.astype(jnp.int32)
        prev = jnp.where(found, bval, prev)
        tstar = pos_a[rows, best]
        lo = lo_a[rows, best]
        hi = hi_a[rows, best]
        pos2, val2 = rmq_fn(jnp.concatenate([lo, tstar + 1]),
                            jnp.concatenate([tstar - 1, hi]))
        lpos, rpos = pos2[:B], pos2[B:]
        lval = jnp.where((lo <= tstar - 1) & found & is_range,
                         val2[:B], INF)
        rval = jnp.where((tstar + 1 <= hi) & found & is_range,
                         val2[B:], INF)
        ct = jnp.clip(tstar, 0, n_terms)
        cl = jnp.clip(lo, 0, n_terms)
        offs = offsets[jnp.concatenate([ct, ct + 1, cl + 1])]
        it_start, it_end, adv_end = offs[:B], offs[B:2 * B], offs[2 * B:]
        it_ptr = it_start + 1
        adv_ptr = tstar + 1
        pv = lookup(jnp.concatenate([it_ptr, adv_ptr]))
        it_val = jnp.where((it_ptr < it_end) & found & is_range,
                           pv[:B], INF)
        adv_val = jnp.where((adv_ptr < adv_end) & found & (~is_range),
                            pv[B:], INF)
        kind = kind.at[rows, best].set(jnp.where(is_range, 0, 1))
        lo_a = lo_a.at[rows, best].set(lo)
        hi_a = hi_a.at[rows, best].set(jnp.where(is_range, tstar - 1, hi))
        pos_a = pos_a.at[rows, best].set(jnp.where(is_range, lpos, adv_ptr))
        val_a = val_a.at[rows, best].set(jnp.where(is_range, lval, adv_val))
        live = found & is_range
        kind = kind.at[:, nf].set(0)
        lo_a = lo_a.at[:, nf].set(tstar + 1)
        hi_a = hi_a.at[:, nf].set(hi)
        pos_a = pos_a.at[:, nf].set(rpos)
        val_a = val_a.at[:, nf].set(jnp.where(live, rval, INF))
        kind = kind.at[:, nf + 1].set(1)
        lo_a = lo_a.at[:, nf + 1].set(tstar)
        hi_a = hi_a.at[:, nf + 1].set(-1)
        pos_a = pos_a.at[:, nf + 1].set(it_ptr)
        val_a = val_a.at[:, nf + 1].set(jnp.where(live, it_val, INF))
        return kind, lo_a, hi_a, pos_a, val_a, out, n_out, prev

    state = (kind, lo_a, hi_a, pos_a, val_a, out, n_out, prev)
    state = lax.fori_loop(0, trips, body, state)
    val_a, out, n_out = state[4], state[5], state[6]
    done = (n_out >= k) | (jnp.min(val_a, axis=1) >= INF)
    return out, done
