"""Pallas TPU kernel: the entire bounded-trip single-term engine (paper §3.3).

One launch runs ALL ``trips`` heap pops of the single-term top-k engine for a
tile of batch lanes, with the dense-slot heap arrays (kind/lo/hi/pos/val,
``int32[bt, cap]``) living in VMEM scratch for the whole loop. Each trip fuses

  * the pop (per-lane argmin over the cap slots),
  * BOTH split-subrange RMQs — reading the sparse table and the ``ib``
    in-block window table directly from VMEM (the same two-overlapping-window
    formulation as ``RangeMin.query_batch``),
  * the offsets/postings gathers that instantiate or advance the lazy
    posting-list iterators.

Under the PR-2 formulation every pop round-tripped the full [B, cap] heap
state (5 int32 arrays) through HBM and issued a separate batched-RMQ
dispatch: 2·trips fusion boundaries per batch. Here the heap state never
leaves the core and there is exactly ONE kernel launch.

Grid: one program per bt-lane tile. The RMQ/index source arrays (values,
sparse table, ``ib`` windows, offsets, postings) are pinned to block 0 so
every grid step reuses the same VMEM-resident copy. VMEM budget is the sum
of those arrays plus 5·bt·cap·4 bytes of heap scratch; the caller
(``core.search._heap_kernel_fits``) verifies the static fit before routing
here — corpora whose tables exceed the budget keep the batched-RMQ path.

Blocks (per program):
  tlh      (bt, 2)          term_lo, hi_incl (= term_hi - 1) per lane
  values   (1, n_pad)       RangeMin values (INF padded, 128-aligned)
  st_pos   (levels, nb_pad) sparse-table argmin positions (row-padded)
  ib       (IB_LEVELS, n_pad) in-block window argmin offsets (int32)
  offsets  (1, v_pad)       inverted-index list boundaries
  postings (1, p_pad)       concatenated docid lists (INF padded) — raw route
  … or the compressed directory (ISSUE 7), replacing ``postings``:
  pwords   (1, w_pad)       PackedPostings.words   (int32 payload stream)
  pbase    (1, nb2_pad)     PackedPostings.base
  pmeta    (1, nb2_pad)     PackedPostings.meta    (width | is_ef<<6)
  pwoff    (1, nb2_pad)     PackedPostings.wordoff
  out      (bt, k)          emitted docids, ascending, INF padded
  done     (bt, 1)          1 iff k emitted or heap exhausted

The compressed route swaps the two postings gathers per trip for
``codecs.packed_lookup`` — block-directory lookup + shift/mask unpack (and
bitmap-select for EF blocks) on the VMEM-resident word stream. Same
function body as the XLA reference, so the route stays bit-identical; what
it buys is the VMEM-fit gate now counting compressed bytes
(``core.search._heap_kernel_fits``), enlarging the kernel-eligible corpus.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.codecs import packed_lookup
from .ref import rmq_window_batch

INF = 2**31 - 1
BLOCK = 128


def _kernel(tlh_ref, values_ref, st_ref, ib_ref, off_ref, *rest,
            k, trips, n, levels, n_blocks, n_terms, n_post, packed_ef):
    if packed_ef is None:
        (post_ref, out_ref, done_ref,
         kind_s, lo_s, hi_s, pos_s, val_s) = rest
        postings = post_ref[...].reshape(-1)
        lookup = lambda ptrs: postings[jnp.minimum(ptrs, n_post - 1)]
    else:
        (pw_ref, pb_ref, pm_ref, po_ref, out_ref, done_ref,
         kind_s, lo_s, hi_s, pos_s, val_s) = rest
        lookup = functools.partial(
            packed_lookup, pw_ref[...].reshape(-1), pb_ref[...].reshape(-1),
            pm_ref[...].reshape(-1), po_ref[...].reshape(-1),
            n_post=n_post, ef=packed_ef)
    bt, cap = kind_s.shape
    n_pad = values_ref.shape[1]
    nb_pad = st_ref.shape[1]
    values = values_ref[...].reshape(-1)
    ib_flat = ib_ref[...].reshape(-1)
    st_flat = st_ref[...].reshape(-1)
    offsets = off_ref[...].reshape(-1)
    rmq = functools.partial(rmq_window_batch, values, ib_flat, st_flat,
                            n=n, levels=levels, n_blocks=n_blocks,
                            nb_stride=nb_pad, n_pad=n_pad)
    col = lax.broadcasted_iota(jnp.int32, (bt, cap), 1)
    kcol = lax.broadcasted_iota(jnp.int32, (bt, k), 1)

    # ---- initial heap: one live range slot [term_lo, hi_incl] per lane ----
    tl = tlh_ref[:, 0]
    hi_incl = tlh_ref[:, 1]
    pos0, val0 = rmq(tl, hi_incl)
    first = col == 0
    kind_s[...] = jnp.zeros((bt, cap), jnp.int32)
    lo_s[...] = jnp.where(first, tl[:, None], 0)
    hi_s[...] = jnp.where(first, hi_incl[:, None], -1)
    pos_s[...] = jnp.where(first, pos0[:, None], 0)
    val_s[...] = jnp.where(
        first, jnp.where(tl <= hi_incl, val0, INF)[:, None], INF)

    def trip(i, carry):
        out, n_out, prev = carry
        kind = kind_s[...]
        lo_a = lo_s[...]
        hi_a = hi_s[...]
        pos_a = pos_s[...]
        val_a = val_s[...]
        nf = 1 + 2 * i                       # next free slot (data-independent)
        best = jnp.argmin(val_a, axis=1)[:, None]                 # [bt, 1]
        bval = jnp.take_along_axis(val_a, best, axis=1)[:, 0]
        found = bval < INF
        is_range = jnp.take_along_axis(kind, best, axis=1)[:, 0] == 0
        # ---- emit (dedup against previous emission) ----
        emit = found & (bval != prev)
        out = jnp.where((kcol == n_out[:, None]) & emit[:, None],
                        bval[:, None], out)
        n_out = n_out + emit.astype(jnp.int32)
        prev = jnp.where(found, bval, prev)
        # ---- both split-subrange RMQs, fused (one [2bt] call) ----
        tstar = jnp.take_along_axis(pos_a, best, axis=1)[:, 0]
        lo = jnp.take_along_axis(lo_a, best, axis=1)[:, 0]
        hi = jnp.take_along_axis(hi_a, best, axis=1)[:, 0]
        pos2, val2 = rmq(jnp.concatenate([lo, tstar + 1]),
                         jnp.concatenate([tstar - 1, hi]))
        lpos, rpos = pos2[:bt], pos2[bt:]
        lval = jnp.where((lo <= tstar - 1) & found & is_range,
                         val2[:bt], INF)
        rval = jnp.where((tstar + 1 <= hi) & found & is_range,
                         val2[bt:], INF)
        # ---- offsets gather: new iterator bounds + advance bound ----
        # offsets has n_terms+2 entries (lane-padded further by ops.py), so
        # the clipped ct+1 / cl+1 indices stay in bounds
        ct = jnp.clip(tstar, 0, n_terms)
        cl = jnp.clip(lo, 0, n_terms)        # iterator slots keep term in lo
        offs3 = offsets[jnp.concatenate([ct, ct + 1, cl + 1])]
        it_start, it_end, adv_end = offs3[:bt], offs3[bt:2 * bt], offs3[2 * bt:]
        it_ptr = it_start + 1                # minimal was postings[start]
        adv_ptr = tstar + 1                  # iterator pop: ptr + 1
        # ---- postings gather/decode: instantiated + advanced iterators ----
        pv = lookup(jnp.concatenate([it_ptr, adv_ptr]))
        it_val = jnp.where((it_ptr < it_end) & found & is_range,
                           pv[:bt], INF)
        adv_val = jnp.where((adv_ptr < adv_end) & found & (~is_range),
                            pv[bt:], INF)
        # ---- write popped slot (masked column scatter) ----
        bm = col == best
        kind = jnp.where(bm, jnp.where(is_range, 0, 1)[:, None], kind)
        lo_a = jnp.where(bm, lo[:, None], lo_a)
        hi_a = jnp.where(bm, jnp.where(is_range, tstar - 1, hi)[:, None], hi_a)
        pos_a = jnp.where(bm, jnp.where(is_range, lpos, adv_ptr)[:, None],
                          pos_a)
        val_a = jnp.where(bm, jnp.where(is_range, lval, adv_val)[:, None],
                          val_a)
        # ---- two fresh slots (static columns; live only after a range pop) --
        live = found & is_range
        fm1 = col == nf
        kind = jnp.where(fm1, 0, kind)
        lo_a = jnp.where(fm1, (tstar + 1)[:, None], lo_a)
        hi_a = jnp.where(fm1, hi[:, None], hi_a)
        pos_a = jnp.where(fm1, rpos[:, None], pos_a)
        val_a = jnp.where(fm1, jnp.where(live, rval, INF)[:, None], val_a)
        fm2 = col == nf + 1
        kind = jnp.where(fm2, 1, kind)
        lo_a = jnp.where(fm2, tstar[:, None], lo_a)  # iterator: term id here
        hi_a = jnp.where(fm2, -1, hi_a)
        pos_a = jnp.where(fm2, it_ptr[:, None], pos_a)
        val_a = jnp.where(fm2, jnp.where(live, it_val, INF)[:, None], val_a)
        kind_s[...] = kind
        lo_s[...] = lo_a
        hi_s[...] = hi_a
        pos_s[...] = pos_a
        val_s[...] = val_a
        return out, n_out, prev

    out0 = jnp.full((bt, k), INF, jnp.int32)
    z = jnp.zeros((bt,), jnp.int32)
    out, n_out, _ = lax.fori_loop(0, trips, trip, (out0, z, z - 1))
    out_ref[...] = out
    done_ref[:, 0] = ((n_out >= k)
                      | (jnp.min(val_s[...], axis=1) >= INF)).astype(jnp.int32)


def heap_topk_kernel(tlh, values, st_pos, ib, offsets, postings, *,
                     k: int, trips: int, n: int, n_terms: int, n_post: int,
                     block_b: int = 128, interpret: bool | None = None,
                     packed: tuple | None = None,
                     packed_ef: bool = False):
    """tlh int32[B, 2] = (term_lo, term_hi - 1); the index/RMQ arrays are
    2-D, 128-lane padded (see ops.py). Returns (out int32[B, k],
    done int32[B, 1]). ``interpret=None`` resolves platform-aware (real
    lowering on TPU, interpreter elsewhere).

    ``packed`` = (words, base, meta, wordoff) — all 2-D lane-padded —
    replaces the raw ``postings`` input with the compressed directory
    (``postings`` is then ignored); ``packed_ef`` is the static
    ``PackedPostings.has_ef`` flag (skips bitmap-select when False)."""
    if interpret is None:
        from ...compat import pallas_interpret_default

        interpret = pallas_interpret_default()
    B = tlh.shape[0]
    levels, nb_pad = st_pos.shape
    n_pad = values.shape[1]
    bt = min(block_b, B)
    assert B % bt == 0
    cap = 2 * trips + 1
    n_blocks = n_pad // BLOCK
    if packed is None:
        post_in = [postings]
        pe = None
    else:
        post_in = list(packed)
        pe = bool(packed_ef)
    kernel = functools.partial(_kernel, k=k, trips=trips, n=n, levels=levels,
                               n_blocks=n_blocks, n_terms=n_terms,
                               n_post=n_post, packed_ef=pe)
    return pl.pallas_call(
        kernel,
        grid=(B // bt,),
        in_specs=[
            pl.BlockSpec((bt, 2), lambda i: (i, 0)),
            pl.BlockSpec((1, n_pad), lambda i: (0, 0)),
            pl.BlockSpec((levels, nb_pad), lambda i: (0, 0)),
            pl.BlockSpec(ib.shape, lambda i: (0, 0)),
            pl.BlockSpec(offsets.shape, lambda i: (0, 0)),
        ] + [pl.BlockSpec(p.shape, lambda i: (0, 0)) for p in post_in],
        out_specs=[
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((bt, cap), jnp.int32) for _ in range(5)],
        interpret=interpret,
    )(tlh, values, st_pos, ib, offsets, *post_in)
