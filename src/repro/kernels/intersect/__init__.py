from .ops import conjunctive_scan  # noqa: F401
