"""Pallas TPU kernel: fused list-intersection + forward-range check.

TPU adaptation of the paper's conjunctive inner loop (DESIGN.md §7): instead
of NextGeq iterator merging (serial), every candidate lane runs a branchless
binary search against each probe list held in VMEM. The forward-index range
test (Fig 5 line 6) is fused so a candidate tile makes exactly one trip
through VMEM.

Grid: one program per batch row. Blocks (per program):
  cands    (1, T)        VMEM   T = candidate tile (lane-aligned, 128|T)
  lists    (1, P, L)     VMEM   P probe lists, padded length L (power of two)
  lens     (1, P)        VMEM
  fwd_rows (1, T, M)     VMEM
  bounds   (1, 2)        VMEM   [term_lo, term_hi)
  out      (1, T)        VMEM   int32 0/1 mask

VMEM budget: T*4 + P*L*4 + T*M*4 bytes; with T=256, P=7, L=8192, M=8 that is
~242 KiB — well inside the ~16 MiB/core VMEM of v5e.

The packed variant (ISSUE 7) replaces the per-row (1, P, L) probe-list
gather with the WHOLE compressed postings index pinned to grid block 0
(words + block directory, ``codecs.PackedPostings``): each lane
binary-searches its [start, end) span directly in the compressed stream,
decoding probes with ``codecs.packed_lookup``. No per-tile HBM gather of
probe lists, no ``list_pad`` truncation — the fit condition becomes the
packed index bytes instead of P·L, which is what lets long-tail lists
(the ones ``list_pad`` would have excluded) take the kernel route.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.codecs import packed_lookup

INF = 2**31 - 1


def _kernel(cands_ref, lists_ref, lens_ref, fwd_ref, bounds_ref, out_ref,
            *, log2_L: int):
    cands = cands_ref[0, :]                      # [T]
    T = cands.shape[0]
    P, L = lists_ref.shape[1], lists_ref.shape[2]
    member = jnp.ones((T,), jnp.bool_)
    for p in range(P):                           # static: few prefix terms
        row = lists_ref[0, p, :]                 # [L] ascending, INF-padded
        n = lens_ref[0, p]
        # branchless binary search of all T lanes into row; the insertion
        # point lives in [0, L] (L+1 states), so log2(L)+1 halvings are
        # needed to pin it down — log2(L) alone leaves (lo, lo+1) unresolved
        lo = jnp.zeros((T,), jnp.int32)
        hi = jnp.full((T,), L, jnp.int32)
        for _ in range(log2_L + 1):
            mid = (lo + hi) // 2
            v = row[jnp.minimum(mid, L - 1)]     # VMEM gather
            go = v < cands
            valid = lo < hi
            lo = jnp.where(valid & go, mid + 1, lo)
            hi = jnp.where(valid & ~go, mid, hi)
        hit = (lo < n) & (row[jnp.minimum(lo, L - 1)] == cands)
        member &= jnp.where(n > 0, hit, True)
    tlo = bounds_ref[0, 0]
    thi = bounds_ref[0, 1]
    rows = fwd_ref[0, :, :]                      # [T, M]
    fwd_ok = jnp.any((rows >= tlo) & (rows < thi), axis=1)
    ok = member & fwd_ok & (cands != INF)
    out_ref[0, :] = ok.astype(jnp.int32)


def _kernel_packed(cands_ref, starts_ref, ends_ref, fwd_ref, bounds_ref,
                   pw_ref, pb_ref, pm_ref, po_ref, out_ref,
                   *, iters: int, n_post: int, packed_ef: bool):
    cands = cands_ref[0, :]                      # [T]
    T = cands.shape[0]
    P = starts_ref.shape[1]
    lookup = functools.partial(
        packed_lookup, pw_ref[...].reshape(-1), pb_ref[...].reshape(-1),
        pm_ref[...].reshape(-1), po_ref[...].reshape(-1),
        n_post=n_post, ef=packed_ef)
    member = jnp.ones((T,), jnp.bool_)
    for p in range(P):                           # static: few prefix terms
        s = starts_ref[0, p]
        e = ends_ref[0, p]
        # the same valid-guarded halving loop as core.searching's
        # ranged_searchsorted (side="left"), probing the compressed stream;
        # surplus iterations are no-ops, so any iters >= log2(span)+1 gives
        # the identical insertion point
        lo = jnp.full((T,), s, jnp.int32)
        hi = jnp.full((T,), e, jnp.int32)
        for _ in range(iters):
            mid = (lo + hi) // 2
            v = lookup(mid)
            go = v < cands
            valid = lo < hi
            lo = jnp.where(valid & go, mid + 1, lo)
            hi = jnp.where(valid & ~go, mid, hi)
        hit = (lo < e) & (lookup(lo) == cands)
        member &= jnp.where(e > s, hit, True)    # s == e: slot unused/empty
    tlo = bounds_ref[0, 0]
    thi = bounds_ref[0, 1]
    rows = fwd_ref[0, :, :]                      # [T, M]
    fwd_ok = jnp.any((rows >= tlo) & (rows < thi), axis=1)
    ok = member & fwd_ok & (cands != INF)
    out_ref[0, :] = ok.astype(jnp.int32)


def conjunctive_scan_packed_kernel(cands, starts, ends, fwd_rows, bounds,
                                   packed_arrays, *, iters: int, n_post: int,
                                   packed_ef: bool, interpret: bool = True):
    """cands int32[B,T]; starts/ends int32[B,P] (start==end => skip slot);
    fwd_rows int32[B,T,M]; bounds int32[B,2]; packed_arrays = 2-D
    lane-padded (words, base, meta, wordoff) -> int32[B,T] mask."""
    B, T = cands.shape
    P = starts.shape[1]
    M = fwd_rows.shape[2]
    kernel = functools.partial(_kernel_packed, iters=iters, n_post=n_post,
                               packed_ef=packed_ef)
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, T), lambda b: (b, 0)),
            pl.BlockSpec((1, P), lambda b: (b, 0)),
            pl.BlockSpec((1, P), lambda b: (b, 0)),
            pl.BlockSpec((1, T, M), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 2), lambda b: (b, 0)),
        ] + [pl.BlockSpec(a.shape, lambda b: (0, 0)) for a in packed_arrays],
        out_specs=pl.BlockSpec((1, T), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T), jnp.int32),
        interpret=interpret,
    )(cands, starts, ends, fwd_rows, bounds, *packed_arrays)


def conjunctive_scan_kernel(cands, lists, lens, fwd_rows, bounds,
                            *, interpret: bool = True):
    """cands int32[B,T]; lists int32[B,P,L]; lens int32[B,P];
    fwd_rows int32[B,T,M]; bounds int32[B,2] -> int32[B,T] mask."""
    B, T = cands.shape
    _, P, L = lists.shape
    M = fwd_rows.shape[2]
    assert L & (L - 1) == 0, "probe list pad must be a power of two"
    log2_L = L.bit_length() - 1
    kernel = functools.partial(_kernel, log2_L=log2_L)
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, T), lambda b: (b, 0)),
            pl.BlockSpec((1, P, L), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, P), lambda b: (b, 0)),
            pl.BlockSpec((1, T, M), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 2), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, T), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T), jnp.int32),
        interpret=interpret,
    )(cands, lists, lens, fwd_rows, bounds)
