"""Public jit'd wrappers for the fused conjunctive scan (raw + packed)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...compat import pallas_interpret_default
from .kernel import conjunctive_scan_kernel, conjunctive_scan_packed_kernel
from .ref import conjunctive_scan_ref, conjunctive_scan_packed_ref

_LANE = 128


def _pad_lanes(a, fill=0):
    """Pad a 1-D array to a lane multiple (VMEM-friendly 2-D reshape)."""
    pad = (-a.shape[0]) % _LANE
    if pad:
        a = jnp.pad(a, (0, pad), constant_values=fill)
    return a.reshape(1, -1)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def conjunctive_scan(cands, lists, lens, fwd_rows, term_lo, term_hi,
                     *, use_kernel: bool = True, interpret: bool | None = None):
    """bool[B, T] conjunctive hits; see ref.py for semantics.

    ``use_kernel=False`` falls back to the XLA reference (used by the
    dry-run, where Pallas cannot lower on the host platform).
    ``interpret=None`` resolves platform-aware: real lowering on TPU,
    interpret mode elsewhere.
    """
    if interpret is None:
        interpret = pallas_interpret_default()
    if not use_kernel:
        return conjunctive_scan_ref(cands, lists, lens, fwd_rows, term_lo, term_hi)
    bounds = jnp.stack([term_lo, term_hi], axis=1).astype(jnp.int32)
    mask = conjunctive_scan_kernel(cands, lists, lens, fwd_rows, bounds,
                                   interpret=interpret)
    return mask.astype(jnp.bool_)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret",
                                             "probe_iters"))
def conjunctive_scan_packed(cands, starts, ends, fwd_rows, term_lo, term_hi,
                            packed, *, use_kernel: bool = True,
                            interpret: bool | None = None,
                            probe_iters: int = 0):
    """bool[B, T] conjunctive hits, probing the compressed postings stream.

    ``packed`` is a ``codecs.PackedPostings`` (n_post/codec static);
    starts/ends int32[B, P] are each slot's postings span, with
    start == end marking unused/empty slots (the caller masks
    needed-but-empty lanes itself, exactly like the raw kernel route).
    ``probe_iters=0`` uses the global log2(n_post)+1 bound — callers that
    host-verify a tighter span bound may pass fewer. Bit-identical to the
    raw probes because ``packed_lookup(ptr) == postings[ptr]`` on every
    in-bounds pointer.
    """
    if interpret is None:
        interpret = pallas_interpret_default()
    iters = probe_iters or min(31, max(1, packed.n_post.bit_length()))
    if not use_kernel:
        return conjunctive_scan_packed_ref(cands, starts, ends, fwd_rows,
                                           term_lo, term_hi, packed,
                                           iters=iters)
    bounds = jnp.stack([term_lo, term_hi], axis=1).astype(jnp.int32)
    pk = (_pad_lanes(packed.words), _pad_lanes(packed.base),
          _pad_lanes(packed.meta), _pad_lanes(packed.wordoff))
    mask = conjunctive_scan_packed_kernel(
        cands, starts.astype(jnp.int32), ends.astype(jnp.int32), fwd_rows,
        bounds, pk, iters=iters, n_post=packed.n_post,
        packed_ef=packed.has_ef, interpret=interpret)
    return mask.astype(jnp.bool_)
