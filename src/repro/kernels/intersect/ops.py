"""Public jit'd wrapper for the fused conjunctive scan."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...compat import pallas_interpret_default
from .kernel import conjunctive_scan_kernel
from .ref import conjunctive_scan_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def conjunctive_scan(cands, lists, lens, fwd_rows, term_lo, term_hi,
                     *, use_kernel: bool = True, interpret: bool | None = None):
    """bool[B, T] conjunctive hits; see ref.py for semantics.

    ``use_kernel=False`` falls back to the XLA reference (used by the
    dry-run, where Pallas cannot lower on the host platform).
    ``interpret=None`` resolves platform-aware: real lowering on TPU,
    interpret mode elsewhere.
    """
    if interpret is None:
        interpret = pallas_interpret_default()
    if not use_kernel:
        return conjunctive_scan_ref(cands, lists, lens, fwd_rows, term_lo, term_hi)
    bounds = jnp.stack([term_lo, term_hi], axis=1).astype(jnp.int32)
    mask = conjunctive_scan_kernel(cands, lists, lens, fwd_rows, bounds,
                                   interpret=interpret)
    return mask.astype(jnp.bool_)
