"""Pure-jnp oracle for the fused conjunctive scan (paper Fig 5 inner loop).

Inputs (per query row; all padded, batch-leading):
  cands:    int32[B, T]   candidate docids from the driver (shortest) list,
                          INF_DOCID-padded
  lists:    int32[B, P, L] the other prefix posting lists, INF_DOCID-padded,
                          each row ascending
  lens:     int32[B, P]   true lengths of those lists (0 => slot unused)
  fwd_rows: int32[B, T, M] forward-index term rows of each candidate
  term_lo/term_hi: int32[B] suffix term-id range [lo, hi)

Output: bool[B, T] — candidate passes the intersection AND the forward
suffix-range check.
"""
from __future__ import annotations

import jax.numpy as jnp

INF = 2**31 - 1


def conjunctive_scan_ref(cands, lists, lens, fwd_rows, term_lo, term_hi):
    B, T = cands.shape
    _, P, L = lists.shape
    # membership: binary-search probe of each candidate into each list.
    # searchsorted over the padded row works because INF pads sort last.
    pos = jnp.stack(
        [
            jnp.stack([jnp.searchsorted(lists[b, p], cands[b], side="left")
                       for p in range(P)], axis=0)
            for b in range(B)
        ],
        axis=0,
    )                                                     # [B, P, T]
    gathered = jnp.take_along_axis(lists, jnp.minimum(pos, L - 1), axis=2)
    present = (gathered == cands[:, None, :]) & (pos < lens[..., None])
    used = (lens > 0)[:, :, None]
    member = jnp.all(present | ~used, axis=1)             # [B, T]
    in_range = (fwd_rows >= term_lo[:, None, None]) & (fwd_rows < term_hi[:, None, None])
    fwd_ok = jnp.any(in_range, axis=2)                    # [B, T]
    valid = cands != INF
    return member & fwd_ok & valid
