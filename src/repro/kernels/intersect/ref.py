"""Pure-jnp oracle for the fused conjunctive scan (paper Fig 5 inner loop).

Inputs (per query row; all padded, batch-leading):
  cands:    int32[B, T]   candidate docids from the driver (shortest) list,
                          INF_DOCID-padded
  lists:    int32[B, P, L] the other prefix posting lists, INF_DOCID-padded,
                          each row ascending
  lens:     int32[B, P]   true lengths of those lists (0 => slot unused)
  fwd_rows: int32[B, T, M] forward-index term rows of each candidate
  term_lo/term_hi: int32[B] suffix term-id range [lo, hi)

Output: bool[B, T] — candidate passes the intersection AND the forward
suffix-range check.
The packed variant probes the compressed postings stream directly
(per-lane [start, end) spans + ``codecs.packed_lookup`` decode) instead of
pre-gathered [B, P, L] list tiles — same output contract.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp

from ...core.codecs import packed_lookup

INF = 2**31 - 1


def conjunctive_scan_ref(cands, lists, lens, fwd_rows, term_lo, term_hi):
    B, T = cands.shape
    _, P, L = lists.shape
    # membership: binary-search probe of each candidate into each list.
    # searchsorted over the padded row works because INF pads sort last.
    pos = jnp.stack(
        [
            jnp.stack([jnp.searchsorted(lists[b, p], cands[b], side="left")
                       for p in range(P)], axis=0)
            for b in range(B)
        ],
        axis=0,
    )                                                     # [B, P, T]
    gathered = jnp.take_along_axis(lists, jnp.minimum(pos, L - 1), axis=2)
    present = (gathered == cands[:, None, :]) & (pos < lens[..., None])
    used = (lens > 0)[:, :, None]
    member = jnp.all(present | ~used, axis=1)             # [B, T]
    in_range = (fwd_rows >= term_lo[:, None, None]) & (fwd_rows < term_hi[:, None, None])
    fwd_ok = jnp.any(in_range, axis=2)                    # [B, T]
    valid = cands != INF
    return member & fwd_ok & valid


def conjunctive_scan_packed_ref(cands, starts, ends, fwd_rows, term_lo,
                                term_hi, packed, *, iters: int):
    """Batched oracle of the packed probe kernel (same loop, [B, T] lanes).

    starts/ends int32[B, P] are per-slot postings spans; start == end marks
    an unused or empty slot (skipped — the caller's lane_dead mask handles
    needed-but-empty). ``iters`` >= log2(longest span)+1; surplus
    iterations are no-ops (valid-guarded halving), matching
    ``core.searching.ranged_searchsorted`` exactly.
    """
    B, T = cands.shape
    P = starts.shape[1]
    lookup = functools.partial(
        packed_lookup, packed.words, packed.base, packed.meta,
        packed.wordoff, n_post=packed.n_post, ef=packed.has_ef)
    member = jnp.ones((B, T), jnp.bool_)
    for p in range(P):
        s = starts[:, p:p + 1]                            # [B, 1]
        e = ends[:, p:p + 1]
        lo = jnp.broadcast_to(s, (B, T)).astype(jnp.int32)
        hi = jnp.broadcast_to(e, (B, T)).astype(jnp.int32)
        for _ in range(iters):
            mid = (lo + hi) // 2
            v = lookup(ptr=mid)
            go = v < cands
            valid = lo < hi
            lo = jnp.where(valid & go, mid + 1, lo)
            hi = jnp.where(valid & ~go, mid, hi)
        hit = (lo < e) & (lookup(ptr=lo) == cands)
        member &= jnp.where(e > s, hit, True)
    in_range = (fwd_rows >= term_lo[:, None, None]) & (fwd_rows < term_hi[:, None, None])
    fwd_ok = jnp.any(in_range, axis=2)
    valid = cands != INF
    return member & fwd_ok & valid
