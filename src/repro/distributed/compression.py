"""Gradient compression for cross-pod reduction: int8 + error feedback.

At 1000+ nodes the pod-interconnect (DCN) all-reduce dominates; int8
quantization cuts those bytes 4x. Error feedback (Seide et al. '14 / EF-SGD)
keeps the quantization bias out of the long-run trajectory: the residual of
each compression round is added back before the next one.

Two entry points:
  * ``compress``/``decompress`` — pure, testable, used by the simulator;
  * ``psum_compressed`` — inside shard_map: uniform scale via psum-max, int32
    summation (exact for <= 2^23 shards), dequant after the wire.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def compress(g, ef=None):
    """-> (q int8, scale f32, new_ef). Per-tensor symmetric quantization."""
    g32 = g.astype(jnp.float32)
    if ef is not None:
        g32 = g32 + ef
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_ef = g32 - q.astype(jnp.float32) * scale
    return q, scale, new_ef


def decompress(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, ef_tree):
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(ef_tree)
    out = [compress(g, e) for g, e in zip(flat_g, flat_e)]
    deq = [decompress(q, s) for q, s, _ in out]
    new_ef = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return jax.tree_util.tree_unflatten(tdef, deq), new_ef


def init_ef(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def psum_compressed(g, axis: str, ef=None):
    """int8-over-the-wire psum along ``axis`` (call inside shard_map).

    The scale is made uniform across the axis with a psum-max (tiny payload),
    so the int32 sum dequantizes exactly. Returns (summed f32, new_ef).
    """
    g32 = g.astype(jnp.float32)
    if ef is not None:
        g32 = g32 + ef
    local_max = jnp.max(jnp.abs(g32))
    global_max = lax.pmax(local_max, axis)
    scale = jnp.maximum(global_max, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int32)
    new_ef = g32 - q.astype(jnp.float32) * scale
    total = lax.psum(q, axis)
    return total.astype(jnp.float32) * scale, new_ef
