"""Logical-axis sharding rules (MaxText-style) for pjit/GSPMD.

Models annotate activations/params with *logical* axis names; a per-family
rule table maps them to mesh axes ("pod", "data", "model"). Outside a mesh
context every hint is a no-op, so the same model code runs on 1 CPU device in
tests and on the 512-chip dry-run mesh unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisRules = dict[str, Optional[object]]  # logical name -> mesh axis (or tuple)

# LM default: batch over (pod, data); heads/ffn/vocab/experts over model.
DEFAULT_LM_RULES: AxisRules = {
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,
    "d_model": None,
    "heads": "model",
    "kv_heads": "model",
    "d_ff": "model",
    "experts": "model",
    "expert_ff": None,
    "vocab": "model",
    "table_rows": "model",
    "nodes": ("pod", "data"),
    "edges": ("pod", "data"),
    "docid": "model",
    "candidates": "model",
}

_ctx = threading.local()


def set_mesh(mesh: Optional[Mesh], rules: Optional[AxisRules] = None):
    _ctx.mesh = mesh
    _ctx.rules = rules or DEFAULT_LM_RULES


def get_mesh() -> Optional[Mesh]:
    return getattr(_ctx, "mesh", None)


def get_rules() -> AxisRules:
    return getattr(_ctx, "rules", DEFAULT_LM_RULES)


@contextlib.contextmanager
def mesh_context(mesh: Mesh, rules: Optional[AxisRules] = None):
    old_mesh, old_rules = get_mesh(), get_rules()
    set_mesh(mesh, rules)
    try:
        yield
    finally:
        set_mesh(old_mesh, old_rules)


def _spec_for(logical: Sequence[Optional[str]], mesh: Mesh, rules: AxisRules) -> P:
    parts = []
    used: set = set()
    for name in logical:
        if name is None:
            parts.append(None)
            continue
        axis = rules.get(name)
        if axis is None:
            parts.append(None)
            continue
        if isinstance(axis, tuple):
            axis = tuple(a for a in axis
                         if a in mesh.axis_names and a not in used)
            used.update(axis)
            parts.append(axis if axis else None)
        else:
            if axis not in mesh.axis_names or axis in used:
                parts.append(None)    # first mapping wins (flax-rule style)
            else:
                used.add(axis)
                parts.append(axis)
    return P(*parts)


def logical_sharding(logical: Sequence[Optional[str]],
                     mesh: Optional[Mesh] = None,
                     rules: Optional[AxisRules] = None) -> Optional[NamedSharding]:
    mesh = mesh or get_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, _spec_for(logical, mesh, rules or get_rules()))


def shard_hint(x, *logical: Optional[str]):
    """with_sharding_constraint by logical names; no-op without a mesh."""
    s = logical_sharding(logical)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)


def tree_shardings(axes_tree, mesh=None, rules=None):
    """Map a pytree of logical-axis tuples to NamedShardings (or None)."""
    return jax.tree_util.tree_map(
        lambda ax: logical_sharding(ax, mesh, rules),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def zero1_shardings(params, param_shardings, mesh: Mesh):
    """ZeRO-1: optimizer-moment shardings = param shardings with the largest
    still-replicated, divisible dim additionally split over 'data'.

    Returns a pytree (same structure as params) of NamedShardings for one
    moment buffer; use for both mu and nu.
    """
    data = mesh.shape.get("data", 1)

    def one(p, sh):
        spec = list(sh.spec) if sh is not None else []
        spec += [None] * (p.ndim - len(spec))

        def uses(axis):
            for e in spec:
                if e == axis or (isinstance(e, tuple) and axis in e):
                    return True
            return False

        if data > 1 and not uses("data"):
            for i in range(p.ndim):
                if spec[i] is None and p.shape[i] % data == 0:
                    spec[i] = "data"
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(one, params, param_shardings,
                                  is_leaf=lambda x: x is None)
