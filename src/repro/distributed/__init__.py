from .sharding import (  # noqa: F401
    AxisRules, set_mesh, get_mesh, get_rules, mesh_context,
    shard_hint, logical_sharding, DEFAULT_LM_RULES,
)
