"""jax version shims — pin repo behavior across jax API drift.

Policy (ROADMAP "compat policy"): any jax symbol that has moved, been
renamed, or changed its keyword surface between the releases we support is
resolved HERE, once, at import time. Call sites never probe jax versions
themselves; they import from ``repro.compat``. Known drift covered:

  * ``shard_map``: top-level ``jax.shard_map`` (jax >= 0.5) vs
    ``jax.experimental.shard_map.shard_map`` (<= 0.4.x), including the
    ``check_vma`` (new) vs ``check_rep`` (old) keyword rename.
  * Pallas TPU compiler params: ``pltpu.CompilerParams`` (new) vs
    ``pltpu.TPUCompilerParams`` (old).

Everything here is import-safe on CPU-only installs: Pallas is imported
lazily so merely importing ``repro.compat`` never pulls in TPU machinery.
"""
from __future__ import annotations

import inspect

import jax

__all__ = ["shard_map", "tpu_compiler_params", "HAS_NATIVE_SHARD_MAP",
           "is_tpu_backend", "pallas_interpret_default", "default_use_kernel",
           "default_heap_kernel_max_bytes"]


def _resolve_shard_map():
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm  # jax <= 0.4.x
        native = False
    else:
        native = True
    params = inspect.signature(sm).parameters
    check_kw = "check_vma" if "check_vma" in params else "check_rep"
    return sm, check_kw, native


_SHARD_MAP, _CHECK_KW, HAS_NATIVE_SHARD_MAP = _resolve_shard_map()


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kwargs):
    """``jax.shard_map`` with the modern keyword surface on every jax.

    ``check_vma`` follows the new-jax name; on old jax it is forwarded as
    ``check_rep`` (same semantics: disable the replication/varying-axis
    checker, which rejects several of our collective-merge patterns).
    """
    kwargs[_CHECK_KW] = check_vma
    return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


def is_tpu_backend() -> bool:
    """True when the default jax backend is a TPU."""
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:  # no backend at all (e.g. sandboxed import)
        return False


def pallas_interpret_default() -> bool:
    """Platform-aware ``interpret`` default for Pallas kernels.

    On TPU the kernels lower for real; everywhere else (CPU/GPU test rigs)
    they run in interpret mode so the same call sites stay portable. Call
    sites take ``interpret: bool | None = None`` and resolve None here —
    never hardcode ``interpret=True``.
    """
    return not is_tpu_backend()


def default_use_kernel() -> bool:
    """Kernel-routing policy for the serving engines (ROADMAP PR 2).

    Pallas kernels are the fast path only where they lower natively (TPU);
    the XLA reference formulations win on CPU/GPU, where interpret-mode
    Pallas would be orders of magnitude slower. Serving call sites take
    ``use_kernel: bool | None = None`` and resolve None here.
    """
    return is_tpu_backend()


def default_heap_kernel_max_bytes() -> int:
    """Platform-resolved VMEM ceiling for the fused heap_topk kernel.

    The kernel pins the engine's source arrays (RMQ values + sparse table +
    ib windows as int32, offsets, and raw or compressed postings) in VMEM
    for the whole launch, plus 5·bt·cap·4 bytes of heap scratch. Current
    TPU generations give ~16 MiB of VMEM per core; 12 MiB leaves headroom
    for scratch + double-buffered lane tiles on every generation we target,
    so that is the default everywhere (off-TPU the interpreter has no real
    ceiling, but routing parity with TPU matters more than a bigger gate).
    Callers take ``max_bytes: int | None = None`` (None = resolve here);
    ``QACArch.heap_kernel_max_bytes`` is the config-level override.
    """
    return 12 << 20


def tpu_compiler_params(**kwargs):
    """Build Pallas-TPU compiler params across the TPUCompilerParams rename.

    Accepts the modern field names (``dimension_semantics``, ``vmem_limit_bytes``,
    ...); both classes share them. Imported lazily so CPU-only paths that never
    launch a kernel don't pay for (or require) Pallas TPU internals.
    """
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams  # jax <= 0.4.x name
    return cls(**kwargs)
