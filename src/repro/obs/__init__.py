"""End-to-end serving observability (ISSUE 10 tentpole).

Four small, dependency-free pieces threaded through every serving layer
(runtime, cluster, frontend, freshness):

  metrics.py    ``percentiles`` — the repo's ONE quantile implementation
                (np.percentile semantics, explicit None on empty) — and
                ``MetricsRegistry``, the counters/gauges/exact-reservoir-
                histograms aggregation point every layer's telemetry
                registers into.
  tracing.py    ``Tracer`` — request spans with explicit ids on the
                virtual microsecond clock, 1/N sampling, zero overhead
                when disabled; JSONL + Chrome/Perfetto export.
  jit_audit.py  ``JitAuditor`` — records every jit-variant compile
                (cache key + wall time) and asserts the closed-variant
                invariant online after ``freeze()``.
  slo.py        ``SLOMonitor`` — multi-window burn-rate evaluation of the
                interactive 50 ms SLA.

``ObsConfig`` bundles the knobs (``QACArch.obs_config()`` is the
production preset); ``launch/serve.py --observe`` wires the whole stack
and ``scripts/obs_report.py`` renders a trace file into a per-request
waterfall + per-stage latency budget.
"""
from __future__ import annotations

import dataclasses

from .metrics import MetricsRegistry, Histogram, percentiles, fmt  # noqa: F401
from .tracing import (Tracer, load_jsonl, request_trees,  # noqa: F401
                      span_children)
from .jit_audit import JitAuditor, JitAuditError  # noqa: F401
from .slo import SLOMonitor, DEFAULT_WINDOWS  # noqa: F401


@dataclasses.dataclass
class ObsConfig:
    """Observability knobs, validated at construction like the other
    serving configs. ``trace_sample_every`` is the 1/N request-sampling
    stride (1 = trace everything; 16 is the acceptance-bench operating
    point whose p99 overhead must stay within 10% of tracing-off)."""

    trace_sample_every: int = 16
    trace_capacity: int = 1 << 20
    hist_capacity: int = 1 << 16
    slo_target_us: float = 50_000.0      # the paper-motivated interactive SLA
    slo_objective: float = 0.999
    slo_windows: tuple = DEFAULT_WINDOWS
    strict_jit_audit: bool = False       # raise on post-freeze compiles

    def __post_init__(self):
        if self.trace_sample_every < 1:
            raise ValueError(f"trace_sample_every must be >= 1, "
                             f"got {self.trace_sample_every}")
        if self.trace_capacity < 1:
            raise ValueError(f"trace_capacity must be >= 1, "
                             f"got {self.trace_capacity}")
        if self.hist_capacity < 1:
            raise ValueError(f"hist_capacity must be >= 1, "
                             f"got {self.hist_capacity}")
        if self.slo_target_us <= 0:
            raise ValueError(f"slo_target_us must be positive, "
                             f"got {self.slo_target_us}")
        if not 0.0 < self.slo_objective < 1.0:
            raise ValueError(f"slo_objective must be in (0, 1), "
                             f"got {self.slo_objective}")

    def tracer(self) -> Tracer:
        return Tracer(sample_every=self.trace_sample_every,
                      capacity=self.trace_capacity)

    def registry(self) -> MetricsRegistry:
        return MetricsRegistry(hist_capacity=self.hist_capacity)

    def auditor(self, tracer: Tracer | None = None) -> JitAuditor:
        return JitAuditor(strict=self.strict_jit_audit, tracer=tracer)

    def slo_monitor(self) -> SLOMonitor:
        return SLOMonitor(target_us=self.slo_target_us,
                          objective=self.slo_objective,
                          windows=self.slo_windows)
