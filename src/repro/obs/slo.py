"""SLO burn-rate monitoring over the interactive latency objective.

The paper's system exists because the old one "was not always able to meet
the required service-level-agreement"; this module is the alerting math
that makes our 50 ms interactive SLA operational rather than a number in a
docstring. The model is the SRE-workbook multi-window burn rate:

  * the SLO is "fraction ``objective`` of requests complete within
    ``target_us``" — so the *error budget* is ``1 - objective``;
  * the *burn rate* over a window is (violation fraction in window) /
    (error budget): 1.0 means spending the budget exactly on schedule,
    14.4 means a 30-day budget gone in 2 days;
  * an alert pair (long_window, short_window, threshold) FIRES only when
    BOTH windows exceed the threshold — the long window proves the burn is
    sustained, the short window proves it is still happening (fast reset).

Windows are virtual microseconds on the serving clock, so the monitor
works identically on trace replays and live feeds. ``observe`` takes each
request's completion time + latency; ``evaluate`` returns per-pair burn
rates and firing flags plus the overall compliance summary that
``launch/serve.py --observe`` and ``scripts/obs_report.py`` print.
"""
from __future__ import annotations

from collections import deque

# (long_us, short_us, burn threshold) — the classic 1h/5m, 6h/30m, 3d/6h
# page/ticket ladder, scaled in virtual microseconds.
DEFAULT_WINDOWS = (
    (3_600e6, 300e6, 14.4),
    (21_600e6, 1_800e6, 6.0),
    (259_200e6, 21_600e6, 1.0),
)


class SLOMonitor:
    """Multi-window burn-rate evaluation of a latency SLO (module
    docstring). Samples are (completion_t_us, ok) pairs kept for the
    longest configured window."""

    def __init__(self, *, target_us: float = 50_000.0,
                 objective: float = 0.999, windows=DEFAULT_WINDOWS):
        if target_us <= 0:
            raise ValueError(f"target_us must be positive, got {target_us}")
        if not 0.0 < objective < 1.0:
            raise ValueError(f"objective must be in (0, 1), "
                             f"got {objective}")
        windows = tuple(tuple(w) for w in windows)
        for long_us, short_us, thr in windows:
            if not 0 < short_us <= long_us:
                raise ValueError(
                    f"window pair must satisfy 0 < short <= long, "
                    f"got ({long_us}, {short_us})")
            if thr <= 0:
                raise ValueError(f"burn threshold must be positive, "
                                 f"got {thr}")
        self.target_us = float(target_us)
        self.objective = float(objective)
        self.budget = 1.0 - float(objective)
        self.windows = windows
        self.samples: deque = deque()     # (t_us, ok) in completion order
        self.n_total = 0
        self.n_violations = 0
        self._max_window = max((w[0] for w in windows), default=0.0)

    def observe(self, t_us: float, lat_us: float):
        """One completed request at virtual time ``t_us`` with end-to-end
        latency ``lat_us``."""
        ok = lat_us <= self.target_us
        self.n_total += 1
        self.n_violations += not ok
        self.samples.append((float(t_us), ok))
        cutoff = float(t_us) - self._max_window
        while self.samples and self.samples[0][0] < cutoff:
            self.samples.popleft()

    def burn_rate(self, window_us: float, now: float | None = None) -> float | None:
        """Burn over the trailing window ending at ``now`` (default: the
        latest sample). None when the window holds no samples."""
        if not self.samples:
            return None
        if now is None:
            now = self.samples[-1][0]
        lo = now - window_us
        n = bad = 0
        for t, ok in reversed(self.samples):
            if t < lo:
                break
            n += 1
            bad += not ok
        if n == 0:
            return None
        return (bad / n) / self.budget

    def evaluate(self, now: float | None = None) -> dict:
        """Per window-pair burn rates + firing flags + overall compliance.
        Stable schema: ``alerts`` is a list of dicts with
        long_window_us/short_window_us/threshold/long_burn/short_burn/
        firing; ``firing`` is the OR over pairs."""
        alerts = []
        firing = False
        for long_us, short_us, thr in self.windows:
            lb = self.burn_rate(long_us, now)
            sb = self.burn_rate(short_us, now)
            fire = (lb is not None and sb is not None
                    and lb >= thr and sb >= thr)
            firing |= fire
            alerts.append({
                "long_window_us": long_us, "short_window_us": short_us,
                "threshold": thr, "long_burn": lb, "short_burn": sb,
                "firing": fire,
            })
        return {
            "target_us": self.target_us,
            "objective": self.objective,
            "n_requests": self.n_total,
            "n_violations": self.n_violations,
            "compliance": (1.0 - self.n_violations / self.n_total
                           if self.n_total else None),
            "alerts": alerts,
            "firing": firing,
        }
