"""Request tracing on the serving stack's virtual microsecond clock.

A ``Tracer`` records *spans* (named intervals with explicit ids and parent
links) and *instants* (point events) against the same virtual clock the
runtime/cluster/freshness layers already schedule on — so a trace is a
faithful picture of the simulated deployment, not of the host's wall
clock. Span taxonomy (the contract obs_report and the tests rely on; see
ROADMAP "Architecture invariants"):

  request           root span per sampled request: [arrival, completion],
                    attrs path/session/k/gen/query.
    cache.trivial / cache.hit_exact / cache.hit_session
                    hit-path child covering the whole request interval,
                    attrs carry the cache-miss/hit reason.
    queue.wait      miss-path child: [arrival, dispatch start].
    engine.service  miss-path child: [dispatch start, batch completion].
                    queue.wait + engine.service == the request's recorded
                    end-to-end latency, EXACTLY (same clock arithmetic),
                    which is how obs_report rebuilds p99 from spans alone.
  batch.dispatch    one span per micro-batch (no request id), attrs
                    size/trigger/jit keys/kernel routes actually taken.
  merge.kway        freshness: per-answer delta merge, attrs
                    n_delta/escalations/seq.
  generation.rebuild / generation.swap_stall
                    freshness: background fold-and-build vs the swap stall.
  admission / replica.death / replica.readmit / generation.swap /
  merge.escalate / delta.apply
                    instants (cluster + freshness decision points).

Zero overhead when disabled: layers hold ``tracer = None`` and every
instrumentation site is behind ``if tracer is not None`` (plus per-request
``want(idx)`` sampling — 1/N of requests carry spans, batch spans fire only
when a sampled request is aboard). The acceptance bench
(``bench_qac_obs``) holds online p99 at 1/16 sampling within 10% of
tracing-off.

Export: ``to_jsonl`` (one record per line, ``type`` = span|instant —
what ``scripts/obs_report.py`` consumes) and ``to_chrome`` (Chrome/
Perfetto trace-event JSON: ph="X" duration events + ph="i" instants,
ts/dur in microseconds — load in chrome://tracing or ui.perfetto.dev).
"""
from __future__ import annotations

import json


class Tracer:
    """Span/instant recorder with 1/N per-request sampling (module
    docstring has the taxonomy and the zero-overhead contract)."""

    def __init__(self, *, sample_every: int = 1, capacity: int = 1 << 20):
        if sample_every < 1:
            raise ValueError(f"sample_every must be >= 1, "
                             f"got {sample_every}")
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.sample_every = int(sample_every)
        self.capacity = int(capacity)
        self.clear()

    def clear(self):
        """Drop recorded spans/instants (measured-replay protocol: clear
        after the warm pass so the trace covers only the measured pass).
        Ids keep advancing — parent links can never dangle across clears.
        """
        self.spans: list[dict] = []
        self.instants: list[dict] = []
        self.dropped = 0
        self._next_id = getattr(self, "_next_id", 1)

    def want(self, idx: int) -> bool:
        """Is request ``idx`` sampled? (1/sample_every of the id space.)"""
        return idx % self.sample_every == 0

    def span(self, name: str, t0_us: float, dur_us: float, *,
             cat: str = "serve", req: int | None = None,
             parent: int | None = None, **attrs) -> int | None:
        """Record one interval; returns its span id (parent for children),
        or None once capacity is hit (counted in ``dropped``)."""
        if len(self.spans) >= self.capacity:
            self.dropped += 1
            return None
        sid = self._next_id
        self._next_id += 1
        self.spans.append({
            "id": sid, "parent": parent, "name": name, "cat": cat,
            "req": req, "t0_us": float(t0_us), "dur_us": float(dur_us),
            "attrs": attrs,
        })
        return sid

    def instant(self, name: str, t_us: float, *, cat: str = "serve",
                req: int | None = None, **attrs):
        if len(self.instants) >= self.capacity:
            self.dropped += 1
            return
        self.instants.append({
            "name": name, "cat": cat, "req": req, "t_us": float(t_us),
            "attrs": attrs,
        })

    # -- export ---------------------------------------------------------------
    def to_jsonl(self, path: str) -> str:
        """One JSON record per line: spans (``type: "span"``) then
        instants (``type: "instant"``) — the obs_report input format."""
        with open(path, "w") as f:
            for s in self.spans:
                f.write(json.dumps({"type": "span", **s}) + "\n")
            for e in self.instants:
                f.write(json.dumps({"type": "instant", **e}) + "\n")
        return path

    def to_chrome(self, path: str) -> str:
        """Chrome/Perfetto trace-event JSON. Requests map to tids so each
        sampled request gets its own lane in the viewer; batch/cluster
        events land on lane 0."""
        events = []
        for s in self.spans:
            events.append({
                "name": s["name"], "cat": s["cat"], "ph": "X",
                "ts": s["t0_us"], "dur": s["dur_us"],
                "pid": 0, "tid": s["req"] if s["req"] is not None else 0,
                "args": dict(s["attrs"], span_id=s["id"],
                             parent=s["parent"]),
            })
        for e in self.instants:
            events.append({
                "name": e["name"], "cat": e["cat"], "ph": "i", "s": "t",
                "ts": e["t_us"], "pid": 0,
                "tid": e["req"] if e["req"] is not None else 0,
                "args": dict(e["attrs"]),
            })
        with open(path, "w") as f:
            json.dump({"traceEvents": events,
                       "displayTimeUnit": "ms"}, f)
        return path


def load_jsonl(path: str) -> tuple[list[dict], list[dict]]:
    """Read a ``to_jsonl`` trace back -> (spans, instants)."""
    spans, instants = [], []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            (spans if rec.get("type") == "span" else instants).append(rec)
    return spans, instants


def span_children(spans: list[dict]) -> dict:
    """parent span id -> list of child spans (None key = roots)."""
    out: dict = {}
    for s in spans:
        out.setdefault(s.get("parent"), []).append(s)
    return out


def request_trees(spans: list[dict]) -> dict:
    """req idx -> (root request span, [child spans]) for every root named
    ``request`` — the obs_report / invariant-test accessor."""
    kids = span_children(spans)
    out = {}
    for root in kids.get(None, []):
        if root["name"] == "request" and root.get("req") is not None:
            out[root["req"]] = (root, kids.get(root["id"], []))
    return out
