"""Jit-variant auditor: make every compile visible, assert none mid-trace.

The serving stack's p99 story rests on a *closed jit-variant space*: the
frontend's pow2 batch/k buckets plus ``specialize_list_pad=False`` mean a
warmed deployment never compiles again, because a mid-trace XLA compile
(tens of ms) is billed to the virtual clock right on the serving path — a
p99 cliff. Until now that invariant was enforced only by construction;
this auditor makes it *observable* and *assertable* online:

  * ``wrap(key, fn)`` — the frontend wraps every newly-minted jit callable;
    the wrapper times the first invocation (which is where XLA compiles)
    with a block-until-ready and records ``(key, wall_us, frozen?)``.
    After the first call the wrapper is a dict-hit + passthrough.
  * ``freeze()`` — called when warmup ends. Every compile recorded after
    the freeze is a VIOLATION of the closed-variant invariant; ``strict``
    mode raises on the spot, default mode accumulates them for
    ``assert_closed()`` / the ``--observe --check`` launcher gate.

The negative control lives in ``benchmarks/bench_qac_obs.py``: a frontend
with ``specialize_list_pad=True`` (the open-variant config the online
stack forbids) must produce >= 1 flagged mid-trace compile on the same
trace a closed frontend serves with zero.
"""
from __future__ import annotations

import time


class JitAuditError(AssertionError):
    """A jit variant compiled after ``freeze()`` in strict mode."""


class JitAuditor:
    """Records every new jit-cache variant (key + first-call wall time)
    and enforces the closed-variant invariant after ``freeze()``."""

    def __init__(self, *, strict: bool = False, tracer=None):
        self.strict = strict
        self.tracer = tracer      # optional: compile instants in the trace
        self.compiles: list[dict] = []   # {key, wall_us, frozen}
        self.seen: set = set()
        self.frozen = False

    def wrap(self, key, fn, *, label: str | None = None):
        """Wrap a fresh jit callable so its first invocation is timed and
        recorded. Must be called at most once per key (the frontend's jit
        cache guarantees it)."""
        state = {"first": True}

        def wrapped(*args, **kwargs):
            if state["first"]:
                state["first"] = False
                t0 = time.perf_counter()
                out = fn(*args, **kwargs)
                _block(out)
                self.record(key, (time.perf_counter() - t0) * 1e6,
                            label=label)
                return out
            return fn(*args, **kwargs)

        return wrapped

    def record(self, key, wall_us: float, *, label: str | None = None):
        """One new variant materialized (first call = compile + run)."""
        entry = {"key": _keyrepr(key), "wall_us": float(wall_us),
                 "frozen": self.frozen}
        if label:
            entry["label"] = label
        self.compiles.append(entry)
        self.seen.add(_keyrepr(key))
        if self.tracer is not None:
            self.tracer.instant("jit.compile", 0.0, cat="jit",
                                key=_keyrepr(key), wall_us=float(wall_us),
                                frozen=self.frozen)
        if self.frozen and self.strict:
            raise JitAuditError(
                f"jit variant {key!r} compiled after freeze() "
                f"({wall_us / 1e3:.1f}ms) — the closed-variant invariant "
                f"is broken")

    def freeze(self):
        """Warmup is over: any compile from here on is a violation."""
        self.frozen = True

    @property
    def violations(self) -> list[dict]:
        return [c for c in self.compiles if c["frozen"]]

    def assert_closed(self):
        """Raise unless zero variants compiled after freeze()."""
        bad = self.violations
        if bad:
            keys = [c["key"] for c in bad]
            raise JitAuditError(
                f"{len(bad)} jit variant(s) compiled after freeze(): "
                f"{keys[:5]}")

    def snapshot(self) -> dict:
        """Stable schema for the metrics registry."""
        return {
            "n_variants": len(self.compiles),
            "n_violations": len(self.violations),
            "frozen": self.frozen,
            "compile_wall_us_total": float(
                sum(c["wall_us"] for c in self.compiles)),
            "compiles": [dict(c) for c in self.compiles],
        }


def _keyrepr(key):
    """Stable, JSON-able rendering of a jit-cache key."""
    if isinstance(key, tuple):
        return tuple(_keyrepr(k) for k in key)
    if isinstance(key, (str, int, float, bool)) or key is None:
        return key
    return repr(key)


def _block(out):
    """Block until a pytree of jax arrays is ready (first-call timing must
    include the XLA compile + execute, not just dispatch)."""
    try:
        import jax

        jax.block_until_ready(out)
    except Exception:
        # non-array outputs (host fallbacks) are already synchronous
        pass
