"""Unified metrics: ONE percentile implementation + a process-wide registry.

Before this module, every serving layer hand-rolled its own quantile math
(``RuntimeTelemetry.snapshot``, ``ClusterTelemetry._pct``,
``GenerationalQAC.snapshot``, the freshness bench) — three copies of the
same ``np.percentile`` call with three different empty-input behaviors,
one of which (silently reporting 0.0 latency for a window that served
nothing) is exactly the failure mode an SLA argument cannot afford.

``percentiles`` is the one copy now: pinned to ``np.percentile`` semantics
verbatim (tests assert equality against numpy, not approximation) and
explicit about emptiness — an empty input yields ``None`` for every
statistic, never a fabricated zero. Callers that print snapshots use
``fmt`` to render the ``None``.

``MetricsRegistry`` is the aggregation point: counters, gauges, and
exact-reservoir histograms for ad-hoc instrumentation, plus *collectors* —
named snapshot callables the serving layers register
(``RuntimeTelemetry``, ``ClusterTelemetry``, freshness counters, the jit
auditor) so one ``registry.snapshot()`` returns the whole serving stack's
state under a stable schema: top-level keys ``counters`` / ``gauges`` /
``histograms`` / ``collectors``, histogram sub-dicts always carrying
``n`` / ``mean`` / ``max`` / ``p50`` / ``p95`` / ``p99`` (None when
empty). Downstream tooling (obs_report, the bench regression gate) reads
this schema and nothing else.
"""
from __future__ import annotations

import numpy as np

DEFAULT_QS = (50, 95, 99)


def percentiles(values, qs=DEFAULT_QS, *, suffix: str = "_us",
                mean: bool = False, vmax: bool = False) -> dict:
    """``{f"p{q}{suffix}": float | None}`` pinned to ``np.percentile``.

    The ONE quantile implementation for the repo (ISSUE 10 satellite):
    nonempty input -> ``float(np.percentile(values, q))`` verbatim, so the
    pinning tests in test_serve_runtime/test_serve_cluster hold by
    construction; empty input -> explicit ``None`` per key — a zero-traffic
    window reports "no data", never a fake 0us latency. ``mean``/``vmax``
    add ``mean{suffix}`` / ``max{suffix}`` under the same rule.
    """
    vals = np.asarray(list(values), np.float64)
    out: dict = {}
    if vals.size == 0:
        for q in qs:
            out[f"p{q}{suffix}"] = None
        if mean:
            out[f"mean{suffix}"] = None
        if vmax:
            out[f"max{suffix}"] = None
        return out
    for q in qs:
        out[f"p{q}{suffix}"] = float(np.percentile(vals, q))
    if mean:
        out[f"mean{suffix}"] = float(vals.mean())
    if vmax:
        out[f"max{suffix}"] = float(vals.max())
    return out


def fmt(v, scale: float = 1.0, nd: int = 0, unit: str = "") -> str:
    """Render a possibly-``None`` statistic: ``fmt(None) == "n/a"``.

    Snapshot consumers (launcher prints, examples) must survive the
    explicit-None contract above; this is the one formatting helper they
    share instead of each guarding f-strings.
    """
    if v is None:
        return "n/a"
    return f"{v / scale:.{nd}f}{unit}"


class Histogram:
    """Exact-reservoir histogram: every observation is kept verbatim up to
    ``capacity`` (so percentiles are exact, not sketched); past capacity
    the count/sum/max stay exact and the reservoir stops growing (the
    snapshot marks itself ``truncated``)."""

    def __init__(self, capacity: int = 1 << 16):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.values: list[float] = []
        self.n = 0
        self.total = 0.0
        self.vmax: float | None = None

    def observe(self, v: float):
        v = float(v)
        self.n += 1
        self.total += v
        self.vmax = v if self.vmax is None else max(self.vmax, v)
        if len(self.values) < self.capacity:
            self.values.append(v)

    def snapshot(self) -> dict:
        out = {"n": self.n,
               "mean": (self.total / self.n) if self.n else None,
               "max": self.vmax}
        out.update(percentiles(self.values, suffix=""))
        if self.n > len(self.values):
            out["truncated"] = True
        return out


class MetricsRegistry:
    """Counters + gauges + exact-reservoir histograms + named collectors.

    One registry per serving deployment; every layer registers its
    telemetry snapshot as a collector so ``snapshot()`` is the single
    machine-readable view of the stack (stable schema, see module
    docstring).
    """

    def __init__(self, *, hist_capacity: int = 1 << 16):
        self._hist_capacity = int(hist_capacity)
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.histograms: dict[str, Histogram] = {}
        self._collectors: dict[str, object] = {}

    def counter(self, name: str, inc: float = 1):
        self.counters[name] = self.counters.get(name, 0) + inc

    def gauge(self, name: str, value: float):
        self.gauges[name] = float(value)

    def observe(self, name: str, value: float):
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(self._hist_capacity)
        h.observe(value)

    def register_collector(self, name: str, snapshot_fn):
        """Register a zero-arg callable returning a dict; re-registering a
        name replaces it (a reset layer re-registers its fresh telemetry).
        """
        if not callable(snapshot_fn):
            raise TypeError(f"collector {name!r} must be callable")
        self._collectors[name] = snapshot_fn

    def snapshot(self) -> dict:
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {k: h.snapshot()
                           for k, h in sorted(self.histograms.items())},
            "collectors": {k: fn() for k, fn in
                           sorted(self._collectors.items())},
        }
