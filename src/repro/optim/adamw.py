"""Decoupled AdamW + warmup-cosine schedule + global-norm clipping.

Functional (optax-free): opt state is a plain pytree so the distributed layer
can shard it (ZeRO-1) and the checkpoint manager can serialize it. Master
moments are always fp32 even with bf16 params.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0


def cosine_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac * cfg.lr + (1 - cfg.min_lr_frac) * cfg.lr * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """-> (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9)) \
        if cfg.clip_norm > 0 else jnp.float32(1.0)
    lr = cosine_lr(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        step_v = mhat / (jnp.sqrt(nhat) + cfg.eps)
        new_p = p.astype(jnp.float32) - lr * (step_v + cfg.weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_mu = jax.tree_util.tree_leaves(state["mu"])
    flat_nu = jax.tree_util.tree_leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_state = {
        "mu": jax.tree_util.tree_unflatten(tdef, [o[1] for o in out]),
        "nu": jax.tree_util.tree_unflatten(tdef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gn, "lr": lr}
