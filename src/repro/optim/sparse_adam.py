"""Lazy (touched-rows-only) AdamW for sparse embedding tables.

Dense AdamW reads+writes every table row every step: 34x table bytes of HBM
traffic (§Roofline's recsys memory term). Production recsys systems update
only the rows touched by the batch (FBGEMM-style). This module does that in
pure JAX with fixed shapes:

  1. flatten this batch's (field, id) pairs -> sort -> segment-reduce dup
     rows' grads (duplicates within a batch MUST be summed, not raced);
  2. gather moments for <= B*F unique rows, run the Adam math on those rows;
  3. scatter params/moments back (`mode=drop` for padding).

Semantics = "lazy Adam": untouched rows keep stale moments and skip weight
decay — the standard trade (TF LazyAdam, torch SparseAdam). With weight_decay
= 0 and every row touched, it is bit-identical to dense AdamW (tested).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .adamw import AdamWConfig, cosine_lr


def dedup_row_grads(flat_ids, grad_rows, n_rows: int):
    """Sum duplicate rows' gradients.

    flat_ids int32[N]; grad_rows f32[N, D] -> (uniq_ids int32[N] padded with
    ``n_rows`` sentinel, uniq_grads f32[N, D], valid bool[N]).
    """
    N = flat_ids.shape[0]
    order = jnp.argsort(flat_ids)
    s_ids = flat_ids[order]
    s_g = grad_rows[order]
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), s_ids[1:] != s_ids[:-1]])
    seg = jnp.cumsum(is_start.astype(jnp.int32)) - 1          # [N]
    uniq_g = jax.ops.segment_sum(s_g, seg, num_segments=N)    # [N, D]
    uniq_ids = jnp.full((N,), n_rows, jnp.int32).at[seg].set(s_ids)
    valid = jnp.arange(N) <= seg[-1]
    uniq_ids = jnp.where(valid, uniq_ids, n_rows)
    return uniq_ids, uniq_g, valid


def sparse_table_update(cfg: AdamWConfig, table, grad_rows, flat_ids,
                        mu, nu, step):
    """Lazy-Adam update of ``table`` [R, D] at this batch's rows.

    grad_rows f32[N, D] are d(loss)/d(gathered rows); flat_ids int32[N].
    mu/nu f32[R, D]. Returns (table', mu', nu').
    """
    R, D = table.shape
    uniq_ids, uniq_g, valid = dedup_row_grads(flat_ids, grad_rows, R)
    idx = jnp.minimum(uniq_ids, R - 1)
    lr = cosine_lr(cfg, step)
    t = step.astype(jnp.float32)
    b1c = 1 - cfg.b1 ** t
    b2c = 1 - cfg.b2 ** t
    mu_rows = mu[idx]
    nu_rows = nu[idx]
    g = uniq_g.astype(jnp.float32)
    mu_new = cfg.b1 * mu_rows + (1 - cfg.b1) * g
    nu_new = cfg.b2 * nu_rows + (1 - cfg.b2) * g * g
    upd = (mu_new / b1c) / (jnp.sqrt(nu_new / b2c) + cfg.eps)
    p_rows = table[idx].astype(jnp.float32)
    p_new = p_rows - lr * (upd + cfg.weight_decay * p_rows)
    # scatter back; sentinel ids land out of range -> dropped
    table = table.at[uniq_ids].set(p_new.astype(table.dtype), mode="drop")
    mu = mu.at[uniq_ids].set(mu_new, mode="drop")
    nu = nu.at[uniq_ids].set(nu_new, mode="drop")
    return table, mu, nu
