from .adamw import AdamWConfig, init_opt_state, adamw_update, cosine_lr  # noqa: F401
