"""QAC serving launcher: build an index from a (synthetic) log and serve
batched completions — the paper's system end-to-end.

  PYTHONPATH=src python -m repro.launch.serve --queries 20000 --batch 256 \
      [--stripes 4] [--routed] [--interactive "bmw i3 s"]

Online mode (ISSUE 4) replays a keystroke-per-session trace through the
deadline-aware micro-batching runtime + prefix/session caches and prints
latency telemetry; ``--check`` additionally asserts bit-identical parity
against naive one-request-per-dispatch serving and a nonzero hit rate
(the CI smoke in scripts/check_seed.sh):

  PYTHONPATH=src python -m repro.launch.serve --online --queries 3000 \
      --sessions 64 [--check] [--slack-us 20000] [--max-batch 64]

Cluster mode (ISSUE 8) serves the same trace through N runtime replicas
behind the session-affinity dispatcher with SLA-class admission control
(serve/cluster.py); ``--drill`` kills replica 0 mid-trace (with recovery)
and ``--check`` asserts every served answer bit-identical to the uncached
frontend oracle, nonzero re-routed traffic under the drill, and continued
post-failover service:

  PYTHONPATH=src python -m repro.launch.serve --online --cluster 2 \
      --queries 3000 --sessions 64 [--drill] [--check]

Freshness mode (ISSUE 9) replays keystroke traffic interleaved with live
corpus mutations (inserts + trend spikes) through the generational serving
layer (serve/freshness.py): delta-tier absorption, exact k-way merge, and
rebuild-and-swap mid-trace; ``--check`` asserts time-indexed bit-parity of
every sampled answer against a from-scratch rebuild at its visible
(generation, seq) version, at least one swap, exactly-once cache
invalidation per swap, and nonzero delta-tier hits:

  PYTHONPATH=src python -m repro.launch.serve --freshness --queries 3000 \
      --sessions 32 [--mutations 24] [--swap-threshold 8] [--check]
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.text import (SynthLogConfig, generate_query_log,
                        KeystrokeTraceConfig, generate_keystroke_trace)
from repro.core import build_qac_index, parse_queries, corpus_stats, INF_DOCID
from repro.core.builder import build_corpus
from repro.core.striped import build_striped
from repro.obs.metrics import fmt
from repro.serve.qac import qac_serve_step, qac_serve_striped
from repro.serve.frontend import QACFrontend
from repro.serve.runtime import (QACOnlineRuntime,
                                 prepare_requests, run_naive_trace)
from repro.configs.qac_common import QACArch
from repro.core.strings import decode_string


def _make_obs(args):
    """The ``--observe`` stack from the arch preset + CLI overrides:
    (ObsConfig, Tracer, JitAuditor, MetricsRegistry)."""
    import dataclasses

    ocfg = QACArch(k=args.k).obs_config()
    if args.trace_sample is not None:
        ocfg = dataclasses.replace(ocfg,
                                   trace_sample_every=args.trace_sample)
    tracer = ocfg.tracer()
    auditor = ocfg.auditor(tracer=tracer)
    registry = ocfg.registry()
    registry.register_collector("jit", auditor.snapshot)
    return ocfg, tracer, auditor, registry


def _export_trace(args, tracer) -> None:
    if not args.trace_out:
        return
    if args.trace_out.endswith(".jsonl"):
        path, kind = tracer.to_jsonl(args.trace_out), "jsonl"
    else:
        path, kind = tracer.to_chrome(args.trace_out), "chrome"
    print(f"[serve] observe: wrote {kind} trace ({len(tracer.spans)} spans,"
          f" {len(tracer.instants)} instants) to {path}")


def _print_slo(ocfg, slo) -> None:
    ev = slo.evaluate()
    worst = max((a for a in ev["alerts"] if a["long_burn"] is not None),
                key=lambda a: a["long_burn"], default=None)
    print(f"[serve] observe SLO: {ev['n_violations']}/{ev['n_requests']} "
          f"over {ocfg.slo_target_us / 1e3:.0f}ms "
          f"(compliance={fmt(ev['compliance'], nd=4)} vs objective "
          f"{ev['objective']}), firing={ev['firing']}"
          + (f", worst long-window burn={worst['long_burn']:.2f} "
             f"@{worst['long_window_us'] / 3.6e9:.1f}h" if worst else ""))


def run_online(args, qidx, kept) -> None:
    trace = generate_keystroke_trace(kept, KeystrokeTraceConfig(
        n_sessions=args.sessions, mean_keystroke_ms=args.keystroke_ms,
        seed=0))
    reqs = prepare_requests(qidx, trace, k=args.k)
    print(f"[serve] online trace: {len(reqs)} keystroke requests over "
          f"{args.sessions} concurrent sessions")
    # the arch config carries the runtime knobs (QACArch.online_*); CLI
    # flags override the scheduler pair for experiments
    cfg = QACArch(k=args.k).runtime_config()
    if args.max_batch is not None:
        cfg.max_batch = args.max_batch
    if args.slack_us is not None:
        cfg.slack_us = args.slack_us
    ocfg = tracer = auditor = registry = None
    if args.observe:
        ocfg, tracer, auditor, registry = _make_obs(args)
    # closed jit-variant space for online traffic: global list_pad, no
    # per-bucket specialization (see QACFrontend.specialize_list_pad)
    frontend = QACFrontend(qidx, k=args.k, specialize_list_pad=False,
                           auditor=auditor)
    rt = QACOnlineRuntime(frontend, cfg, tracer=tracer, registry=registry)
    if args.observe:
        # the measured-replay protocol with the obs twist: the warm pass
        # compiles every jit variant the trace can form, then the trace is
        # cleared and the auditor frozen, so the measured pass is steady
        # state BY ASSERTION (any further compile is a flagged violation)
        rt.warmup(reqs)
        rt.run_trace(reqs)
        rt.reset()
        tracer.clear()
        auditor.freeze()
        results = rt.run_trace(reqs)
    else:
        results = rt.replay(reqs)
    s = rt.telemetry.snapshot()
    print(f"[serve] online: p50={fmt(s['p50_us'])}us "
          f"p95={fmt(s['p95_us'])}us p99={fmt(s['p99_us'])}us "
          f"mean={fmt(s['mean_us'])}us "
          f"hit_rate={s['cache_hit_rate']:.2f} paths={s['paths']}")
    print(f"[serve] online: {s['n_batches']} batches "
          f"(mean size {fmt(s['mean_batch_size'], nd=1)}, "
          f"hist {s['batch_hist']}), "
          f"triggers={s['triggers']}, queue_peak={s['queue_peak']}, "
          f"engine_wall={s['engine_wall_us']/1e3:.1f}ms")
    if args.observe:
        aud = auditor.snapshot()
        print(f"[serve] observe: {len(tracer.spans)} spans + "
              f"{len(tracer.instants)} instants at 1/"
              f"{tracer.sample_every} sampling; jit variants="
              f"{aud['n_variants']} (compile wall "
              f"{aud['compile_wall_us_total']/1e3:.0f}ms, all pre-freeze), "
              f"post-freeze compiles={aud['n_violations']}")
        slo = ocfg.slo_monitor()
        for r in reqs:
            done = rt.done_t_us[r.idx]
            slo.observe(done, done - r.t_us)
        _print_slo(ocfg, slo)
        _export_trace(args, tracer)
    if args.check:
        # same (warm) frontend: complete() is pure, so the reference is
        # identical and the B=1 jit variants aren't compiled twice
        naive_rows, naive = run_naive_trace(frontend, reqs)
        for i, (g, w) in enumerate(zip(results, naive_rows)):
            assert np.array_equal(g, w), (
                f"online-runtime parity break at request {i} "
                f"({reqs[i].query!r}): {g} != {w}")
        assert s["cache_hit_rate"] > 0, "expected a nonzero cache hit rate"
        if args.observe:
            from repro.obs.tracing import request_trees

            assert tracer.spans, "observe produced no spans"
            # zero unexpected compiles in steady state: the warm pass must
            # have closed the jit-variant space
            auditor.assert_closed()
            # every sampled root span's duration is the telemetry latency
            # for that request, exactly (same clock arithmetic)
            trees = request_trees(tracer.spans)
            assert trees, "observe produced no request roots"
            for idx, (root, _) in trees.items():
                lat = rt.done_t_us[idx] - reqs[idx].t_us
                assert abs(root["dur_us"] - lat) < 1e-6, (
                    f"request {idx}: root span {root['dur_us']}us vs "
                    f"telemetry {lat}us")
            print(f"[serve] observe check OK: {len(trees)} sampled request "
                  f"trees match telemetry; jit-variant space closed "
                  f"({aud['n_variants']} variants, 0 post-freeze)")
        print(f"[serve] online check OK: {len(reqs)} requests bit-identical "
              f"to one-request-per-dispatch serving "
              f"(naive mean={fmt(naive['mean_us'])}us, "
              f"speedup={(naive['mean_us'] or 0)/max(s['mean_us'] or 1e-9, 1e-9):.2f}x)")


def run_cluster(args, qidx, kept) -> None:
    from repro.runtime.fault import FaultInjector, ReplicaFault
    from repro.serve.cluster import (QACServingCluster, assign_sla,
                                     check_cluster_parity)

    trace = generate_keystroke_trace(kept, KeystrokeTraceConfig(
        n_sessions=args.sessions, mean_keystroke_ms=args.keystroke_ms,
        seed=0))
    reqs = prepare_requests(qidx, trace, k=args.k)
    sla = assign_sla(reqs, bulk_fraction=0.25)
    arch = QACArch(k=args.k)
    rt_cfg = arch.runtime_config()
    if args.max_batch is not None:
        rt_cfg.max_batch = args.max_batch
    if args.slack_us is not None:
        rt_cfg.slack_us = args.slack_us
    cl_cfg = arch.cluster_config(n_replicas=args.cluster)
    injector = None
    t_kill = t_up = None
    if args.drill:
        # kill replica 0 mid-trace, recover after 2 heartbeat timeouts —
        # the drill exercises detection, failover AND re-admission
        t_kill = reqs[len(reqs) // 2].t_us
        t_up = t_kill + 2 * cl_cfg.heartbeat_timeout_us
        injector = FaultInjector([], replica_faults=[
            ReplicaFault(0, t_kill, t_up)])
    ocfg = tracer = auditor = registry = None
    if args.observe:
        ocfg, tracer, auditor, registry = _make_obs(args)
    # ONE warm frontend shared by every replica: complete() is pure, so
    # sharing cannot change results, and the jit variants compile once
    frontend = QACFrontend(qidx, k=args.k, specialize_list_pad=False,
                           auditor=auditor)
    cluster = QACServingCluster(qidx, cl_cfg, rt_cfg,
                                frontends=[frontend] * args.cluster,
                                injector=injector, tracer=tracer,
                                registry=registry)
    print(f"[serve] cluster: {args.cluster} replicas, {len(reqs)} requests, "
          f"{sum(s == 'bulk' for s in sla)} bulk"
          + (f", drill kill@{t_kill/1e3:.0f}ms up@{t_up/1e3:.0f}ms"
             if args.drill else ""))
    if args.observe:
        cluster.run_trace(reqs, sla)         # warm pass compiles everything
        cluster.reset()
        tracer.clear()
        auditor.freeze()
        results = cluster.run_trace(reqs, sla)
    else:
        results = cluster.replay(reqs, sla)
    s = cluster.telemetry.snapshot()
    print(f"[serve] cluster: served={s['served']} rejected={s['rejected']} "
          f"(shed_rate={s['shed_rate']:.3f}, degrade_rate="
          f"{s['degrade_rate']:.3f}) per_replica={s['per_replica']}")
    print(f"[serve] cluster: interactive p50={fmt(s['interactive_p50_us'])}"
          f"us p99={fmt(s['interactive_p99_us'])}us | bulk "
          f"p99={fmt(s['bulk_p99_us'])}us | sheds={s['shed']}")
    if args.drill:
        print(f"[serve] cluster: deaths={s['deaths']} "
              f"readmissions={s['readmissions']} rerouted={s['rerouted']} "
              f"failover_p99={fmt(s['failover_p99_us'])}us")
    if args.observe:
        n_adm = sum(1 for e in tracer.instants if e["name"] == "admission")
        print(f"[serve] observe: {len(tracer.spans)} spans + "
              f"{len(tracer.instants)} instants ({n_adm} admission "
              f"decisions sampled); post-freeze compiles="
              f"{len(auditor.violations)}")
        _export_trace(args, tracer)
        if args.check:
            assert tracer.spans, "observe produced no spans"
            auditor.assert_closed()
    if args.check:
        n = check_cluster_parity(frontend, reqs, results)
        assert n > 0, "no served results to check"
        if args.drill:
            assert s["rerouted"] > 0, "drill produced no re-routed traffic"
            assert s["deaths"], "drill death was never detected"
            # availability: the surviving replicas kept serving requests
            # that ARRIVED after the kill
            post = [r for q, r in zip(reqs, results)
                    if q.t_us > t_kill and r.status == "ok"]
            assert post, "no requests served after the kill"
        print(f"[serve] cluster check OK: {n} served answers bit-identical "
              f"to the uncached frontend oracle"
              + (f", {s['rerouted']} re-routed" if args.drill else ""))


def run_freshness(args, kept, kscores) -> None:
    """``kept``/``kscores`` are the canonical deduped corpus from the base
    build — the mutation trace draws targets (and trend spikes' old
    scores) from it, and the generational layer rebuilds from it."""
    from repro.serve.freshness import FreshnessConfig, GenerationalQAC
    from repro.text import MutationTraceConfig, generate_mutation_trace

    n_mut = args.mutations
    swap_thr = (args.swap_threshold if args.swap_threshold is not None
                else max(2, n_mut // 3))
    arch = QACArch(k=args.k)
    fr_cfg = FreshnessConfig(
        k=args.k,
        delta_capacity=max(arch.freshness_delta_capacity, swap_thr),
        swap_threshold=swap_thr)
    rt_cfg = arch.runtime_config()
    if args.max_batch is not None:
        rt_cfg.max_batch = args.max_batch
    if args.slack_us is not None:
        rt_cfg.slack_us = args.slack_us
    events = generate_mutation_trace(kept, kscores, MutationTraceConfig(
        keystrokes=KeystrokeTraceConfig(
            n_sessions=args.sessions, mean_keystroke_ms=args.keystroke_ms,
            seed=0),
        n_mutations=n_mut, seed=0))
    n_req = sum(1 for e in events if e.kind == "request")
    print(f"[serve] freshness trace: {n_req} requests + "
          f"{len(events) - n_req} mutations, swap_threshold={swap_thr}")
    tracer = registry = None
    if args.observe:
        # no jit auditor here: a mid-trace rebuild-and-swap legitimately
        # compiles the new generation's variants (billed to the background
        # rebuild), so the closed-variant invariant is per-generation, not
        # per-trace — the tracer's generation.rebuild spans carry the cost
        _, tracer, _, registry = _make_obs(args)
    gq = GenerationalQAC(kept, kscores, cfg=fr_cfg, rt_cfg=rt_cfg,
                         tracer=tracer, registry=registry)
    if args.observe:
        gq.run_mutation_trace(events)        # warm pass
        gq.reset()
        tracer.clear()
        results = gq.run_mutation_trace(events)
    else:
        results = gq.replay(events)
    s = gq.snapshot()
    rts = s["runtime"]
    print(f"[serve] freshness: generation={s['generation']} "
          f"swaps={s['n_swaps']} outcomes={s['mutation_outcomes']} "
          f"delta_hit_answers={s['delta_hit_answers']} "
          f"escalations={s['escalations']}")
    print(f"[serve] freshness: apply_p99={s['apply_p99_us']:.0f}us "
          f"swap_stall_p99={s['swap_stall_p99_us']/1e3:.1f}ms "
          f"rebuilds={[f'{r/1e3:.0f}ms' for r in s['rebuild_wall_us']]} "
          f"hit_rate={rts['cache_hit_rate']:.2f}")
    print(f"[serve] freshness: per_generation={rts['per_generation']} "
          f"invalidations={rts['invalidations']}")
    if args.observe:
        merges = sum(1 for sp in tracer.spans if sp["name"] == "merge.kway")
        print(f"[serve] observe: {len(tracer.spans)} spans + "
              f"{len(tracer.instants)} instants ({merges} k-way merges "
              f"sampled)")
        _export_trace(args, tracer)
        if args.check:
            assert tracer.spans, "observe produced no spans"
    if args.check:
        assert s["n_swaps"] >= 1, "trace produced no generation swap"
        assert s["delta_hit_answers"] > 0, \
            "no answer was served from the delta tier"
        for key, inv in rts["invalidations"].items():
            assert inv["count"] == 1, \
                f"swap {key} invalidated caches {inv['count']} times"
        assert len(rts["invalidations"]) == s["n_swaps"], \
            "each swap must invalidate the cache tiers exactly once"
        n = gq.check_parity(results, sample_every=max(1, len(results) // 200))
        print(f"[serve] freshness check OK: {n} sampled answers bit-identical"
              f" to from-scratch rebuilds at their visible versions, "
              f"{s['n_swaps']} swaps each invalidating caches exactly once")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=20_000)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--stripes", type=int, default=0)
    ap.add_argument("--routed", action="store_true",
                    help="serve through the class-routed QACFrontend "
                         "(host partition by query class) instead of the "
                         "fused both-engines step")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--interactive", default=None,
                    help="serve one literal partial query and print strings")
    ap.add_argument("--online", action="store_true",
                    help="replay a keystroke-session trace through the "
                         "micro-batching runtime (serve/runtime.py) and "
                         "print latency telemetry")
    ap.add_argument("--sessions", type=int, default=64,
                    help="concurrent keystroke sessions in --online mode")
    ap.add_argument("--keystroke-ms", type=float, default=150.0)
    ap.add_argument("--slack-us", type=float, default=None,
                    help="micro-batch deadline slack per request "
                         "(default: QACArch.online_slack_us)")
    ap.add_argument("--max-batch", type=int, default=None,
                    help="micro-batch size cap "
                         "(default: QACArch.online_max_batch)")
    ap.add_argument("--check", action="store_true",
                    help="--online only: assert bit-identical parity vs "
                         "naive per-request dispatch + nonzero hit rate")
    ap.add_argument("--cluster", type=int, default=0,
                    help="with --online: serve through a QACServingCluster "
                         "with this many replicas (serve/cluster.py)")
    ap.add_argument("--drill", action="store_true",
                    help="--cluster only: kill replica 0 mid-trace and "
                         "exercise detection/failover/re-admission")
    ap.add_argument("--freshness", action="store_true",
                    help="replay keystroke traffic + live corpus mutations "
                         "through the generational serving layer "
                         "(serve/freshness.py): delta tier, k-way merge, "
                         "mid-trace rebuild-and-swap")
    ap.add_argument("--mutations", type=int, default=24,
                    help="--freshness: mutation events (inserts + trend "
                         "spikes) interleaved into the trace")
    ap.add_argument("--swap-threshold", type=int, default=None,
                    help="--freshness: visible delta changes before a "
                         "rebuild-and-swap (default: ~mutations/3, so a "
                         "default trace swaps at least once)")
    ap.add_argument("--observe", action="store_true",
                    help="attach the observability stack (request tracing, "
                         "metrics registry, jit-variant audit, SLO burn "
                         "monitor) to --online/--cluster/--freshness; with "
                         "--check also asserts nonzero spans, a closed "
                         "jit-variant space in steady state, and span/"
                         "telemetry agreement")
    ap.add_argument("--trace-out", default=None,
                    help="--observe: write the measured pass's trace "
                         "(.jsonl = span records for scripts/obs_report.py;"
                         " any other suffix = Chrome/Perfetto trace-event "
                         "JSON for chrome://tracing)")
    ap.add_argument("--trace-sample", type=int, default=None,
                    help="--observe: trace every Nth request (default: "
                         "QACArch.obs_trace_sample_every)")
    args = ap.parse_args()

    print(f"[serve] generating {args.queries} synthetic scored queries ...")
    qs, sc = generate_query_log(SynthLogConfig(n_queries=args.queries))
    t0 = time.time()
    qidx, kept, scores = build_qac_index(qs, sc)
    stats = corpus_stats(kept)
    print(f"[serve] built index in {time.time()-t0:.1f}s: "
          f"{stats.n_queries} completions, {stats.n_unique_terms} terms, "
          f"{stats.avg_terms_per_query:.2f} terms/query")

    if args.freshness:
        run_freshness(args, kept, scores)
        return

    if args.online:
        if args.cluster > 0:
            run_cluster(args, qidx, kept)
        else:
            run_online(args, qidx, kept)
        return

    if args.interactive:
        pids, plen, pok, suf, slen = parse_queries(qidx.dictionary,
                                                   [args.interactive])
        docids = np.asarray(qac_serve_step(qidx, pids, plen, suf, slen,
                                           k=args.k))[0]
        print(f"[serve] completions for {args.interactive!r}:")
        for d in docids:
            if d == INF_DOCID:
                break
            terms, n = qidx.completions.extract(jnp.int32(d))
            chars = qidx.dictionary.extract(terms[: int(n)])
            words = [decode_string(np.asarray(c)) for c in np.asarray(chars)]
            print(f"   #{d:6d}  {' '.join(words)}")
        return

    # throughput run on sampled partial queries
    rng = np.random.default_rng(0)
    partials = []
    for qi in rng.integers(0, len(kept), args.batch):
        toks = kept[qi].split()
        cut = rng.integers(1, len(toks[-1]) + 1)
        partials.append(" ".join(toks[:-1] + [toks[-1][:cut]]))
    pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, partials)

    if args.routed:
        # ROADMAP PR-1 next step: the class-routed frontend as the launcher
        # entry point — host partition by class, per-class jit cache
        frontend = QACFrontend(qidx, k=args.k)
        fn = lambda a, b, c, d: jnp.asarray(frontend.complete(a, b, c, d))
    elif args.stripes > 1:
        dictionary, rows, sc2, _ = build_corpus(qs, sc)
        order = np.lexsort(tuple(rows[:, j] for j in range(rows.shape[1] - 1, -1, -1)) + (-sc2,))
        d_of_row = np.empty(len(rows), dtype=np.int32)
        d_of_row[order] = np.arange(len(rows), dtype=np.int32)
        striped = build_striped(rows, d_of_row, dictionary.n_terms, args.stripes)
        from repro.core.striped import local_heap_kernel_fits
        fit_raw = local_heap_kernel_fits(striped)
        fit_pk = local_heap_kernel_fits(striped, use_packed=True)
        route = ("heap_topk kernel" if (fit_raw or fit_pk)
                 else "per-pop batched RMQ kernel")
        if jax.default_backend() != "tpu":
            route += " on TPU; per-pop XLA query_batch on this backend"
        print(f"[serve] single-term route per stripe: {route}")
        print(f"[serve] heap-kernel VMEM fit per stripe: "
              f"raw CSR {'fits' if fit_raw else 'DOES NOT fit'}, "
              f"compressed ({striped.pp_codec or 'none'}) "
              f"{'fits' if fit_pk else 'DOES NOT fit'}")
        fn = jax.jit(lambda a, b, c, d: qac_serve_striped(
            striped, qidx.dictionary, a, b, c, d, k=args.k))
    else:
        fn = jax.jit(lambda a, b, c, d: qac_serve_step(qidx, a, b, c, d, k=args.k))

    out = fn(pids, plen, suf, slen).block_until_ready()
    t0 = time.time()
    n_rounds = 5
    for _ in range(n_rounds):
        out = fn(pids, plen, suf, slen).block_until_ready()
    dt = (time.time() - t0) / n_rounds
    n_res = int((np.asarray(out) != INF_DOCID).sum())
    mode = "routed" if args.routed else f"stripes={max(args.stripes, 1)}"
    print(f"[serve] batch={args.batch} k={args.k} {mode}: "
          f"{dt/args.batch*1e6:.1f} us/query, {args.batch/dt:.0f} QPS "
          f"(host CPU), {n_res} results")
    if args.routed:
        print(f"[serve] frontend stats: {frontend.stats}")


if __name__ == "__main__":
    main()
