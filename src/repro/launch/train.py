"""Training launcher: real training on the local mesh, any arch.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --steps 50 \
      [--smoke] [--ckpt-dir /tmp/ckpt] [--drill]   # --drill injects a fault
                                                   # and restarts from ckpt

On this CPU container --smoke (reduced config) is the default; the same code
path drives the production mesh when devices exist. Demonstrates: data
pipeline -> jit'd train step -> checkpoint manager -> fault-tolerant driver.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch, list_archs
from repro.optim.adamw import AdamWConfig
from repro.train.steps import init_train_state, make_lm_train_step, \
    make_gnn_train_step, make_recsys_train_step
from repro.ckpt import CheckpointManager
from repro.runtime import TrainDriver, FaultInjector, StepMonitor


def make_lm_setup(arch, steps):
    from repro.data.lm import TokenStream, lm_batches
    model = arch.smoke_model()
    stream = TokenStream.synthetic(vocab=model.cfg.vocab, n_docs=50)
    batches = lm_batches(stream, batch=8, seq_len=64)
    step_fn = jax.jit(make_lm_train_step(model, AdamWConfig(
        lr=3e-3, total_steps=steps, warmup_steps=max(steps // 20, 1))))
    params = model.init_params(jax.random.PRNGKey(0))

    def next_batch():
        t, y, m = next(batches)
        return {"tokens": jnp.asarray(t), "targets": jnp.asarray(y),
                "mask": jnp.asarray(m)}

    return model, params, step_fn, next_batch


def make_gnn_setup(arch, steps):
    from repro.models.mace import MACEModel
    from repro.data.graphs import batch_molecules
    model = MACEModel(arch.smoke_cfg)
    rng = np.random.default_rng(0)
    step_fn = jax.jit(make_gnn_train_step(
        model, AdamWConfig(lr=1e-3, total_steps=steps), task="energy",
        n_graphs=8))
    params = model.init_params(jax.random.PRNGKey(0))

    def next_batch():
        pos, sp, nm, s, r, em, gi = batch_molecules(rng, 8, 8, 16, 8)
        return {"positions": jnp.asarray(pos), "node_feat": jnp.asarray(sp),
                "node_mask": jnp.asarray(nm), "senders": jnp.asarray(s),
                "receivers": jnp.asarray(r), "edge_mask": jnp.asarray(em),
                "graph_ids": jnp.asarray(gi),
                "targets": jnp.asarray(rng.normal(size=8), jnp.float32)}

    return model, params, step_fn, next_batch


def make_recsys_setup(arch, steps):
    from repro.configs.recsys_common import MODEL_CLS
    from repro.data.recsys_data import recsys_batch
    cfg = arch.smoke_cfg
    model = MODEL_CLS[cfg.kind](cfg)
    rng = np.random.default_rng(0)
    step_fn = jax.jit(make_recsys_train_step(
        model, AdamWConfig(lr=1e-3, total_steps=steps)))
    params = model.init_params(jax.random.PRNGKey(0))

    def next_batch():
        feats, labels = recsys_batch(cfg, 64, rng)
        return {"feats": {k: jnp.asarray(v) for k, v in feats.items()},
                "labels": jnp.asarray(labels)}

    return model, params, step_fn, next_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=list_archs())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--drill", action="store_true",
                    help="inject a fault mid-run and restart from checkpoint")
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if arch.family == "lm":
        model, params, step_fn, next_batch = make_lm_setup(arch, args.steps)
    elif arch.family == "gnn":
        model, params, step_fn, next_batch = make_gnn_setup(arch, args.steps)
    elif arch.family == "recsys":
        model, params, step_fn, next_batch = make_recsys_setup(arch, args.steps)
    else:
        raise SystemExit("use launch/serve.py for the qac arch")

    state = init_train_state(params)
    mgr = CheckpointManager(args.ckpt_dir, keep=2, async_save=True)
    inject = FaultInjector([args.steps // 2] if args.drill else [])
    monitor = StepMonitor()
    losses = []

    def step(s, i):
        inject.check(i)
        s, metrics = step_fn(s, next_batch())
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
        losses.append(float(metrics["loss"]))
        return s

    def save(s, i):
        mgr.save(i, s)

    def restore():
        got, i = mgr.restore(state)
        print(f"[driver] restored from step {i}")
        return got, i

    driver = TrainDriver(step, save, restore, ckpt_every=args.ckpt_every,
                         monitor=monitor)
    t0 = time.time()
    state, final = driver.run(state, 0, args.steps)
    mgr.wait()
    print(f"done: {final} steps in {time.time()-t0:.1f}s, "
          f"restarts={driver.restarts}, stragglers={len(monitor.stragglers)}, "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
