import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                       # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b      # one arch
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  ... [--single-pod-only | --multi-pod-only] [--out results.json]

Per cell x {single-pod 16x16, multi-pod 2x16x16}:
  jit(step, in_shardings, out_shardings).lower(*specs).compile()
  -> memory_analysis(), cost_analysis(), collective-bytes parse (§Roofline).

Results go to launch/dryrun_results/<mesh>/<arch>__<shape>.json and feed
EXPERIMENTS.md §Dry-run and §Roofline.
"""
import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs import get_arch, list_archs, all_cells  # noqa: E402
from repro.launch.mesh import make_production_mesh         # noqa: E402
from repro.launch.roofline import analyze, collective_bytes  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "dryrun_results")


def run_cell(arch_id: str, shape: str, multi_pod: bool, out_dir: str,
             keep_hlo: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    arch = get_arch(arch_id)
    cell = next(c for c in arch.cells() if c.shape == shape)
    rec = {"arch": arch_id, "shape": shape, "mesh": mesh_name,
           "kind": cell.kind, "ok": False}
    if cell.skip:
        rec.update(ok=True, skipped=cell.skip)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_chips = 1
        for a in mesh.axis_names:
            n_chips *= mesh.shape[a]
        low = arch.lowerable(shape, mesh)
        jitted = jax.jit(
            low.fn,
            in_shardings=low.in_shardings,
            out_shardings=low.out_shardings,
            donate_argnums=low.donate_argnums,
        )
        lowered = jitted.lower(*low.arg_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        terms = analyze(compiled, hlo, n_chips, low.model_flops, low.model_bytes)
        rec.update(
            ok=True,
            note=low.note,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            n_chips=n_chips,
            bytes_per_device={
                "argument": getattr(mem, "argument_size_in_bytes", None),
                "output": getattr(mem, "output_size_in_bytes", None),
                "temp": getattr(mem, "temp_size_in_bytes", None),
                "peak": getattr(mem, "peak_memory_in_bytes", None),
            },
            hlo_flops_per_partition=terms.hlo_flops_pp,
            hlo_bytes_per_partition=terms.hlo_bytes_pp,
            collective_bytes=terms.coll_bytes,
            n_collectives=terms.n_collectives,
            collective_breakdown=collective_bytes(hlo),
            compute_s=terms.compute_s,
            memory_s=terms.memory_s,
            collective_s=terms.collective_s,
            dominant=terms.dominant,
            model_flops=low.model_flops,
            model_bytes=low.model_bytes,
            roofline_frac=terms.roofline_frac,
        )
        if keep_hlo:
            with open(os.path.join(out_dir, f"{arch_id}__{shape}.hlo"), "w") as f:
                f.write(hlo)
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list_archs() + [None])
    ap.add_argument("--shape", default=None)
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = all_cells()
    if args.arch:
        cells = [c for c in cells if c.arch == args.arch]
    if args.shape:
        cells = [c for c in cells if c.shape == args.shape]
    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)

    all_recs = []
    for multi in meshes:
        mesh_name = "pod2x16x16" if multi else "pod16x16"
        out_dir = os.path.join(RESULTS_DIR, mesh_name)
        os.makedirs(out_dir, exist_ok=True)
        for c in cells:
            print(f"[dryrun] {mesh_name} {c.arch} x {c.shape} ...", flush=True)
            rec = run_cell(c.arch, c.shape, multi, out_dir, args.keep_hlo)
            path = os.path.join(out_dir, f"{c.arch}__{c.shape}.json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            status = ("SKIP " + rec.get("skipped", "")[:40] if "skipped" in rec
                      else ("ok" if rec["ok"] else "FAIL " + rec.get("error", "")[:120]))
            extra = ""
            if rec.get("ok") and "dominant" in rec:
                extra = (f" dom={rec['dominant']} "
                         f"t={max(rec['compute_s'], rec['memory_s'], rec['collective_s']):.2e}s"
                         f" peak={(rec['bytes_per_device']['peak'] or 0)/2**30:.2f}GiB")
            print(f"[dryrun]   -> {status}{extra}", flush=True)
            all_recs.append(rec)

    n_fail = sum(1 for r in all_recs if not r["ok"])
    print(f"[dryrun] {len(all_recs)} cells, {n_fail} failures")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(all_recs, f, indent=1)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
