"""Roofline-term derivation from compiled dry-run artifacts.

Per (arch x shape x mesh), three terms in seconds (TPU v5e constants):
  compute    = MODEL_FLOPS / (chips x 197e12)        [analytic 6ND-style]
  memory     = MODEL_BYTES / (chips x 819e9)         [analytic minimum traffic]
  collective = collective_bytes_per_device / 50e9    [parsed from HLO]

Why analytic FLOPs/bytes: XLA's compiled.cost_analysis() on the host platform
reports *per-partition* numbers and counts while-loop (lax.scan) bodies ONCE
— for a 94-layer scanned transformer that is a ~100x undercount. We verified
this with a calibration experiment (see EXPERIMENTS.md §Roofline). So the
compute/memory numerators are analytic per-cell (the standard MFU practice),
and cost_analysis is kept as a per-partition diagnostic.

Why the collective parse multiplies loop trip counts: collectives inside the
layer scan (TP all-reduces, MoE combine-psums) execute once per layer. The
parser splits the optimized HLO into computations, walks from ENTRY through
`while` ops, extracts each loop's trip count from its condition computation
(largest integer constant in the ROOT compare), and multiplies nested
collective bytes accordingly.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_KTC_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> dict:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        m = _COMP_HDR.match(line.strip())
        if m and (line.rstrip().endswith("{")):
            cur = m.group(2)
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line)
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    consts = []
    for line in cond_lines:
        consts += [int(x) for x in _CONST_RE.findall(line)]
    return max(consts) if consts else 1


def _direct_collectives(lines: list[str]) -> dict:
    out = {k: 0 for k in COLLECTIVES}
    out["count"] = 0
    for line in lines:
        s = line.strip()
        for kind in COLLECTIVES:
            if f" {kind}(" in s or f" {kind}-start(" in s:
                eq = s.split("=", 1)
                if len(eq) != 2:
                    continue
                shape_part = eq[1].split(kind)[0]
                out[kind] += _shape_bytes(shape_part)
                out["count"] += 1
                break
    return out


def collective_bytes(hlo_text: str) -> dict:
    """While-trip-count-aware collective byte totals (per device)."""
    comps = _split_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR.match(line.strip())
        if m and m.group(1):
            entry = m.group(2)
            break
    if entry is None and comps:
        entry = next(iter(comps))

    memo: dict[str, dict] = {}

    def walk(name: str, depth: int = 0) -> dict:
        if name in memo or name not in comps or depth > 8:
            return memo.get(name, {k: 0 for k in COLLECTIVES} | {"count": 0})
        lines = comps[name]
        total = _direct_collectives(lines)
        for line in lines:
            m = _WHILE_RE.search(line)
            if m:
                cond, body = m.group(1), m.group(2)
                ktc = _KTC_RE.search(line)          # authoritative when present
                if ktc:
                    trips = int(ktc.group(1))
                else:
                    trips = _trip_count(comps.get(cond, []))
                sub = walk(body, depth + 1)
                for k in total:
                    total[k] += trips * sub[k]
        memo[name] = total
        return total

    return walk(entry) if entry else {k: 0 for k in COLLECTIVES} | {"count": 0}


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: Optional[float]
    model_bytes: Optional[float]
    coll_bytes: float
    n_collectives: int
    hlo_flops_pp: float         # per-partition diagnostic (body-once caveat)
    hlo_bytes_pp: float
    n_chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)  # type: ignore

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_frac(self) -> Optional[float]:
        """Achievable fraction of compute roofline: compute / bound."""
        if self.bound_s <= 0:
            return None
        return self.compute_s / self.bound_s


def analyze(compiled, hlo_text: str, n_chips: int,
            model_flops: Optional[float],
            model_bytes: Optional[float]) -> RooflineTerms:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    coll = collective_bytes(hlo_text)
    cbytes = float(sum(v for k, v in coll.items() if k != "count"))
    mf = model_flops or 0.0
    mb = model_bytes or 0.0
    return RooflineTerms(
        compute_s=mf / (n_chips * PEAK_FLOPS),
        memory_s=mb / (n_chips * HBM_BW),
        collective_s=cbytes / ICI_BW,
        model_flops=model_flops,
        model_bytes=model_bytes,
        coll_bytes=cbytes,
        n_collectives=int(coll["count"]),
        hlo_flops_pp=float(ca.get("flops", 0.0)),
        hlo_bytes_pp=float(ca.get("bytes accessed", 0.0)),
        n_chips=n_chips,
    )
