"""Production mesh construction (multi-pod dry-run contract)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Whatever fits the visible devices (tests / single host)."""
    n = len(jax.devices())
    data = max(n // model, 1)
    return jax.make_mesh((data, model), ("data", "model"))
