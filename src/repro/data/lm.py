"""LM data pipeline: synthetic tokenized corpus with packing + host sharding.

Real-pipeline shape: a memmap-able token stream, fixed-length sequence
packing with document boundaries, shift-by-one labels, per-host sharding for
multi-host data parallelism, and a simple double-buffered prefetch iterator.
"""
from __future__ import annotations

import dataclasses
import threading
import queue

import numpy as np


@dataclasses.dataclass
class TokenStream:
    tokens: np.ndarray           # int32[total]
    doc_bounds: np.ndarray       # int64 offsets

    @staticmethod
    def synthetic(vocab: int, n_docs: int = 200, mean_len: int = 512, seed=0):
        rng = np.random.default_rng(seed)
        lens = np.maximum(8, rng.poisson(mean_len, n_docs))
        # Zipfian unigram stream (skewed like natural text)
        toks = []
        for L in lens:
            t = rng.zipf(1.3, int(L)).astype(np.int64) % (vocab - 2) + 2
            toks.append(t)
        tokens = np.concatenate(toks).astype(np.int32)
        bounds = np.zeros(n_docs + 1, np.int64)
        bounds[1:] = np.cumsum(lens)
        return TokenStream(tokens, bounds)


def lm_batches(stream: TokenStream, batch: int, seq_len: int, *,
               host_id: int = 0, n_hosts: int = 1, seed: int = 0,
               prefetch: int = 2):
    """Yield (tokens, targets, mask) int32[batch, seq_len] forever.

    Packing: contiguous stream slices; host h reads a disjoint strided
    partition (multi-host DP). Prefetch thread keeps `prefetch` batches ready.
    """
    total = len(stream.tokens) - 1
    per = batch * seq_len
    rng = np.random.default_rng(seed + host_id)

    def gen():
        while True:
            starts = rng.integers(0, max(total - seq_len - 1, 1),
                                  size=batch)
            toks = np.stack([stream.tokens[s : s + seq_len] for s in starts])
            tgts = np.stack([stream.tokens[s + 1 : s + seq_len + 1] for s in starts])
            yield toks.astype(np.int32), tgts.astype(np.int32), np.ones_like(toks, np.float32)

    q: queue.Queue = queue.Queue(maxsize=prefetch)
    g = gen()

    def worker():
        while True:
            q.put(next(g))

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        yield q.get()
