from .graphs import (  # noqa: F401
    random_graph, build_csr, neighbor_sample, batch_molecules, synth_positions,
)
from .lm import TokenStream, lm_batches  # noqa: F401
from .recsys_data import recsys_batch  # noqa: F401
