"""Graph data pipeline: synthesis, CSR, fanout neighbor sampling, batching.

``neighbor_sample`` is a real GraphSAGE-style sampler (numpy host side): for
each GNN layer it uniformly samples up to ``fanout[l]`` in-neighbors of the
frontier, emitting a padded edge list per hop. This IS part of the system
(JAX has no sparse neighbor sampling) — the minibatch_lg shape depends on it.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def random_graph(n_nodes: int, n_edges: int, seed: int = 0, power: float = 1.5):
    """Power-law-ish random directed graph; returns (src, dst) int32 arrays."""
    rng = np.random.default_rng(seed)
    # preferential-attachment-like degree skew via zipf on targets
    ranks = rng.zipf(power, size=n_edges).astype(np.int64)
    dst = (ranks - 1) % n_nodes
    src = rng.integers(0, n_nodes, n_edges)
    keep = src != dst
    return src[keep].astype(np.int32), dst[keep].astype(np.int32)


def build_csr(src: np.ndarray, dst: np.ndarray, n_nodes: int):
    """In-neighbor CSR: for node v, neighbors(v) = indices[indptr[v]:indptr[v+1]]."""
    order = np.argsort(dst, kind="stable")
    src_sorted = src[order]
    counts = np.bincount(dst, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    indptr[1:] = np.cumsum(counts)
    return indptr, src_sorted.astype(np.int32)


def neighbor_sample(indptr, indices, seeds: np.ndarray, fanouts, rng):
    """k-hop uniform fanout sampling.

    Returns (nodes, senders, receivers): `nodes` is the union frontier
    (seeds first); edges are indexed into `nodes`; padded edges use sender =
    receiver = 0 with mask 0 — handled by the caller's padding step.
    """
    nodes = list(seeds.tolist())
    node_pos = {int(v): i for i, v in enumerate(nodes)}
    senders, receivers = [], []
    frontier = list(seeds.tolist())
    for f in fanouts:
        nxt = []
        for v in frontier:
            lo, hi = int(indptr[v]), int(indptr[v + 1])
            deg = hi - lo
            if deg == 0:
                continue
            take = min(f, deg)
            sel = rng.choice(deg, size=take, replace=False) + lo
            for u in indices[sel]:
                u = int(u)
                if u not in node_pos:
                    node_pos[u] = len(nodes)
                    nodes.append(u)
                    nxt.append(u)
                senders.append(node_pos[u])
                receivers.append(node_pos[v])
        frontier = nxt
    return (np.asarray(nodes, np.int32), np.asarray(senders, np.int32),
            np.asarray(receivers, np.int32))


def pad_subgraph(nodes, senders, receivers, n_nodes_pad: int, n_edges_pad: int):
    """Pad sampled subgraph to fixed shapes; returns arrays + masks."""
    nn, ne = len(nodes), len(senders)
    nodes_p = np.zeros(n_nodes_pad, np.int32)
    nodes_p[: min(nn, n_nodes_pad)] = nodes[:n_nodes_pad]
    s = np.zeros(n_edges_pad, np.int32)
    r = np.zeros(n_edges_pad, np.int32)
    m = np.zeros(n_edges_pad, np.float32)
    ne = min(ne, n_edges_pad)
    s[:ne], r[:ne], m[:ne] = senders[:ne], receivers[:ne], 1.0
    node_mask = np.zeros(n_nodes_pad, np.float32)
    node_mask[: min(nn, n_nodes_pad)] = 1.0
    return nodes_p, s, r, m, node_mask


def synth_positions(node_ids: np.ndarray) -> np.ndarray:
    """Deterministic unit-sphere positions for graphs without coordinates
    (DESIGN.md §Arch-applicability: Cora/ogbn-products have no 3D geometry)."""
    rng = np.random.default_rng(12345)
    # hash-like: reseed from ids for determinism independent of batch
    g = np.random.default_rng(np.asarray(node_ids, np.uint32) + 1)
    p = g.normal(size=(len(node_ids), 3))
    return (p / np.maximum(np.linalg.norm(p, axis=1, keepdims=True), 1e-9)).astype(np.float32)


def batch_molecules(rng, batch: int, n_nodes: int, n_edges: int, n_species: int,
                    box: float = 4.0):
    """Random molecular batch: positions in a box, radius-graph edges
    (capped at n_edges per molecule), block-diagonal batching."""
    N, E = batch * n_nodes, batch * n_edges
    pos = rng.uniform(0, box, size=(batch, n_nodes, 3)).astype(np.float32)
    species = rng.integers(0, n_species, size=(batch, n_nodes)).astype(np.int32)
    senders = np.zeros(E, np.int32)
    receivers = np.zeros(E, np.int32)
    emask = np.zeros(E, np.float32)
    for b in range(batch):
        d = np.linalg.norm(pos[b][:, None] - pos[b][None], axis=-1)
        np.fill_diagonal(d, np.inf)
        cand = np.argwhere(d < 2.5)
        cand = cand[rng.permutation(len(cand))][:n_edges]
        off = b * n_edges
        nb = b * n_nodes
        senders[off : off + len(cand)] = cand[:, 0] + nb
        receivers[off : off + len(cand)] = cand[:, 1] + nb
        emask[off : off + len(cand)] = 1.0
    graph_ids = np.repeat(np.arange(batch, dtype=np.int32), n_nodes)
    return (pos.reshape(N, 3), species.reshape(N), np.ones(N, np.float32),
            senders, receivers, emask, graph_ids)
