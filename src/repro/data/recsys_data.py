"""Synthetic recsys batches (Zipfian ids, ragged histories)."""
from __future__ import annotations

import numpy as np


def recsys_batch(cfg, batch: int, rng: np.random.Generator):
    """Feature dict + labels matching models/recsys.py contracts."""
    kind = cfg.kind
    if kind == "fm":
        ids = (rng.zipf(1.2, size=(batch, cfg.n_sparse)) - 1) % cfg.field_vocab
        feats = {"sparse_ids": ids.astype(np.int32)}
    else:
        L = cfg.seq_len
        hist = (rng.zipf(1.2, size=(batch, L)) - 1) % cfg.item_vocab
        lens = rng.integers(1, L + 1, size=batch)
        mask = (np.arange(L)[None, :] < lens[:, None]).astype(np.float32)
        feats = {
            "hist_items": hist.astype(np.int32),
            "hist_mask": mask,
            "target_item": ((rng.zipf(1.2, size=batch) - 1) % cfg.item_vocab).astype(np.int32),
        }
        if kind == "din":
            feats["hist_cates"] = (hist % cfg.cate_vocab).astype(np.int32)
            feats["target_cate"] = (feats["target_item"] % cfg.cate_vocab).astype(np.int32)
    labels = rng.integers(0, 2, size=batch).astype(np.float32)
    return feats, labels
