"""Docid-striped QAC index for model-axis sharding (DESIGN.md §4).

Stripe s owns docids with ``docid % n_stripes == s``: every stripe sees every
score band, so stripe-local "first k in docid order" results merge into the
global top-k with one k-wide all-gather + min-k. All stripe arrays are padded
to common shapes and stacked on a leading stripe axis, which shard_map splits
over the ``model`` mesh axis.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .types import INF_DOCID, pytree_dataclass
from .rmq import RangeMin, BLOCK
from .inverted_index import InvertedIndex
from .codecs import PackedPostings, pack_postings


@pytree_dataclass(meta_fields=("n_stripes", "n_terms", "n_local_docs",
                               "postings_pad", "max_terms", "rmq_levels",
                               "rmq_blocks", "pp_codec"))
class StripedQACIndex:
    postings: jnp.ndarray      # int32[S, P_pad] global docids, ascending
    offsets: jnp.ndarray       # int32[S, V+2]
    minimal: jnp.ndarray       # int32[S, V+2]
    fwd_terms: jnp.ndarray     # int32[S, N_loc, M] row = docid // S
    fwd_nterms: jnp.ndarray    # int32[S, N_loc]
    rmq_values: jnp.ndarray    # int32[S, n_pad] (padded minimal)
    rmq_st: jnp.ndarray        # int32[S, levels, nb]
    rmq_ib: jnp.ndarray        # int8[S, IB_LEVELS, n_pad] in-block argmins
    n_stripes: int
    n_terms: int
    n_local_docs: int
    postings_pad: int
    max_terms: int
    rmq_levels: int
    rmq_blocks: int
    # compressed postings, stacked per stripe (ISSUE 7). Every stripe packs
    # its PADDED postings row (common n_post == postings_pad), so the block
    # directory shapes agree across stripes and only the word stream needs
    # zero-padding to a common length. pp_codec None <=> fields absent.
    pp_words: jnp.ndarray | None = None    # int32[S, W_pad]
    pp_base: jnp.ndarray | None = None     # int32[S, NB]
    pp_meta: jnp.ndarray | None = None     # int32[S, NB]
    pp_wordoff: jnp.ndarray | None = None  # int32[S, NB]
    pp_codec: str | None = None


class LocalFwd:
    """Stripe-local forward index exposing the Completions.extract contract."""

    def __init__(self, fwd_terms, fwd_nterms, n_stripes: int):
        self.fwd_terms = fwd_terms          # [N_loc, M]
        self.fwd_nterms = fwd_nterms
        self.n_stripes = n_stripes

    def extract(self, docid):
        n_loc = self.fwd_terms.shape[0]
        row_idx = jnp.clip(docid // self.n_stripes, 0, n_loc - 1)
        valid = (docid >= 0) & (docid < n_loc * self.n_stripes)
        row = jnp.where(valid, self.fwd_terms[row_idx], 0)
        return row, jnp.where(valid, self.fwd_nterms[row_idx], 0)


def build_striped(term_rows: np.ndarray, docid_of_row: np.ndarray,
                  n_terms: int, n_stripes: int,
                  postings_codec: str | None = "ef") -> StripedQACIndex:
    """Host-side: split the corpus into docid stripes and stack.

    ``postings_codec`` ("ef" default / "bitpack" / None) additionally packs
    each stripe's padded postings row into the compressed device layout so
    the shard_map body can route the heap kernel through in-kernel decode.
    """
    term_rows = np.asarray(term_rows, np.int32)
    docid_of_row = np.asarray(docid_of_row, np.int32)
    n, m = term_rows.shape
    n_loc = (n + n_stripes - 1) // n_stripes
    posts, offs, mins, fwds, fnts, rvals, rsts, ribs = [], [], [], [], [], [], [], []
    for s in range(n_stripes):
        keep = (docid_of_row % n_stripes) == s
        # stripe packing happens below on the PADDED rows (common shapes);
        # skip the sub-index's own packing pass
        sub_idx = InvertedIndex.build(term_rows[keep], docid_of_row[keep],
                                      n_terms, postings_codec=None)
        posts.append(np.asarray(sub_idx.postings))
        offs.append(np.asarray(sub_idx.offsets))
        mins.append(np.asarray(sub_idx.minimal))
        fwd = np.zeros((n_loc, m), np.int32)
        fnt = np.zeros((n_loc,), np.int32)
        rows_s = term_rows[keep]
        d_s = docid_of_row[keep] // n_stripes
        fwd[d_s] = rows_s
        fnt[d_s] = (rows_s != 0).sum(1)
        fwds.append(fwd)
        fnts.append(fnt)
        rm = RangeMin.build(np.asarray(sub_idx.minimal))
        rvals.append(np.asarray(rm.values))
        ribs.append(np.asarray(rm.ib))
        rsts.append((np.asarray(rm.st_pos), rm.levels, rm.n_blocks))
    p_pad = max(len(p) for p in posts)
    posts = [np.pad(p, (0, p_pad - len(p)), constant_values=INF_DOCID) for p in posts]
    pk_fields = {}
    if postings_codec is not None:
        # pack the PADDED rows: a shared n_post (== p_pad) keeps n_blocks —
        # and hence packed_lookup's static shapes — identical on every
        # stripe, which shard_map requires. INF pads compress to width-0
        # runs past the first transition block, so the overhead is tiny.
        pks = [pack_postings(p, codec=postings_codec) for p in posts]
        w_pad = max(int(pk.words.shape[0]) for pk in pks)
        pk_fields = dict(
            pp_words=jnp.asarray(np.stack(
                [np.pad(np.asarray(pk.words), (0, w_pad - pk.words.shape[0]))
                 for pk in pks])),
            pp_base=jnp.asarray(np.stack([np.asarray(pk.base) for pk in pks])),
            pp_meta=jnp.asarray(np.stack([np.asarray(pk.meta) for pk in pks])),
            pp_wordoff=jnp.asarray(np.stack(
                [np.asarray(pk.wordoff) for pk in pks])),
            pp_codec=postings_codec,
        )
    levels = max(st[1] for st in rsts)
    nb = max(st[2] for st in rsts)
    sts = []
    for stp, lv, b in rsts:
        stp = np.pad(stp, ((0, levels - lv), (0, nb - b)), mode="edge")
        sts.append(stp)
    return StripedQACIndex(
        postings=jnp.asarray(np.stack(posts)),
        offsets=jnp.asarray(np.stack(offs)),
        minimal=jnp.asarray(np.stack(mins)),
        fwd_terms=jnp.asarray(np.stack(fwds)),
        fwd_nterms=jnp.asarray(np.stack(fnts)),
        rmq_values=jnp.asarray(np.stack(rvals)),
        rmq_st=jnp.asarray(np.stack(sts)),
        rmq_ib=jnp.asarray(np.stack(ribs)),
        n_stripes=n_stripes,
        n_terms=n_terms,
        n_local_docs=n_loc,
        postings_pad=p_pad,
        max_terms=m,
        rmq_levels=levels,
        rmq_blocks=nb,
        **pk_fields,
    )


def local_heap_kernel_fits(striped: StripedQACIndex, *, s: int = 0,
                           use_packed: bool = False,
                           max_bytes: int | None = None) -> bool:
    """Host-side preview of the heap_topk routing for stripe ``s``.

    The single-term engine routes its whole trip loop to the fused heap
    kernel only when the stripe-local RMQ tables + index arrays statically
    fit VMEM (``core.search._heap_kernel_fits``); this mirrors that check on
    the stacked arrays so launchers/benches can report which route the
    shard_map body will take without tracing it. All stripes share padded
    shapes, so the answer is stripe-independent unless a caller probes a
    specific one. ``use_packed=True`` evaluates the fit on the compressed
    postings bytes (ISSUE 7) and ``max_bytes`` overrides the default VMEM
    ceiling — together they preview the raw-vs-compressed crossover per
    stripe.
    """
    from .search import _heap_kernel_fits

    idx, _, rmq = local_index(striped, s)
    packed = idx.packed if use_packed else None
    if use_packed and packed is None:
        return False
    return _heap_kernel_fits(idx, rmq, packed=packed, max_bytes=max_bytes)


def local_index(striped: StripedQACIndex, s: int = 0):
    """Reconstruct stripe ``s``'s local (InvertedIndex, fwd, RangeMin) views.

    Two callers, two values of ``s``: inside shard_map the leading stripe
    dim is already split to length 1 and the default ``s=0`` reads the lone
    local slice; HOST-side replica topologies (the serving cluster's
    stripe-resident replicas — ``serve/cluster.py``) address any stripe of
    the stacked index directly, one ``local_index(striped, s)`` per replica.
    """
    packed = None
    if striped.pp_words is not None:
        packed = PackedPostings(
            words=striped.pp_words[s],
            base=striped.pp_base[s],
            meta=striped.pp_meta[s],
            wordoff=striped.pp_wordoff[s],
            n_post=striped.postings_pad,
            codec=striped.pp_codec,
        )
    idx = InvertedIndex(
        postings=striped.postings[s],
        offsets=striped.offsets[s],
        minimal=striped.minimal[s],
        n_terms=striped.n_terms,
        n_postings=striped.postings_pad,
        packed=packed,
    )
    fwd = LocalFwd(striped.fwd_terms[s], striped.fwd_nterms[s], striped.n_stripes)
    rmq = RangeMin(
        values=striped.rmq_values[s],
        st_pos=striped.rmq_st[s],
        ib=striped.rmq_ib[s],
        n=striped.minimal.shape[-1],
        n_blocks=striped.rmq_blocks,
        levels=striped.rmq_levels,
    )
    return idx, fwd, rmq
