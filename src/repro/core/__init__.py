"""The paper's contribution: succinct-structure QAC retrieval, TPU-native."""
from .types import PAD_TERM, INF_DOCID, MAX_TERMS, MAX_TERM_CHARS  # noqa: F401
from .dictionary import TermDictionary  # noqa: F401
from .fc import FrontCodedStore  # noqa: F401
from .completions import Completions  # noqa: F401
from .rmq import RangeMin, topk_in_range, topk_in_range_batch  # noqa: F401
from .inverted_index import InvertedIndex  # noqa: F401
from .search import (  # noqa: F401
    prefix_search_topk,
    conjunctive_multi,
    conjunctive_multi_batch,
    single_term_topk,
    single_term_topk_batch,
    single_term_topk_bounded,
    single_term_topk_bounded_batch,
    complete_conjunctive,
    complete_conjunctive_batch,
)
from .builder import (  # noqa: F401
    QACIndex,
    build_qac_index,
    build_corpus,
    parse_queries,
    corpus_stats,
)
from .delta import DeltaIndex, MainCorpusView  # noqa: F401
from .ref_engines import HostIndex  # noqa: F401
