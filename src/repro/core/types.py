"""Shared constants and pytree helpers for the QAC core.

Conventions (see DESIGN.md §2):
  * term ids are 1-based; 0 is the PAD term.
  * docids are 0-based score ranks (0 = best score); INF_DOCID is the sentinel.
  * all variable-length data is padded to fixed shapes; correctness is masked.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax

PAD_TERM = 0
INF_DOCID = 2**31 - 1          # int32 max: sorts after every real docid
INVALID = -1                   # invalid id / range marker
CHARS_PER_CHUNK = 3            # 3 bytes per int32 chunk keeps keys non-negative
MAX_TERM_CHARS = 24            # padded term length (AOL avg is 14.6)
MAX_QUERY_CHARS = 96           # padded whole-query length
MAX_TERMS = 8                  # padded terms per completion (paper: avg ~3)


def pytree_dataclass(cls=None, *, meta_fields: tuple = ()):  # noqa: ANN001
    """Register a frozen dataclass as a JAX pytree.

    ``meta_fields`` are static (hashed into the jit cache key); everything else
    is a leaf subtree.
    """

    def wrap(c):
        c = dataclasses.dataclass(frozen=True)(c)
        data_fields = tuple(
            f.name for f in dataclasses.fields(c) if f.name not in meta_fields
        )
        jax.tree_util.register_dataclass(
            c, data_fields=data_fields, meta_fields=tuple(meta_fields)
        )
        return c

    if cls is None:
        return wrap
    return wrap(cls)


def tree_bytes(tree: Any) -> int:
    """Total bytes of all array leaves in a pytree."""
    return sum(
        leaf.nbytes
        for leaf in jax.tree_util.tree_leaves(tree)
        if hasattr(leaf, "nbytes")
    )
