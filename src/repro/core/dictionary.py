"""The term dictionary (paper §3.2 "The Dictionary").

TPU-native representation: lexicographically sorted, padded char matrix plus
packed int32 chunk keys. Locate / LocatePrefix are batched binary searches;
Extract is a row gather. The Front-Coded variant (space/time study, paper
Table 3) lives in ``fc.py``.

Term ids are 1-based lexicographic ranks (0 = PAD), exactly the paper's
"lexicographic integer id".
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .types import MAX_TERM_CHARS, pytree_dataclass
from .strings import encode_strings, pack_chars, prefix_bound_keys, n_chunks
from .searching import ranged_searchsorted_keys


@pytree_dataclass(meta_fields=("n_terms", "max_chars"))
class TermDictionary:
    chars: jnp.ndarray      # uint8[V, T] sorted
    keys: jnp.ndarray       # int32[V, C] packed chunk keys
    n_terms: int
    max_chars: int

    # -- construction -------------------------------------------------------
    @staticmethod
    def build(terms, max_chars: int = MAX_TERM_CHARS) -> "TermDictionary":
        """terms: iterable of unique strings (host side)."""
        terms = sorted(set(terms))
        chars = encode_strings(terms, max_chars)
        keys = pack_chars(chars)
        return TermDictionary(
            chars=jnp.asarray(chars),
            keys=jnp.asarray(keys),
            n_terms=len(terms),
            max_chars=max_chars,
        )

    # -- queries (all jit/vmap friendly) ------------------------------------
    def locate(self, q_chars: jnp.ndarray) -> jnp.ndarray:
        """Locate(t): uint8[B, T] -> 1-based term id, 0 if absent."""
        q_keys = pack_chars(q_chars)

        def one(qk, qc):
            lo = jnp.int32(0)
            hi = jnp.int32(self.n_terms)
            pos = ranged_searchsorted_keys(self.keys, qk, lo, hi, side="left")
            row = self.chars[jnp.minimum(pos, self.n_terms - 1)]
            hit = (pos < self.n_terms) & jnp.all(row == qc)
            return jnp.where(hit, pos + 1, 0).astype(jnp.int32)

        return jax.vmap(one)(q_keys, q_chars)

    def locate_prefix(self, q_chars: jnp.ndarray, q_len: jnp.ndarray):
        """LocatePrefix(suffix): -> (l, r) 1-based half-open term-id range.

        Empty range (no term has the prefix) gives l == r.
        A zero-length prefix matches every term: (1, V+1).
        """
        lo_keys, hi_keys = prefix_bound_keys(q_chars, q_len, self.max_chars)

        def one(lk, hk):
            z = jnp.int32(0)
            v = jnp.int32(self.n_terms)
            l = ranged_searchsorted_keys(self.keys, lk, z, v, side="left")
            r = ranged_searchsorted_keys(self.keys, hk, z, v, side="right")
            return l + 1, r + 1  # to 1-based ids

        return jax.vmap(one)(lo_keys, hi_keys)

    def extract(self, term_ids: jnp.ndarray) -> jnp.ndarray:
        """Extract(id): 1-based ids[B] -> uint8[B, T] (PAD id -> zeros)."""
        idx = jnp.clip(term_ids - 1, 0, self.n_terms - 1)
        rows = self.chars[idx]
        return jnp.where((term_ids > 0)[:, None], rows, 0).astype(jnp.uint8)

    # -- host helpers --------------------------------------------------------
    def id_of(self, term: str) -> int:
        """Host-side exact lookup (for builders/tests)."""
        chars = encode_strings([term], self.max_chars)
        return int(self.locate(jnp.asarray(chars))[0])

    def space_bytes(self) -> int:
        return int(self.chars.nbytes + self.keys.nbytes)

    @property
    def n_key_chunks(self) -> int:
        return n_chunks(self.max_chars)
