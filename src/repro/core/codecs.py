"""Posting-list compression codecs (paper §3.2 / Table 4) + the device format.

Two layers live here:

1. **Space-study codecs** (host-side numpy, bit-exact): the paper evaluates
   BIC/DINT/PEF/EF/OptVB/VB/Simple16 and picks Elias-Fano for its space/time
   balance; we implement EF, partitioned EF (uniform partitions), VByte, and
   delta+fixed-width bitpacking, and report bits-per-integer the same way.
   (BIC/DINT are omitted: BIC's recursion is ~3x slower to decode in the
   paper's own Table 4 and was not chosen; DINT needs a trained dictionary.)

2. **The device block format** (``PackedPostings``): the serving index no
   longer has to keep raw CSR int32 on-chip.  Postings are split into
   ``PACK_BLOCK``-entry blocks; each block stores deltas from the block
   minimum either fixed-width bitpacked or as a per-block Elias-Fano pair
   (256-bit upper-bits bitmap + fixed-width lows), whichever is smaller,
   into a single int32 word stream with a per-block
   (base docid, bit-width|is_ef, word offset) directory.  ``packed_lookup``
   is the O(1) random-access decoder written in pure shift/mask jnp — the
   SAME function body executes inside the Pallas kernels (on VMEM-resident
   words) and as the XLA reference, so the compressed route is bit-identical
   to the raw-CSR engines by construction.  ``_heap_kernel_fits`` in
   ``core.search`` is what spends the saved bytes: corpora whose raw CSR
   busts the VMEM ceiling can still take the fused-kernel route compressed.

Stream layout (all bit offsets little-endian within int32 words):

  block b (= postings[128*b : 128*(b+1)], tail blocks padded by repeating
  the last value; pads are never addressable because lookups clamp to
  ``n_post - 1``):
    base[b]    = min(block)                      -- int32 directory
    meta[b]    = width | (is_ef << 6)
    wordoff[b] = first int32 word of the block's payload
  bitpack payload: 128 deltas at ``width`` bits each  -> 4*width words
  EF payload:      8-word bitmap with bit (j + high_j) set, where
                   high_j = delta_j >> width (width = the EF low-bit count
                   l = max(0, msb-7)), followed by 128 packed ``width``-bit
                   lows                          -> 8 + 4*width words
  EF is chosen per block only when the block is sorted and the EF payload
  is strictly smaller; ``codec="bitpack"`` disables it globally so the
  decoder can skip the bitmap-select gathers.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np
import jax.numpy as jnp
from jax import lax

from .types import pytree_dataclass

_U64 = np.uint64
_FULL64 = (1 << 64) - 1


# ---------------------------------------------------------------- bit I/O
class BitWriter:
    """Append-only little-endian bit stream over uint64 words.

    Word-level numpy throughout: ``write``/``unary`` are O(bits/64) scalar
    ops, ``write_many``/``unary_many`` are fully vectorized (one
    ``bitwise_or.at`` scatter per word touched) — the per-bit Python loops
    this replaces dominated both index build and ``bench_compression``.
    """

    def __init__(self):
        self._words = np.zeros(4, dtype=_U64)
        self._nbits = 0

    def _reserve(self, nbits: int) -> None:
        need = (nbits + 63) >> 6
        if need > len(self._words):
            grown = np.zeros(max(need, 2 * len(self._words)), dtype=_U64)
            grown[: len(self._words)] = self._words
            self._words = grown

    def write(self, value: int, n_bits: int) -> None:
        if n_bits <= 0:
            return
        v = int(value) & ((1 << n_bits) - 1)
        pos = self._nbits
        self._reserve(pos + n_bits)
        self._nbits = pos + n_bits
        w, b = divmod(pos, 64)
        while True:
            self._words[w] |= _U64((v << b) & _FULL64)
            take = 64 - b
            if n_bits <= take:
                return
            v >>= take
            n_bits -= take
            w += 1
            b = 0

    def write_many(self, values: np.ndarray, n_bits: int) -> None:
        """Append ``len(values)`` fields of ``n_bits`` bits each."""
        vals = np.asarray(values).astype(_U64)
        n = len(vals)
        if n == 0 or n_bits == 0:
            return
        assert 0 < n_bits <= 64
        if n_bits < 64:
            vals = vals & _U64((1 << n_bits) - 1)
        pos0 = self._nbits
        self._reserve(pos0 + n * n_bits)
        pos = _U64(pos0) + np.arange(n, dtype=_U64) * _U64(n_bits)
        w = (pos >> _U64(6)).astype(np.int64)
        b = pos & _U64(63)
        np.bitwise_or.at(self._words, w, vals << b)
        spill = (b + _U64(n_bits)) > _U64(64)
        if spill.any():
            bs = b[spill]
            np.bitwise_or.at(self._words, w[spill] + 1,
                             vals[spill] >> (_U64(64) - bs))
        self._nbits = pos0 + n * n_bits

    def unary(self, n: int) -> None:
        self.write(0, n)
        self.write(1, 1)

    def unary_many(self, gaps: np.ndarray) -> None:
        """Append one unary code (``gap`` zeros then a one) per entry."""
        g = np.asarray(gaps, dtype=np.int64)
        if len(g) == 0:
            return
        stops = self._nbits + np.cumsum(g + 1) - 1
        end = int(stops[-1]) + 1
        self._reserve(end)
        np.bitwise_or.at(self._words, (stops >> 6).astype(np.int64),
                         _U64(1) << (stops.astype(_U64) & _U64(63)))
        self._nbits = end

    def pad_to(self, n_bits: int) -> None:
        """Advance the cursor to an absolute bit position (zero fill)."""
        assert n_bits >= self._nbits
        self._reserve(n_bits)
        self._nbits = n_bits

    def n_bits(self) -> int:
        return self._nbits

    def array(self) -> np.ndarray:
        return self._words[: max(1, (self._nbits + 63) >> 6)].copy()


class BitReader:
    """Cursor over a BitWriter stream; same word-level discipline."""

    def __init__(self, words: np.ndarray):
        self.words = np.asarray(words, dtype=_U64)
        self.pos = 0

    def read(self, n_bits: int) -> int:
        out = 0
        got = 0
        while got < n_bits:
            w, b = divmod(self.pos, 64)
            take = min(64 - b, n_bits - got)
            out |= ((int(self.words[w]) >> b) & ((1 << take) - 1)) << got
            got += take
            self.pos += take
        return out

    def read_many(self, count: int, n_bits: int) -> np.ndarray:
        """Read ``count`` fields of ``n_bits`` bits -> int64[count]."""
        if count == 0 or n_bits == 0:
            return np.zeros(count, dtype=np.int64)
        assert 0 < n_bits <= 63
        L = len(self.words)
        pos = _U64(self.pos) + np.arange(count, dtype=_U64) * _U64(n_bits)
        w = (pos >> _U64(6)).astype(np.int64)
        b = pos & _U64(63)
        lo = self.words[w] >> b
        w1 = np.minimum(w + 1, L - 1)
        sh = (_U64(64) - b) & _U64(63)
        hi = np.where(b == 0, _U64(0), self.words[w1] << sh)
        out = (lo | hi) & _U64((1 << n_bits) - 1)
        self.pos += count * n_bits
        return out.astype(np.int64)

    def unary(self) -> int:
        n = 0
        while True:
            w, b = divmod(self.pos, 64)
            bit = (int(self.words[w]) >> b) & 1
            self.pos += 1
            if bit:
                return n
            n += 1

    def unary_many(self, count: int) -> np.ndarray:
        """Decode ``count`` unary codes -> int64[count] (the zero runs)."""
        if count == 0:
            return np.zeros(0, dtype=np.int64)
        w0 = self.pos >> 6
        tail = self.words[w0:]
        if not np.little_endian:  # pragma: no cover - scalar fallback
            return np.array([self.unary() for _ in range(count)], np.int64)
        bits = np.unpackbits(tail.view(np.uint8), bitorder="little")
        bits = bits[self.pos - (w0 << 6):]
        ones = np.flatnonzero(bits)[:count]
        assert len(ones) == count, "unary stream truncated"
        self.pos += int(ones[-1]) + 1
        return np.diff(ones, prepend=np.int64(-1)) - 1


# ---------------------------------------------------------------- Elias-Fano
@dataclasses.dataclass
class EFList:
    words: np.ndarray
    n: int
    universe: int
    low_bits: int

    def bits(self) -> int:
        # canonical EF size: n*ceil(log2(U/n)) + 2n (+ o(n) select, excluded
        # consistently for all codecs)
        return len(self.words) * 64


def ef_encode(values: np.ndarray, universe: int | None = None) -> EFList:
    v = np.asarray(values, dtype=np.int64)
    assert (np.diff(v) >= 0).all(), "EF needs a sorted sequence"
    n = len(v)
    u = int(universe if universe is not None else (v[-1] + 1 if n else 1))
    l = max(0, int(math.floor(math.log2(max(u, 1) / max(n, 1))))) if n else 0
    w = BitWriter()
    if n:
        # low bits, packed; then high bits as unary-coded gaps
        w.write_many(v & ((1 << l) - 1), l)
        w.unary_many(np.diff(v >> l, prepend=np.int64(0)))
    return EFList(words=w.array(), n=n, universe=u, low_bits=l)


def ef_decode(ef: EFList) -> np.ndarray:
    r = BitReader(ef.words)
    lows = r.read_many(ef.n, ef.low_bits)
    high = np.cumsum(r.unary_many(ef.n)) if ef.n else lows
    return (high << ef.low_bits) | lows


def pef_bits(values: np.ndarray, partition: int = 128) -> int:
    """Uniformly-partitioned EF (Ottaviano-Venturini, uniform variant)."""
    v = np.asarray(values, dtype=np.int64)
    total = 0
    for i in range(0, len(v), partition):
        chunk = v[i : i + partition]
        base = int(chunk[0])
        total += 32  # per-partition header (base + size)
        total += ef_encode(chunk - base).bits()
    return total


# ---------------------------------------------------------------- VByte
def vbyte_encode(values: np.ndarray) -> bytes:
    v = np.asarray(values, dtype=np.int64)
    deltas = np.concatenate([[v[0] + 1], np.diff(v)]) if len(v) else v
    out = bytearray()
    for d in deltas:
        d = int(d)
        while True:
            b = d & 0x7F
            d >>= 7
            if d:
                out.append(b)
            else:
                out.append(b | 0x80)
                break
    return bytes(out)


def vbyte_decode(data: bytes, n: int) -> np.ndarray:
    out = np.empty(n, dtype=np.int64)
    pos = 0
    cur = -1
    for i in range(n):
        d = 0
        shift = 0
        while True:
            b = data[pos]
            pos += 1
            d |= (b & 0x7F) << shift
            shift += 7
            if b & 0x80:
                break
        cur += d
        out[i] = cur
    return out


# ---------------------------------------------------------------- bitpacked deltas
def _bit_length(x: np.ndarray) -> np.ndarray:
    """Vectorized int bit_length; exact for 0 <= x < 2**53."""
    return np.frexp(np.asarray(x, dtype=np.float64))[1].astype(np.int64)


def bitpack_bits(values: np.ndarray, block: int = 128) -> int:
    """Delta + per-block fixed-width packing (FastPFor-lite), size only."""
    v = np.asarray(values, dtype=np.int64)
    if not len(v):
        return 0
    gaps = np.concatenate([[v[0] + 1], np.diff(v)])
    total = 0
    for i in range(0, len(gaps), block):
        chunk = gaps[i : i + block]
        width = max(1, int(_bit_length(chunk.max())))
        total += 8 + width * len(chunk)   # 8-bit width header
    return total


def index_bpi(lists: list[np.ndarray], method: str) -> float:
    """Average bits per posting over an inverted index."""
    bits = 0
    n = 0
    for lst in lists:
        if len(lst) == 0:
            continue
        n += len(lst)
        if method == "ef":
            bits += ef_encode(lst).bits()
        elif method == "pef":
            bits += pef_bits(lst)
        elif method == "vbyte":
            bits += len(vbyte_encode(lst)) * 8
        elif method == "bitpack":
            bits += bitpack_bits(lst)
        elif method == "raw32":
            bits += 32 * len(lst)
        else:
            raise ValueError(method)
    return bits / max(n, 1)


# ------------------------------------------------- device block format
PACK_BLOCK = 128          # postings per block (= one VPU lane tile)
EF_BITMAP_WORDS = 8       # 256-bit upper-bits bitmap per EF block
_META_EF_BIT = 6          # meta = width | (is_ef << _META_EF_BIT)


@pytree_dataclass(meta_fields=("n_post", "codec"))
class PackedPostings:
    """Device-layout compressed postings (see module docstring).

    ``codec`` records the build-time choice: "ef" allows per-block EF
    payloads (bitmap-select decode), "bitpack" forbids them so
    ``packed_lookup(..., ef=False)`` can skip the bitmap gathers entirely.
    """

    words: jnp.ndarray     # int32[W] payload bit stream
    base: jnp.ndarray      # int32[NB] per-block minimum docid
    meta: jnp.ndarray      # int32[NB] width | is_ef<<6
    wordoff: jnp.ndarray   # int32[NB] first payload word per block
    n_post: int
    codec: str

    @property
    def has_ef(self) -> bool:
        return self.codec == "ef"

    def nbytes(self) -> int:
        return 4 * (int(self.words.shape[0]) + 3 * int(self.base.shape[0]))

    def bits_per_int(self) -> float:
        return self.nbytes() * 8.0 / max(self.n_post, 1)


def pack_postings(postings: np.ndarray, codec: str = "ef") -> PackedPostings:
    """Encode a postings array into the device block format."""
    if codec not in ("ef", "bitpack"):
        raise ValueError(f"unknown packed codec {codec!r}")
    v = np.asarray(postings, dtype=np.int64).ravel()
    n = int(v.size)
    nb = max(1, -(-n // PACK_BLOCK))
    vp = np.empty(nb * PACK_BLOCK, dtype=np.int64)
    vp[:n] = v
    vp[n:] = v[n - 1] if n else 0          # pads are never addressable
    blocks = vp.reshape(nb, PACK_BLOCK)
    base = blocks.min(axis=1)
    d = blocks - base[:, None]
    width = _bit_length(d.max(axis=1))
    block_sorted = (np.diff(blocks, axis=1) >= 0).all(axis=1)
    l = np.maximum(width - 7, 0)           # EF high parts then fit 256 bits
    use_ef = ((codec == "ef") & block_sorted
              & (EF_BITMAP_WORDS + 4 * l < 4 * width))
    wfield = np.where(use_ef, l, width)
    nwords = np.where(use_ef, EF_BITMAP_WORDS + 4 * l, 4 * width)
    wordoff = np.concatenate([[0], np.cumsum(nwords)[:-1]])
    total = int(nwords.sum())

    # blocks are uint64-aligned (every payload is an even word count), so
    # one sequential BitWriter produces the whole stream
    bw = BitWriter()
    for b in range(nb):
        if use_ef[b]:
            start = bw.n_bits()
            bw.unary_many(np.diff(d[b] >> int(l[b]), prepend=np.int64(0)))
            bw.pad_to(start + EF_BITMAP_WORDS * 32)
            bw.write_many(d[b] & ((1 << int(l[b])) - 1), int(l[b]))
        elif width[b] > 0:
            bw.write_many(d[b], int(width[b]))
    assert bw.n_bits() == total * 32
    w64 = np.zeros(max(total + 1, 2) // 2, dtype=_U64)
    got = bw.array()[: len(w64)]
    w64[: len(got)] = got
    words32 = np.empty(max(total, 1), dtype=np.uint32)
    words32[0::2] = (w64 & _U64(0xFFFFFFFF)).astype(np.uint32)[: len(words32[0::2])]
    words32[1::2] = (w64 >> _U64(32)).astype(np.uint32)[: len(words32[1::2])]

    meta = wfield | (use_ef.astype(np.int64) << _META_EF_BIT)
    return PackedPostings(
        words=jnp.asarray(words32.view(np.int32)),
        base=jnp.asarray(base.astype(np.int32)),
        meta=jnp.asarray(meta.astype(np.int32)),
        wordoff=jnp.asarray(wordoff.astype(np.int32)),
        n_post=n, codec=codec)


def unpack_postings(pk: PackedPostings) -> np.ndarray:
    """Host reference decode of the full stream -> int32[n_post]."""
    words = np.asarray(pk.words).view(np.uint32)
    base = np.asarray(pk.base, dtype=np.int64)
    meta = np.asarray(pk.meta)
    wordoff = np.asarray(pk.wordoff, dtype=np.int64)
    nb = len(base)
    out = np.empty(nb * PACK_BLOCK, dtype=np.int64)
    for b in range(nb):
        w = int(meta[b]) & ((1 << _META_EF_BIT) - 1)
        is_ef = (int(meta[b]) >> _META_EF_BIT) & 1
        nw = (EF_BITMAP_WORDS + 4 * w) if is_ef else 4 * w
        seg = words[wordoff[b] : wordoff[b] + nw].astype(_U64)
        w64 = seg[0::2] | (seg[1::2] << _U64(32))
        if nw == 0:
            d = np.zeros(PACK_BLOCK, dtype=np.int64)
        elif is_ef:
            r = BitReader(w64)
            high = np.cumsum(r.unary_many(PACK_BLOCK))
            r.pos = EF_BITMAP_WORDS * 32
            d = (high << w) | r.read_many(PACK_BLOCK, w)
        else:
            d = BitReader(w64).read_many(PACK_BLOCK, w)
        out[b * PACK_BLOCK : (b + 1) * PACK_BLOCK] = base[b] + d
    return out[: pk.n_post].astype(np.int32)


def _popcount32(x):
    """SWAR popcount on int32 lanes (no population_count primitive needed;
    the wraparound multiply is well-defined two's-complement)."""
    srl = lax.shift_right_logical
    x = x - (srl(x, 1) & 0x55555555)
    x = (x & 0x33333333) + (srl(x, 2) & 0x33333333)
    x = (x + srl(x, 4)) & 0x0F0F0F0F
    return srl(x * 0x01010101, 24)


def packed_lookup(words, base, meta, wordoff, ptr, *, n_post: int, ef: bool):
    """Random-access decode: postings[min(max(ptr, 0), n_post-1)].

    Pure shift/mask jnp over flat int32 arrays — the shared transcription
    (like ``rmq_window_batch``): the Pallas kernels call this very function
    on their VMEM-resident arrays and the XLA reference calls it on device
    arrays, so both routes are bit-identical by construction.  The clamp
    matches the raw path's ``postings[min(ptr, n_post-1)]`` gather contract
    (callers mask out-of-list lanes themselves).

    ``ef=False`` (static) promises no block has an EF payload and skips the
    8 bitmap gathers + select; with ``ef=True`` the per-block meta flag
    picks bitmap-select or plain bitpack decode lane-wise.
    """
    srl = lax.shift_right_logical
    W = words.shape[0]
    p = jnp.minimum(jnp.maximum(ptr, 0), max(n_post - 1, 0)).astype(jnp.int32)
    b = srl(p, 7)                      # // PACK_BLOCK
    j = p & (PACK_BLOCK - 1)
    bb = base[b]
    mm = meta[b]
    off = wordoff[b]
    wf = mm & ((1 << _META_EF_BIT) - 1)
    is_ef = srl(mm, _META_EF_BIT) & 1
    # fixed-width field j of the low/bitpack payload
    bit = j * wf
    wi = (off + (is_ef << 3)) + srl(bit, 5)
    bo = bit & 31
    w0 = words[jnp.minimum(wi, W - 1)]
    w1 = words[jnp.minimum(wi + 1, W - 1)]
    straddle = jnp.where(bo == 0, 0, w1 << ((32 - bo) & 31))
    mask = jnp.where(wf == 0, 0, srl(jnp.int32(-1), 32 - jnp.maximum(wf, 1)))
    low = (srl(w0, bo) | straddle) & mask
    if not ef:
        return (bb + low).astype(jnp.int32)
    # EF upper bits: select the j-th set bit of the 8-word bitmap.  For
    # bitpack blocks these gathers read (clamped) garbage that the final
    # ``where`` discards.
    r = j
    sel_word = jnp.zeros_like(j)
    sel_base = jnp.zeros_like(j)
    found = jnp.zeros_like(j, dtype=bool)
    for t in range(EF_BITMAP_WORDS):
        wt = words[jnp.minimum(off + t, W - 1)]
        c = _popcount32(wt)
        here = (~found) & (r < c)
        sel_word = jnp.where(here, wt, sel_word)
        sel_base = jnp.where(here, t << 5, sel_base)
        r = jnp.where(found | here, r, r - c)
        found = found | here
    # binary strip: position of the r-th set bit inside sel_word
    pos = jnp.zeros_like(j)
    cur = sel_word
    for s in (16, 8, 4, 2, 1):
        c = _popcount32(cur & ((1 << s) - 1))
        go = c <= r
        r = jnp.where(go, r - c, r)
        pos = pos + jnp.where(go, s, 0)
        cur = jnp.where(go, srl(cur, s), cur & ((1 << s) - 1))
    high = sel_base + pos - j
    val = jnp.where(is_ef == 1, (high << wf) | low, low)
    return (bb + val).astype(jnp.int32)
