"""Posting-list compression codecs (paper §3.2 / Table 4).

Host-side (numpy) bit-exact encoders/decoders for the space study. The paper
evaluates BIC/DINT/PEF/EF/OptVB/VB/Simple16 and picks Elias-Fano for its
space/time balance; we implement EF, partitioned EF (uniform partitions),
VByte, and delta+fixed-width bitpacking, and report bits-per-integer the
same way. (BIC/DINT are omitted: BIC's recursion is ~3x slower to decode in
the paper's own Table 4 and was not chosen; DINT needs a trained dictionary.)

The JAX-side serving index keeps raw CSR int32 (DESIGN.md §2: on TPU the
further space/time trade to raw arrays is the same move the paper makes when
it prefers EF over BIC); these codecs quantify exactly what that trade costs.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


# ---------------------------------------------------------------- bit I/O
class BitWriter:
    def __init__(self):
        self.words: list[int] = [0]
        self.bit = 0

    def write(self, value: int, n_bits: int):
        v = int(value)
        for i in range(n_bits):
            if v >> i & 1:
                self.words[-1] |= 1 << self.bit
            self.bit += 1
            if self.bit == 64:
                self.words.append(0)
                self.bit = 0

    def unary(self, n: int):
        self.write(0, n)
        self.write(1, 1)

    def n_bits(self) -> int:
        return (len(self.words) - 1) * 64 + self.bit

    def array(self) -> np.ndarray:
        return np.asarray(self.words, dtype=np.uint64)


class BitReader:
    def __init__(self, words: np.ndarray):
        self.words = words
        self.pos = 0

    def read(self, n_bits: int) -> int:
        out = 0
        for i in range(n_bits):
            w, b = divmod(self.pos, 64)
            out |= ((int(self.words[w]) >> b) & 1) << i
            self.pos += 1
        return out

    def unary(self) -> int:
        n = 0
        while True:
            w, b = divmod(self.pos, 64)
            bit = (int(self.words[w]) >> b) & 1
            self.pos += 1
            if bit:
                return n
            n += 1


# ---------------------------------------------------------------- Elias-Fano
@dataclasses.dataclass
class EFList:
    words: np.ndarray
    n: int
    universe: int
    low_bits: int

    def bits(self) -> int:
        # canonical EF size: n*ceil(log2(U/n)) + 2n (+ o(n) select, excluded
        # consistently for all codecs)
        return len(self.words) * 64


def ef_encode(values: np.ndarray, universe: int | None = None) -> EFList:
    v = np.asarray(values, dtype=np.int64)
    assert (np.diff(v) >= 0).all(), "EF needs a sorted sequence"
    n = len(v)
    u = int(universe if universe is not None else (v[-1] + 1 if n else 1))
    l = max(0, int(math.floor(math.log2(max(u, 1) / max(n, 1))))) if n else 0
    w = BitWriter()
    # low bits, packed
    for x in v:
        w.write(int(x) & ((1 << l) - 1), l)
    # high bits, unary-coded gaps
    prev = 0
    for x in v:
        h = int(x) >> l
        w.unary(h - prev)
        prev = h
    return EFList(words=w.array(), n=n, universe=u, low_bits=l)


def ef_decode(ef: EFList) -> np.ndarray:
    r = BitReader(ef.words)
    lows = [r.read(ef.low_bits) for _ in range(ef.n)]
    out = np.empty(ef.n, dtype=np.int64)
    h = 0
    for i in range(ef.n):
        h += r.unary()
        out[i] = (h << ef.low_bits) | lows[i]
    return out


def pef_bits(values: np.ndarray, partition: int = 128) -> int:
    """Uniformly-partitioned EF (Ottaviano-Venturini, uniform variant)."""
    v = np.asarray(values, dtype=np.int64)
    total = 0
    for i in range(0, len(v), partition):
        chunk = v[i : i + partition]
        base = int(chunk[0])
        total += 32  # per-partition header (base + size)
        total += ef_encode(chunk - base).bits()
    return total


# ---------------------------------------------------------------- VByte
def vbyte_encode(values: np.ndarray) -> bytes:
    v = np.asarray(values, dtype=np.int64)
    deltas = np.diff(v, prepend=np.int64(-1)) - 0  # gaps (first = v[0]+1... )
    deltas = np.concatenate([[v[0] + 1], np.diff(v)]) if len(v) else deltas[:0]
    out = bytearray()
    for d in deltas:
        d = int(d)
        while True:
            b = d & 0x7F
            d >>= 7
            if d:
                out.append(b)
            else:
                out.append(b | 0x80)
                break
    return bytes(out)


def vbyte_decode(data: bytes, n: int) -> np.ndarray:
    out = np.empty(n, dtype=np.int64)
    pos = 0
    cur = -1
    for i in range(n):
        d = 0
        shift = 0
        while True:
            b = data[pos]
            pos += 1
            d |= (b & 0x7F) << shift
            shift += 7
            if b & 0x80:
                break
        cur += d
        out[i] = cur
    return out


# ---------------------------------------------------------------- bitpacked deltas
def bitpack_bits(values: np.ndarray, block: int = 128) -> int:
    """Delta + per-block fixed-width packing (FastPFor-lite), size only."""
    v = np.asarray(values, dtype=np.int64)
    if not len(v):
        return 0
    gaps = np.concatenate([[v[0] + 1], np.diff(v)])
    total = 0
    for i in range(0, len(gaps), block):
        chunk = gaps[i : i + block]
        width = max(1, int(chunk.max()).bit_length())
        total += 8 + width * len(chunk)   # 8-bit width header
    return total


def index_bpi(lists: list[np.ndarray], method: str) -> float:
    """Average bits per posting over an inverted index."""
    bits = 0
    n = 0
    for lst in lists:
        if len(lst) == 0:
            continue
        n += len(lst)
        if method == "ef":
            bits += ef_encode(lst).bits()
        elif method == "pef":
            bits += pef_bits(lst)
        elif method == "vbyte":
            bits += len(vbyte_encode(lst)) * 8
        elif method == "bitpack":
            bits += bitpack_bits(lst)
        elif method == "raw32":
            bits += 32 * len(lst)
        else:
            raise ValueError(method)
    return bits / max(n, 1)
