"""Corpus -> QACIndex: ties every structure of paper §3.2 together."""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np
import jax.numpy as jnp

from .types import MAX_TERMS, MAX_TERM_CHARS, pytree_dataclass
from .dictionary import TermDictionary
from .fc import FrontCodedStore
from .completions import Completions
from .inverted_index import InvertedIndex
from .rmq import RangeMin
from .strings import encode_strings


@pytree_dataclass(meta_fields=("k_default",))
class QACIndex:
    dictionary: TermDictionary
    completions: Completions
    index: InvertedIndex
    rmq_docids: RangeMin        # over completions.docids (prefix-search top-k)
    rmq_minimal: RangeMin       # over index.minimal (single-term queries)
    k_default: int


@dataclasses.dataclass
class CorpusStats:
    n_queries: int
    n_unique_terms: int
    avg_chars_per_term: float
    avg_queries_per_term: float
    avg_terms_per_query: float
    uncompressed_bytes: int


def tokenize(s: str) -> list[str]:
    return [t for t in s.strip().split() if t]


def build_corpus(queries: Sequence[str], scores: Sequence[float],
                 max_terms: int = MAX_TERMS,
                 max_term_chars: int = MAX_TERM_CHARS):
    """Dedup + tokenize a scored query log (host side).

    Returns (dictionary, term_rows int32[N,M], scores float64[N], kept_strings).
    """
    seen = {}
    for q, s in zip(queries, scores):
        toks = tokenize(q)
        if not toks or len(toks) > max_terms:
            continue
        key = " ".join(toks)
        seen[key] = max(seen.get(key, -np.inf), float(s))
    kept = sorted(seen.keys())
    sc = np.asarray([seen[kq] for kq in kept], dtype=np.float64)
    vocab = sorted({t for q in kept for t in tokenize(q)})
    dictionary = TermDictionary.build(vocab, max_term_chars)
    tid = {t: i + 1 for i, t in enumerate(vocab)}  # 1-based lexicographic ids
    rows = np.zeros((len(kept), max_terms), dtype=np.int32)
    for i, q in enumerate(kept):
        for j, t in enumerate(tokenize(q)):
            rows[i, j] = tid[t]
    return dictionary, rows, sc, kept


def build_qac_index(queries: Sequence[str], scores: Sequence[float],
                    k_default: int = 10,
                    max_terms: int = MAX_TERMS,
                    max_term_chars: int = MAX_TERM_CHARS,
                    postings_codec: str | None = "ef"):
    """Full pipeline: scored log -> all paper data structures.

    ``postings_codec`` ("ef" default, "bitpack", or None) controls the
    compressed device layout emitted alongside raw CSR (see
    ``InvertedIndex.build``); serving routes pick raw or packed per the
    VMEM gate (``core.search`` ``postings_codec`` knob).
    """
    dictionary, rows, sc, kept = build_corpus(
        queries, scores, max_terms, max_term_chars
    )
    comps = Completions.build(rows, sc)
    # row -> docid mapping on host for the index builder
    order = np.lexsort(
        tuple(rows[:, j] for j in range(rows.shape[1] - 1, -1, -1)) + (-sc,)
    )
    d_of_row = np.empty(len(rows), dtype=np.int32)
    d_of_row[order] = np.arange(len(rows), dtype=np.int32)
    inv = InvertedIndex.build(rows, d_of_row, dictionary.n_terms,
                              postings_codec=postings_codec)
    rmq_doc = RangeMin.build(np.asarray(comps.docids))
    rmq_min = inv.build_minimal_rmq()
    qidx = QACIndex(
        dictionary=dictionary,
        completions=comps,
        index=inv,
        rmq_docids=rmq_doc,
        rmq_minimal=rmq_min,
        k_default=k_default,
    )
    return qidx, kept, sc


def corpus_stats(kept: Sequence[str]) -> CorpusStats:
    terms = [t for q in kept for t in tokenize(q)]
    uniq = set(terms)
    return CorpusStats(
        n_queries=len(kept),
        n_unique_terms=len(uniq),
        avg_chars_per_term=float(np.mean([len(t) for t in uniq])) if uniq else 0.0,
        avg_queries_per_term=len(terms) / max(len(uniq), 1),
        avg_terms_per_query=len(terms) / max(len(kept), 1),
        uncompressed_bytes=sum(len(q) + 1 for q in kept),
    )


def parse_queries(dictionary: TermDictionary, raw_queries: Sequence[str],
                  max_terms: int = MAX_TERMS,
                  max_term_chars: int = MAX_TERM_CHARS):
    """Paper §3.1 "Parsing": split each raw query into prefix term-ids and a
    (possibly incomplete) suffix. Host-side; returns device-ready arrays.

    A trailing space means the last term is complete -> it joins the prefix
    and the suffix is empty (matches any term).
    """
    B = len(raw_queries)
    prefix_ids = np.zeros((B, max_terms), dtype=np.int32)
    prefix_len = np.zeros(B, dtype=np.int32)
    prefix_ok = np.ones(B, dtype=bool)
    suffix = np.zeros((B, max_term_chars), dtype=np.uint8)
    suffix_len = np.zeros(B, dtype=np.int32)
    all_terms = []
    for q in raw_queries:
        toks = tokenize(q)
        ends_complete = q.endswith(" ") or q.endswith("\t")
        pre = toks if ends_complete else toks[:-1]
        all_terms.append((pre, "" if ends_complete or not toks else toks[-1]))
    flat = [t for pre, _ in all_terms for t in pre]
    ids = {}
    if flat:
        uniq = sorted(set(flat))
        chars = encode_strings(uniq, max_term_chars)
        got = np.asarray(dictionary.locate(jnp.asarray(chars)))
        ids = dict(zip(uniq, got.tolist()))
    for i, (pre, suf) in enumerate(all_terms):
        pre = pre[: max_terms - 1]
        for j, t in enumerate(pre):
            tid = ids.get(t, 0)
            prefix_ids[i, j] = tid
            if tid == 0:
                prefix_ok[i] = False
        prefix_len[i] = len(pre)
        b = suf.encode("utf-8")[:max_term_chars]
        suffix[i, : len(b)] = np.frombuffer(b, dtype=np.uint8)
        suffix_len[i] = len(b)
    return (
        jnp.asarray(prefix_ids),
        jnp.asarray(prefix_len),
        np.asarray(prefix_ok),
        jnp.asarray(suffix),
        jnp.asarray(suffix_len),
    )
