"""The freshness delta tier (ISSUE 9 tentpole): a small uncompressed
in-memory index absorbing newly observed completions between rebuilds.

A production QAC corpus mutates continuously — trending queries must become
suggestible within seconds, not at the next offline rebuild (paper §1: the
system replaced eBay's SOLR deployment exactly because operating the old
stack under continuous change missed the SLA). The immutable ``QACIndex``
is the wrong structure for that: every insert would re-sort the docid
space. This module is the classic LSM-shaped answer:

  * ``DeltaIndex`` — a tiny, uncompressed, host-resident tier. Inserts are
    O(row) appends: term ids come from the CURRENT generation's (front-
    coded-compatible) ``TermDictionary`` — an id here means exactly what it
    means in the immutable tier, so one parse serves both — and postings
    are APPEND-ONLY per-term entry-id lists (scores may be rewritten in
    place by a later trend bump; list structure only ever grows).
  * ``MainCorpusView`` — the host mirror of the immutable generation the
    delta shadows: completion-string <-> docid <-> score maps built from
    the index arrays themselves (no ordering assumptions on the corpus),
    used for shadow detection at insert and for the merge/oracle layers in
    ``serve.freshness``.

Exactness contract (the whole point): the visible state after any prefix of
inserts must answer bit-identically to a from-scratch ``build_qac_index``
over (base corpus + those inserts). ``build_corpus`` dedups completions
with MAX score, so the delta mirrors that algebra at insert time:

  * a completion already in the main tier with ``score <= main score`` is a
    **noop** (the from-scratch build would keep the main copy);
  * with ``score > main score`` it becomes a **shadow** entry — the entry
    remembers the main docid it outranks, and the merge layer suppresses
    the main tier's copy (the from-scratch build would keep only the new
    score);
  * a completion already in the delta keeps the max of both scores
    (**update** — in place, never a second entry);
  * a completion with an out-of-vocabulary term is **deferred**: the
    current dictionary cannot assign it ids, so it is buffered for the
    next rebuild (which re-runs the full builder over base + delta +
    deferred) and is NOT part of the visible state until the swap. Same
    for completions the builder itself would drop (empty / too many
    terms -> **dropped**, not even deferred).

Lookup (``topk``) mirrors the engines' match rule verbatim — every prefix
term present in the completion's term set and >= 1 term in the suffix's
``[lo, hi)`` dictionary range — and returns entries in (score desc, token
tuple asc) order, which is exactly the (-score, lexicographic row) docid
order a from-scratch build would assign. ``upto`` replays any historical
prefix of the insert log, which is what makes the time-indexed parity
oracle (serve/freshness.py) cheap to state.
"""
from __future__ import annotations

import bisect
import dataclasses

import numpy as np

from .builder import QACIndex, tokenize
from .types import MAX_TERMS


class MainCorpusView:
    """Host mirror of one immutable generation: string/docid/score maps.

    Built from the index arrays themselves (``fwd_terms`` + the dictionary's
    char rows), not from any assumed alignment between the builder's
    ``kept`` list and docid order — so it stays correct for any corpus.
    """

    def __init__(self, qidx: QACIndex, kept, scores):
        self.qidx = qidx
        self.kept = list(kept)
        self.scores = np.asarray(scores, dtype=np.float64)
        if len(self.kept) != len(self.scores):
            raise ValueError(f"{len(self.kept)} kept strings vs "
                             f"{len(self.scores)} scores")
        score_by_string = dict(zip(self.kept, self.scores))
        # decode each unique term once (V decodes), then join per docid
        chars = np.asarray(qidx.dictionary.chars)
        term_str = [""] + [
            bytes(r).rstrip(b"\x00").decode("utf-8", errors="replace")
            for r in chars]
        # host-side term -> 1-based id (the dictionary's own `id_of` runs a
        # per-call device binary search — ~ms, ruinous on the insert path)
        self.term_id = {s: i for i, s in enumerate(term_str) if i > 0}
        fwd = np.asarray(qidx.completions.fwd_terms)
        self.string_of_docid: list[str] = []
        self.tokens_of_docid: list[tuple] = []
        for row in fwd:
            toks = tuple(term_str[t] for t in row if t)
            self.tokens_of_docid.append(toks)
            self.string_of_docid.append(" ".join(toks))
        self.score_of_docid = np.asarray(
            [score_by_string[s] for s in self.string_of_docid],
            dtype=np.float64)
        self.docid_of_string = {s: d for d, s in
                                enumerate(self.string_of_docid)}

    def lookup(self, canonical: str):
        """canonical completion string -> (docid, score) or None."""
        d = self.docid_of_string.get(canonical)
        if d is None:
            return None
        return d, float(self.score_of_docid[d])


@dataclasses.dataclass
class DeltaEntry:
    """One applied insert: the completion under the current generation's
    term ids, its score history, and the main docid it shadows (-1 =
    a genuinely new completion).

    ``born`` is the delta sequence number at which this entry became
    visible; ``hist`` is its (seq, score) history — a later trend bump
    rewrites the score IN PLACE structurally but appends to the history,
    so any historical sequence number replays the exact score it saw.
    """

    query: str               # canonical " ".join(tokens)
    tokens: tuple            # token tuple — the cross-dictionary tie-break
    row: np.ndarray          # int32[max_terms] 1-based ids, 0 pad
    born: int                # seq at which the entry became visible
    hist: list               # [(seq, score)] ascending, never empty
    shadow_docid: int        # main docid outranked by this entry, or -1

    @property
    def score(self) -> float:
        return self.hist[-1][1]

    def score_at(self, seq: int) -> float:
        for s, sc in reversed(self.hist):
            if s <= seq:
                return sc
        raise ValueError(f"entry born at seq {self.born} queried at {seq}")


class DeltaIndex:
    """Append-only in-memory delta tier over one ``MainCorpusView``.

    ``seq`` counts VISIBLE state changes: it bumps on every applied entry
    and on every in-place score raise of an existing entry (the two insert
    outcomes the from-scratch oracle can observe), and ``oplog`` records
    the (query, score) of each bump. Visible state ``(generation, seq)``
    therefore means "the generation's base corpus with ``oplog[:seq]``
    replayed under the builder's max-score dedup", and every read API
    takes ``upto=seq`` to reproduce that state exactly — entries born
    later are filtered out, earlier entries report ``score_at(seq)``.
    """

    def __init__(self, view: MainCorpusView, *, capacity: int = 4096,
                 max_terms: int = MAX_TERMS):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.view = view
        self.capacity = capacity
        self.max_terms = max_terms
        self.entries: list[DeltaEntry] = []
        self.rows = np.zeros((capacity, max_terms), dtype=np.int32)
        self.scores = np.zeros(capacity, dtype=np.float64)
        # append-only postings: term id -> entry ids, in insertion order
        # (ascending by construction — the "docid order" of the delta tier
        # is (score, tokens), recomputed at read time over the tiny tier,
        # but the postings themselves never reorder)
        self.postings: dict[int, list[int]] = {}
        self.by_query: dict[str, int] = {}
        self.shadow_docids: list[int] = []   # grows with shadow entries
        self.deferred: list[tuple[str, float]] = []   # OOV: next rebuild
        self.seq = 0                          # visible-state version counter
        self.oplog: list[tuple[str, float]] = []      # one row per seq bump
        self._born: list[int] = []            # born seq per entry (ascending)
        self._stats = {"applied": 0, "updated": 0, "noop": 0,
                       "deferred": 0, "dropped": 0}

    @property
    def n(self) -> int:
        return len(self.entries)

    def _n_visible(self, seq: int) -> int:
        """Entries born at or before ``seq`` — a PREFIX of the entry list,
        because born values are assigned in append order."""
        return bisect.bisect_right(self._born, seq)

    # -- writes ---------------------------------------------------------------
    def insert(self, query: str, score: float) -> str:
        """Absorb one observed completion; returns the outcome kind:
        ``"applied"`` (new visible entry), ``"updated"`` (an existing delta
        entry's score rose in place), ``"noop"`` (main tier already
        outranks it), ``"deferred"`` (OOV term, buffered for the next
        rebuild), or ``"dropped"`` (the builder itself would discard it).
        Raises ``OverflowError`` when the delta is full — the caller
        (``GenerationalQAC``) rebuilds and swaps before that can happen.
        """
        score = float(score)
        toks = tokenize(query)
        if not toks or len(toks) > self.max_terms:
            self._stats["dropped"] += 1
            return "dropped"
        canonical = " ".join(toks)
        prev = self.by_query.get(canonical)
        if prev is not None:
            if score > self.entries[prev].score:
                # in-place score raise: max-dedup, never a second entry —
                # but a VISIBLE change, so it gets its own seq + oplog row
                self.seq += 1
                self.oplog.append((canonical, score))
                self.entries[prev].hist.append((self.seq, score))
                self.scores[prev] = score
                self._stats["updated"] += 1
                return "updated"
            self._stats["noop"] += 1
            return "noop"
        main = self.view.lookup(canonical)
        if main is not None and score <= main[1]:
            self._stats["noop"] += 1
            return "noop"
        ids = [self.view.term_id.get(t, 0) for t in toks]
        if any(i == 0 for i in ids):
            # out-of-vocabulary term: the current dictionary cannot name
            # it, so it waits for the rebuild (which re-runs the builder
            # over base + delta + deferred and mints the new term ids)
            self.deferred.append((canonical, score))
            self._stats["deferred"] += 1
            return "deferred"
        if self.n >= self.capacity:
            raise OverflowError(
                f"delta full ({self.capacity} entries); rebuild and swap")
        eid = self.n
        row = np.zeros(self.max_terms, dtype=np.int32)
        row[: len(ids)] = ids
        shadow = main[0] if main is not None else -1
        self.seq += 1
        self.oplog.append((canonical, score))
        self.entries.append(DeltaEntry(query=canonical, tokens=tuple(toks),
                                       row=row, born=self.seq,
                                       hist=[(self.seq, score)],
                                       shadow_docid=shadow))
        self._born.append(self.seq)
        self.rows[eid] = row
        self.scores[eid] = score
        for t in sorted(set(ids)):
            self.postings.setdefault(t, []).append(eid)
        if shadow >= 0:
            self.shadow_docids.append(shadow)
        self.by_query[canonical] = eid
        self._stats["applied"] += 1
        return "applied"

    # -- reads ----------------------------------------------------------------
    def shadowed(self, upto: int | None = None) -> set[int]:
        """Main docids outranked by the state at sequence ``upto``."""
        nv = self._n_visible(self.seq if upto is None else upto)
        return {e.shadow_docid for e in self.entries[:nv]
                if e.shadow_docid >= 0}

    def _candidates(self, pids, plen: int, n_vis: int) -> np.ndarray:
        """Entry ids that can possibly match: the append-only postings of
        the rarest prefix term when there is one, else everything live."""
        if plen <= 0:
            return np.arange(n_vis, dtype=np.int64)
        lists = [np.asarray(self.postings.get(int(t), ()), dtype=np.int64)
                 for t in set(int(x) for x in pids[:plen])]
        cand = min(lists, key=len)
        return cand[cand < n_vis]

    def matches(self, pids, plen: int, lo: int, hi: int,
                upto: int | None = None) -> list[int]:
        """Entry ids matching the engines' rule — every prefix term present
        AND >= 1 term in [lo, hi) — in (score desc, tokens asc) order at
        sequence ``upto``, i.e. exactly the (-score, lexicographic row)
        docid order a from-scratch build of that state would assign."""
        seq = self.seq if upto is None else upto
        n_vis = self._n_visible(seq)
        if n_vis <= 0 or hi <= lo:
            return []
        pids = np.asarray(pids, dtype=np.int64)
        if plen > 0 and bool((pids[:plen] == 0).any()):
            return []                       # engines reject unknown prefix terms
        cand = self._candidates(pids, plen, n_vis)
        if cand.size == 0:
            return []
        rows = self.rows[cand]                                    # [C, M]
        keep = ((rows >= lo) & (rows < hi)).any(axis=1)
        for t in set(int(x) for x in pids[:plen]):
            keep &= (rows == t).any(axis=1)
        hit = cand[keep]
        return sorted((int(i) for i in hit),
                      key=lambda i: (-self.entries[i].score_at(seq),
                                     self.entries[i].tokens))

    def topk(self, pids, plen: int, lo: int, hi: int, k: int,
             upto: int | None = None) -> list[int]:
        return self.matches(pids, plen, lo, hi, upto)[:k]

    # -- rebuild handoff ------------------------------------------------------
    def fold_corpus(self) -> tuple[list[str], list[float]]:
        """(queries, scores) to append to the base corpus at rebuild:
        every applied entry plus the deferred OOV buffer. ``build_corpus``'s
        max-dedup makes re-stating a shadow harmless by construction."""
        qs = [e.query for e in self.entries] + [q for q, _ in self.deferred]
        sc = [e.score for e in self.entries] + [s for _, s in self.deferred]
        return qs, sc

    def stats(self) -> dict:
        return dict(self._stats, n=self.n, seq=self.seq,
                    deferred_pending=len(self.deferred),
                    shadows=len(self.shadow_docids))
