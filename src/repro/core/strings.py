"""Byte-string <-> packed int32 chunk-key conversion.

TPU adaptation of string comparison (DESIGN.md §2): strings are padded uint8
rows; for *sorted* search we pack 3 bytes per int32 chunk (big-endian within the
chunk) so that chunkwise signed-integer comparison equals lexicographic byte
comparison, and every chunk stays non-negative even for 0xFF padding.
"""
from __future__ import annotations

import numpy as np

from .types import CHARS_PER_CHUNK


def n_chunks(max_chars: int) -> int:
    return (max_chars + CHARS_PER_CHUNK - 1) // CHARS_PER_CHUNK


def encode_strings(strings, max_chars: int) -> np.ndarray:
    """List of bytes/str -> uint8[N, max_chars] padded with 0 (host-side)."""
    out = np.zeros((len(strings), max_chars), dtype=np.uint8)
    for i, s in enumerate(strings):
        b = s.encode("utf-8") if isinstance(s, str) else bytes(s)
        b = b[:max_chars]
        out[i, : len(b)] = np.frombuffer(b, dtype=np.uint8)
    return out


def decode_string(row: np.ndarray) -> str:
    row = np.asarray(row, dtype=np.uint8)
    end = int(np.argmax(row == 0)) if (row == 0).any() else len(row)
    return bytes(row[:end]).decode("utf-8", errors="replace")


def pack_chars(chars):
    """uint8[..., T] -> int32[..., ceil(T/3)] big-endian 3-byte chunks.

    Works on numpy or jax arrays (pure ufunc ops).
    """
    import jax.numpy as jnp

    xp = jnp if not isinstance(chars, np.ndarray) else np
    T = chars.shape[-1]
    pad = (-T) % CHARS_PER_CHUNK
    if pad:
        chars = xp.concatenate(
            [chars, xp.zeros(chars.shape[:-1] + (pad,), dtype=chars.dtype)], axis=-1
        )
    c = chars.astype(xp.int32).reshape(chars.shape[:-1] + (-1, CHARS_PER_CHUNK))
    return (c[..., 0] << 16) | (c[..., 1] << 8) | c[..., 2]


def prefix_bound_keys(chars, length, max_chars: int):
    """Packed keys for the lower/upper bound of a prefix search.

    chars: uint8[..., T] prefix padded with 0; length: int32[...]. Returns
    (lo_key, hi_key): positions >= length are 0x00 in lo_key and 0xFF in hi_key,
    so ``searchsorted(lo,'left') .. searchsorted(hi,'right')`` brackets exactly
    the strings with that prefix.
    """
    import jax.numpy as jnp

    xp = jnp if not isinstance(chars, np.ndarray) else np
    T = max_chars
    idx = xp.arange(T, dtype=xp.int32)
    mask = idx[None, :] < xp.asarray(length).reshape(-1, 1) if chars.ndim > 1 else idx < length
    lo = xp.where(mask, chars, xp.zeros_like(chars))
    hi = xp.where(mask, chars, xp.full_like(chars, 255))
    return pack_chars(lo), pack_chars(hi)
