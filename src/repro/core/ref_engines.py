"""Host (numpy/python) reference engines — the paper's exact algorithms.

These serve two purposes:
  1. oracles for the JAX engines' tests (results must match exactly);
  2. the CPU baselines of the paper's Table 5 comparison (Heap vs Fwd vs FC),
     implemented faithfully: Heap == Fig 3, Fwd == Fig 5, FC == Fig 5 with
     front-coded extraction, single-term == §3.3 RMQ-on-minimal.
"""
from __future__ import annotations

import heapq
from bisect import bisect_left
from typing import Sequence

import numpy as np


class HostIndex:
    """Plain-python mirror of the built structures, for oracles/baselines."""

    def __init__(self, rows: np.ndarray, docid_of_row: np.ndarray, n_terms: int):
        self.rows = np.asarray(rows)
        self.doc_of_row = np.asarray(docid_of_row)
        n = len(rows)
        self.fwd = np.zeros_like(self.rows)
        self.fwd[self.doc_of_row] = self.rows
        self.lists: dict[int, list[int]] = {}
        for r, d in zip(self.rows, self.doc_of_row):
            for t in r:
                if t:
                    self.lists.setdefault(int(t), set()).add(int(d))  # type: ignore
        self.lists = {t: sorted(s) for t, s in self.lists.items()}
        self.n_terms = n_terms
        self.n = n
        lex = np.lexsort(tuple(self.rows[:, j] for j in range(self.rows.shape[1] - 1, -1, -1)))
        self.lex_rows = self.rows[lex]
        self.docids = self.doc_of_row[lex]

    def plist(self, t: int) -> list[int]:
        return self.lists.get(int(t), [])

    # -- oracles --------------------------------------------------------------
    def brute_conjunctive(self, prefix: Sequence[int], lo: int, hi: int, k: int):
        """All docids containing every prefix term and >=1 term in [lo,hi)."""
        out = []
        for d in range(self.n):
            terms = set(int(t) for t in self.fwd[d] if t)
            if all(int(t) in terms for t in prefix) and any(
                lo <= t < hi for t in terms
            ):
                out.append(d)
                if len(out) == k:
                    break
        return out

    def brute_prefix_search(self, prefix: Sequence[int], lo: int, hi: int, k: int):
        """Docids of completions prefixed by prefix + one term in [lo,hi)."""
        p = list(prefix)
        out = []
        for row, d in zip(self.fwd, range(self.n)):
            terms = [int(t) for t in row if t]
            if len(terms) < len(p) + 1:
                continue
            if terms[: len(p)] == p and lo <= terms[len(p)] < hi:
                out.append(d)
        return sorted(out)[:k]

    # -- paper Fig 3: heap-based conjunctive ----------------------------------
    def heap_conjunctive(self, prefix: Sequence[int], lo: int, hi: int, k: int):
        prefix = [int(t) for t in prefix]
        if not prefix:
            return self.single_term_classic(lo, hi, k)
        plists = [self.plist(t) for t in prefix]
        if any(not l for l in plists):
            return []
        # intersection iterator over the prefix lists
        def intersection():
            short = min(plists, key=len)
            others = [l for l in plists if l is not short]
            for x in short:
                ok = True
                for l in others:
                    i = bisect_left(l, x)
                    if i >= len(l) or l[i] != x:
                        ok = False
                        break
                if ok:
                    yield x

        iters = []
        for t in range(lo, hi):
            l = self.plist(t)
            if l:
                iters.append([l[0], t, 0])  # [current docid, term, ptr]
        heapq.heapify(iters)
        results = []
        for x in intersection():
            while iters:
                top = iters[0]
                if top[0] > x:
                    break
                if top[0] < x:
                    l = self.plist(top[1])
                    i = bisect_left(l, x, top[2])
                    if i < len(l):
                        heapq.heapreplace(iters, [l[i], top[1], i])
                    else:
                        heapq.heappop(iters)
                else:
                    results.append(x)
                    break
            if len(results) == k or not iters:
                break
        return results

    # -- paper Fig 5: forward search ------------------------------------------
    def fwd_conjunctive(self, prefix: Sequence[int], lo: int, hi: int, k: int,
                        extract=None):
        prefix = [int(t) for t in prefix]
        if not prefix:
            return self.single_term_rmq(lo, hi, k)
        plists = [self.plist(t) for t in prefix]
        if any(not l for l in plists):
            return []
        short = min(plists, key=len)
        others = [l for l in plists if l is not short]
        results = []
        for x in short:
            ok = True
            for l in others:
                i = bisect_left(l, x)
                if i >= len(l) or l[i] != x:
                    ok = False
                    break
            if not ok:
                continue
            terms = extract(x) if extract else [int(t) for t in self.fwd[x] if t]
            if any(lo <= t < hi for t in terms):
                results.append(x)
                if len(results) == k:
                    break
        return results

    # -- single-term engines ---------------------------------------------------
    def single_term_classic(self, lo: int, hi: int, k: int):
        """Classic k-way merge over all lists in range (the slow baseline)."""
        iters = []
        for t in range(lo, hi):
            l = self.plist(t)
            if l:
                iters.append((l[0], t, 0))
        heapq.heapify(iters)
        out = []
        while iters and len(out) < k:
            d, t, i = heapq.heappop(iters)
            if not out or out[-1] != d:
                out.append(d)
            l = self.plist(t)
            if i + 1 < len(l):
                heapq.heappush(iters, (l[i + 1], t, i + 1))
        return out

    def single_term_rmq(self, lo: int, hi: int, k: int):
        """Paper §3.3: RMQ over `minimal` with lazy iterator instantiation."""
        INF = 2**31 - 1
        minimal = np.full(self.n_terms + 2, INF, dtype=np.int64)
        for t, l in self.lists.items():
            minimal[t] = l[0]

        # (value, kind, payload): kind 0 = range (lo, hi) over minimal,
        # kind 1 = iterator (term, ptr)
        def rng(a, b):
            if a > b:
                return None
            seg = minimal[a : b + 1]
            i = int(np.argmin(seg))
            v = int(seg[i])
            if v == INF:
                return None
            return (v, 0, (a, b, a + i))

        heap = []
        r0 = rng(lo, hi - 1)
        if r0:
            heap.append(r0)
        heapq.heapify(heap)
        out = []
        while heap and len(out) < k:
            v, kind, payload = heapq.heappop(heap)
            if not out or out[-1] != v:
                out.append(v)
            if kind == 0:
                a, b, tstar = payload
                for r in (rng(a, tstar - 1), rng(tstar + 1, b)):
                    if r:
                        heapq.heappush(heap, r)
                l = self.plist(tstar)
                if len(l) > 1:
                    heapq.heappush(heap, (l[1], 1, (tstar, 1)))
            else:
                t, i = payload
                l = self.plist(t)
                if i + 1 < len(l):
                    heapq.heappush(heap, (l[i + 1], 1, (t, i + 1)))
        return out


class HybIndex:
    """Bast-Weber HYB baseline (SIGIR'06): inverted lists merged into blocks
    of consecutive term ids; each block stores (docid, termid) pairs sorted
    by docid. A conjunctive query intersects the prefix lists (as usual) and
    checks candidates against the blocks overlapping the suffix range —
    cheap when the range ~ covers blocks, at the price of storing termids.

    Block sizing follows the paper's c-parameter: blocks close when they
    hold >= c * total_postings postings.
    """

    def __init__(self, host: HostIndex, c: float = 1e-2):
        total = sum(len(l) for l in host.lists.values())
        cap = max(1, int(c * total))
        self.host = host
        self.blocks = []          # list of (t_lo, t_hi_incl, docids[], termids[])
        cur_d, cur_t = [], []
        t_lo = 1
        for t in range(1, host.n_terms + 1):
            for d in host.plist(t):
                cur_d.append(d)
                cur_t.append(t)
            if len(cur_d) >= cap and t >= t_lo:
                order = np.argsort(np.asarray(cur_d), kind="stable")
                self.blocks.append((t_lo, t,
                                    np.asarray(cur_d)[order],
                                    np.asarray(cur_t)[order]))
                cur_d, cur_t = [], []
                t_lo = t + 1
        if cur_d:
            order = np.argsort(np.asarray(cur_d), kind="stable")
            self.blocks.append((t_lo, host.n_terms,
                                np.asarray(cur_d)[order],
                                np.asarray(cur_t)[order]))

    def space_bytes(self) -> int:
        return sum(len(d) * 8 for _, _, d, _ in self.blocks)

    def _range_blocks(self, lo: int, hi: int):
        return [b for b in self.blocks if b[0] < hi and b[1] >= lo]

    def conjunctive(self, prefix, lo: int, hi: int, k: int):
        """Candidates from the prefix intersection, suffix check via blocks."""
        from bisect import bisect_left
        prefix = [int(t) for t in prefix]
        blocks = self._range_blocks(lo, hi)
        if not prefix:
            # single-term: k smallest docids in the union of range lists,
            # scanned from the blocks (docid-sorted)
            out = []
            ptrs = [0] * len(blocks)
            import heapq
            heap = []
            for i, (_, _, dd, tt) in enumerate(blocks):
                for j in range(len(dd)):
                    if lo <= tt[j] < hi:
                        heap.append((int(dd[j]), i, j))
                        break
            heapq.heapify(heap)
            while heap and len(out) < k:
                d, i, j = heapq.heappop(heap)
                if not out or out[-1] != d:
                    out.append(d)
                _, _, dd, tt = blocks[i]
                j += 1
                while j < len(dd):
                    if lo <= tt[j] < hi:
                        heapq.heappush(heap, (int(dd[j]), i, j))
                        break
                    j += 1
            return out
        plists = [self.host.plist(t) for t in prefix]
        if any(not l for l in plists):
            return []
        short = min(plists, key=len)
        others = [l for l in plists if l is not short]
        results = []
        for x in short:
            ok = True
            for l in others:
                i = bisect_left(l, x)
                if i >= len(l) or l[i] != x:
                    ok = False
                    break
            if not ok:
                continue
            hit = False
            for _, _, dd, tt in blocks:
                i = np.searchsorted(dd, x, side="left")
                while i < len(dd) and dd[i] == x:
                    if lo <= tt[i] < hi:
                        hit = True
                        break
                    i += 1
                if hit:
                    break
            if hit:
                results.append(x)
                if len(results) == k:
                    break
        return results
