"""Completions as integer (multi-)sets + the docids map (paper §3.2).

TPU adaptation (DESIGN.md §2): the integer trie becomes a *columnar* sorted
term matrix. Descending one trie level == one range-restricted binary search in
a sorted column, so LocatePrefix(prefix, [l,r]) is ``len(prefix)+1`` fixed-depth
binary searches — no pointers, fully batchable. The forward index (docid ->
term set) is the same matrix indexed by docid, used by conjunctive forward
search and Reporting.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from .types import MAX_TERMS, INF_DOCID, pytree_dataclass
from .searching import ranged_searchsorted


@pytree_dataclass(meta_fields=("n", "max_terms"))
class Completions:
    cols: jnp.ndarray       # int32[M, N]: column j = j-th term of each lex-sorted completion
    docids: jnp.ndarray     # int32[N]: lex position -> docid (score rank, 0 = best)
    fwd_terms: jnp.ndarray  # int32[N, M]: docid -> term ids (the forward index)
    n_terms_per: jnp.ndarray  # int32[N]: docid -> number of terms
    n: int
    max_terms: int

    # -- construction (host) -------------------------------------------------
    @staticmethod
    def build(term_rows: np.ndarray, scores: np.ndarray) -> "Completions":
        """term_rows: int32[N, M] 1-based term ids (0 pad), one row per completion.

        ``scores`` (higher = better) define docids: docid = rank under
        (-score, lexicographic row) — the paper's decreasing-score assignment
        with lexicographic tie-break.
        """
        term_rows = np.asarray(term_rows, dtype=np.int32)
        n, m = term_rows.shape
        # score rank (docid): sort by (-score, row lex)
        order = np.lexsort(tuple(term_rows[:, j] for j in range(m - 1, -1, -1)) + (-scores,))
        docid_of_row = np.empty(n, dtype=np.int32)
        docid_of_row[order] = np.arange(n, dtype=np.int32)
        # lexicographic order of completions
        lex = np.lexsort(tuple(term_rows[:, j] for j in range(m - 1, -1, -1)))
        cols = term_rows[lex].T.copy()                      # [M, N]
        docids = docid_of_row[lex].copy()                   # [N]
        fwd = np.zeros_like(term_rows)
        fwd[docid_of_row] = term_rows                       # docid -> terms
        nt = (term_rows != 0).sum(axis=1).astype(np.int32)
        nterms = np.zeros(n, dtype=np.int32)
        nterms[docid_of_row] = nt
        return Completions(
            cols=jnp.asarray(cols),
            docids=jnp.asarray(docids),
            fwd_terms=jnp.asarray(fwd),
            n_terms_per=jnp.asarray(nterms),
            n=n,
            max_terms=m,
        )

    # -- queries --------------------------------------------------------------
    def locate_prefix(self, prefix_ids, prefix_len, term_lo, term_hi):
        """Lexicographic range [p, q) of completions prefixed by
        prefix_ids[:prefix_len] followed by any term id in [term_lo, term_hi).

        All args are per-query scalars; vmap for batches. Empty -> p == q.
        """
        lo = jnp.int32(0)
        hi = jnp.int32(self.n)
        for j in range(self.max_terms):          # static unroll: trie descent
            active = j < prefix_len
            t = prefix_ids[j]
            nlo = ranged_searchsorted(self.cols[j], t, lo, hi, side="left")
            nhi = ranged_searchsorted(self.cols[j], t, lo, hi, side="right")
            lo = jnp.where(active, nlo, lo)
            hi = jnp.where(active, nhi, hi)
        # final level: any term in [term_lo, term_hi)
        col = self.cols[jnp.minimum(prefix_len, self.max_terms - 1)]
        p = ranged_searchsorted(col, term_lo, lo, hi, side="left")
        q = ranged_searchsorted(col, term_hi, lo, hi, side="left")
        ok = prefix_len < self.max_terms
        return jnp.where(ok, p, 0), jnp.where(ok, q, 0)

    def extract(self, docid):
        """docid -> (term_ids int32[M], n_terms). INF/invalid -> zeros."""
        valid = (docid >= 0) & (docid < self.n)
        idx = jnp.clip(docid, 0, self.n - 1)
        row = jnp.where(valid, self.fwd_terms[idx], 0)
        return row, jnp.where(valid, self.n_terms_per[idx], 0)

    def space_bytes(self) -> int:
        return int(self.cols.nbytes + self.docids.nbytes)

    def fwd_space_bytes(self) -> int:
        return int(self.fwd_terms.nbytes + self.n_terms_per.nbytes)
