"""QAC search engines (paper §3.1, §3.3) — batched TPU formulations.

Three device-side engines:

  * ``prefix_search_topk``   — Fig 1a: trie-descent LocatePrefix + RMQ top-k.
  * ``conjunctive_multi``    — Fig 5 (Fwd): intersection of prefix posting
    lists iterated in docid (= score) order, forward-index range check, first-k
    compaction. The intersection is probe-based (each candidate lane binary-
    searches the other lists) — the SIMD替 of NextGeq iterator merging.
  * ``single_term_topk``     — paper §3.3 "Single-Term Queries": RMQ over the
    ``minimal`` array with lazily instantiated list iterators, as a dense-slot
    loop (no heap). Single-term queries are the most frequent in production.

The per-query functions (``jax.vmap`` them for batches) are the parity
reference; the serving hot path uses the batch-native ``*_batch`` engines
below, whose inner loops issue ONE batched RMQ / conjunctive-scan per step
across all B lanes and can route through the Pallas kernels — per-pop
``kernels/rmq`` and ``kernels/intersect`` (ISSUE 2), or the whole
single-term trip loop fused into ``kernels/heap_topk`` when the index
statically fits VMEM (ISSUE 3; see the ROADMAP kernel-routing policy).
Results are docids, ascending == best-score-first; INF_DOCID pads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .types import INF_DOCID
from .searching import ranged_searchsorted
from .rmq import RangeMin, topk_in_range
from .completions import Completions
from .inverted_index import InvertedIndex
from .dictionary import TermDictionary

INT32_MAX = jnp.iinfo(jnp.int32).max


# --------------------------------------------------------------------------
# prefix-search (Fig 1a)
# --------------------------------------------------------------------------
def prefix_search_topk(completions: Completions, rmq_docids: RangeMin,
                       prefix_ids, prefix_len, term_lo, term_hi, k: int):
    """Top-k docids of completions prefixed by prefix + suffix-range."""
    p, q = completions.locate_prefix(prefix_ids, prefix_len, term_lo, term_hi)
    vals, _ = topk_in_range(rmq_docids, p, q, k)
    bad = term_lo >= term_hi
    return jnp.where(bad, INF_DOCID, vals)


# --------------------------------------------------------------------------
# conjunctive-search, multi-term (Fig 5: forward / Fwd engine)
# --------------------------------------------------------------------------
def conjunctive_multi(index: InvertedIndex, completions, prefix_ids,
                      prefix_len, term_lo, term_hi, k: int,
                      *, tile: int = 128, max_tiles: int = 4096):
    """Per-query conjunctive search with >= 1 prefix terms.

    prefix_ids: int32[PMAX] 1-based (0 pad); term range [term_lo, term_hi).
    Iterates the shortest prefix list in ``tile``-wide chunks; each lane
    checks membership in the other lists (binary-search probes) and the
    forward-index range test, then first-k hits are compacted in docid order.

    ``completions`` is either a Completions or any object with an
    ``extract(docid) -> (terms[M], n)`` method (e.g. a stripe-local forward
    index for the distributed path).
    """
    PMAX = prefix_ids.shape[0]
    valid_t = jnp.arange(PMAX) < prefix_len
    lens = jax.vmap(index.list_len)(prefix_ids)
    lens = jnp.where(valid_t, lens, jnp.iinfo(jnp.int32).max)
    driver = jnp.argmin(lens)                       # slot of shortest list
    d_start, d_end = index.list_bounds(prefix_ids[driver])
    d_len = d_end - d_start

    n_post = index.postings.shape[0]
    lane = jnp.arange(tile, dtype=jnp.int32)

    starts, ends = jax.vmap(index.list_bounds)(prefix_ids)  # [PMAX]

    def cond(state):
        t, found, _ = state
        return (t * tile < d_len) & (found < k) & (t < max_tiles)

    def body(state):
        t, found, res = state
        base = d_start + t * tile
        idx = jnp.minimum(base + lane, n_post - 1)
        cand = index.postings[idx]                              # [T]
        in_list = (base + lane) < d_end
        # membership probes into every other prefix list
        member = jnp.ones((tile,), bool)
        for j in range(PMAX):
            need = (j < prefix_len) & (j != driver)
            pos = jax.vmap(
                lambda v: ranged_searchsorted(index.postings, v, starts[j], ends[j], side="left")
            )(cand)
            hit = (pos < ends[j]) & (index.postings[jnp.minimum(pos, n_post - 1)] == cand)
            member &= jnp.where(need, hit, True)
        # forward-index suffix-range check (Fig 5 line 6)
        rows, _ = jax.vmap(completions.extract)(cand)           # [T, M]
        fwd_ok = jnp.any((rows >= term_lo) & (rows < term_hi), axis=1)
        hits = in_list & member & fwd_ok
        # first-k compaction in docid order
        pos_out = found + jnp.cumsum(hits.astype(jnp.int32)) - 1
        write = hits & (pos_out < k)
        res = res.at[jnp.where(write, pos_out, k)].set(
            jnp.where(write, cand, res[jnp.minimum(pos_out, k)]), mode="drop"
        )
        found = jnp.minimum(found + hits.sum(dtype=jnp.int32), k)
        return t + 1, found, res

    res0 = jnp.full((k + 1,), INF_DOCID, jnp.int32)
    _, _, res = lax.while_loop(cond, body, (jnp.int32(0), jnp.int32(0), res0))
    bad = (term_lo >= term_hi) | (prefix_len <= 0) | jnp.any(jnp.where(valid_t, prefix_ids == 0, False))
    return jnp.where(bad, INF_DOCID, res[:k])


# --------------------------------------------------------------------------
# conjunctive-search, single term (paper §3.3, RMQ over `minimal`)
# --------------------------------------------------------------------------
def _single_term_state(rmq_minimal: RangeMin, term_lo, term_hi, k: int,
                       iters: int):
    """Initial dense-slot heap state for the single-term engine."""
    cap = 2 * iters + 1
    hi_incl = term_hi - 1
    pos0, val0 = rmq_minimal.query(term_lo, hi_incl)
    kind = jnp.zeros((cap,), jnp.int32)
    lo_a = jnp.zeros((cap,), jnp.int32).at[0].set(term_lo)
    hi_a = jnp.full((cap,), -1, jnp.int32).at[0].set(hi_incl)
    pos_a = jnp.zeros((cap,), jnp.int32).at[0].set(pos0)     # range: argmin term; iter: ptr
    val_a = jnp.full((cap,), INF_DOCID, jnp.int32).at[0].set(
        jnp.where(term_lo <= hi_incl, val0, INF_DOCID)
    )
    out = jnp.full((k,), INF_DOCID, jnp.int32)
    return (kind, lo_a, hi_a, pos_a, val_a, out, jnp.int32(0), jnp.int32(1),
            jnp.int32(-1))


def _single_term_body(index: InvertedIndex, rmq_minimal: RangeMin, k: int):
    """One pop of the dense-slot lazy-iterator heap, shared by the fixed-trip
    (branchless fused / striped) and bounded-trip (routed frontend) engines."""

    def body(i, state):
        kind, lo_a, hi_a, pos_a, val_a, out, n_out, nf, prev = state
        best = jnp.argmin(val_a)
        bval = val_a[best]
        found = bval < INF_DOCID
        is_range = kind[best] == 0
        # ---- emit (dedup against previous emission) ----
        emit = found & (bval != prev)
        out = out.at[jnp.where(emit, n_out, k)].set(bval, mode="drop")
        n_out = n_out + emit.astype(jnp.int32)
        prev = jnp.where(found, bval, prev)
        # ---- range pop: split + instantiate iterator ----
        tstar = pos_a[best]                                   # term with the min
        lo, hi = lo_a[best], hi_a[best]
        lpos, lval = rmq_minimal.query(lo, tstar - 1)
        lval = jnp.where((lo <= tstar - 1) & found & is_range, lval, INF_DOCID)
        rpos, rval = rmq_minimal.query(tstar + 1, hi)
        rval = jnp.where((tstar + 1 <= hi) & found & is_range, rval, INF_DOCID)
        it_start, it_end = index.list_bounds(tstar)
        it_ptr = it_start + 1                                  # minimal was postings[start]
        it_val = jnp.where(
            (it_ptr < it_end) & found & is_range,
            index.postings[jnp.minimum(it_ptr, index.postings.shape[0] - 1)],
            INF_DOCID,
        )
        # ---- iterator pop: advance ----
        adv_ptr = pos_a[best] + 1
        _, adv_end = index.list_bounds(lo_a[best])             # iterator stores term in lo_a
        adv_val = jnp.where(
            (adv_ptr < adv_end) & found & (~is_range),
            index.postings[jnp.minimum(adv_ptr, index.postings.shape[0] - 1)],
            INF_DOCID,
        )
        # ---- write popped slot ----
        kind = kind.at[best].set(jnp.where(is_range, 0, 1))
        lo_a = lo_a.at[best].set(jnp.where(is_range, lo, lo_a[best]))
        hi_a = hi_a.at[best].set(jnp.where(is_range, tstar - 1, hi_a[best]))
        pos_a = pos_a.at[best].set(jnp.where(is_range, lpos, adv_ptr))
        val_a = val_a.at[best].set(jnp.where(is_range, lval, adv_val))
        # ---- two fresh slots (inactive unless a range was popped) ----
        live = found & is_range
        kind = kind.at[nf].set(0)
        lo_a = lo_a.at[nf].set(tstar + 1)
        hi_a = hi_a.at[nf].set(hi)
        pos_a = pos_a.at[nf].set(rpos)
        val_a = val_a.at[nf].set(jnp.where(live, rval, INF_DOCID))
        kind = kind.at[nf + 1].set(1)
        lo_a = lo_a.at[nf + 1].set(tstar)                      # iterator: term id here
        hi_a = hi_a.at[nf + 1].set(-1)
        pos_a = pos_a.at[nf + 1].set(it_ptr)
        val_a = val_a.at[nf + 1].set(jnp.where(live, it_val, INF_DOCID))
        return kind, lo_a, hi_a, pos_a, val_a, out, n_out, nf + 2, prev

    return body


def single_term_topk(index: InvertedIndex, rmq_minimal: RangeMin,
                     term_lo, term_hi, k: int):
    """Top-k docids in the union of lists of terms in [term_lo, term_hi).

    Dense-slot version of the paper's lazy-iterator heap: a slot is either a
    `minimal`-range (kind 0) or a posting-list iterator (kind 1). An iterator
    is instantiated only when its list's minimum is popped — the paper's key
    saving. Runs 2k iterations with consecutive-duplicate suppression (a docid
    may appear in several lists of the range). Branchless and fixed-trip, so
    it composes with vmap/shard_map without data-dependent control flow.
    """
    iters = 2 * k
    state = _single_term_state(rmq_minimal, term_lo, term_hi, k, iters)
    state = lax.fori_loop(0, iters, _single_term_body(index, rmq_minimal, k),
                          state)
    out = state[5]
    bad = term_lo >= term_hi
    return jnp.where(bad, INF_DOCID, out)


def single_term_topk_bounded(index: InvertedIndex, rmq_minimal: RangeMin,
                             term_lo, term_hi, k: int, trips: int):
    """Single-term engine with a caller-chosen trip budget -> (out, done).

    ``done`` is True iff the result equals the full 2k-trip engine's: either k
    results were emitted (out is full; later pops are dropped) or the heap is
    exhausted (every remaining slot is INF). 2k trips are only ever needed when
    consecutive duplicate docids burn pops, so a short budget (k + slack)
    almost always completes; the caller re-runs the full engine on the rare
    incomplete lane. A short *fixed* fori_loop beats an early-exit while_loop
    here: under vmap, while_loop's masked batching costs more per trip than
    the trips it saves.
    """
    trips = min(trips, 2 * k)
    state = _single_term_state(rmq_minimal, term_lo, term_hi, k, trips)
    state = lax.fori_loop(0, trips, _single_term_body(index, rmq_minimal, k),
                          state)
    out, n_out, val_a = state[5], state[6], state[4]
    bad = term_lo >= term_hi
    # a full 2k budget IS the exact engine — never signal a fallback for it
    done = bad | (n_out >= k) | (jnp.min(val_a) >= INF_DOCID) | (trips >= 2 * k)
    return jnp.where(bad, INF_DOCID, out), done


# --------------------------------------------------------------------------
# full Complete() (Fig 1b) for a parsed query — used by serve/qac.py
# --------------------------------------------------------------------------
def complete_conjunctive(index, completions, rmq_minimal,
                         prefix_ids, prefix_len, term_lo, term_hi, k: int,
                         **kw):
    """Fused per-query Complete(): run BOTH engines, select branchlessly.

    This is the fallback for call sites that cannot partition the batch by
    query class (vmap over mixed lanes, the shard_map striped path). Batched
    serving should prefer ``serve.frontend.QACFrontend``, which classifies on
    the host (``prefix_len > 0`` == multi-term) and dispatches each sub-batch
    to only its engine — ``conjunctive_multi`` or ``single_term_topk`` — so
    the other engine's work isn't computed and discarded.
    """
    multi = conjunctive_multi(index, completions, prefix_ids, prefix_len,
                              term_lo, term_hi, k, **kw)
    single = single_term_topk(index, rmq_minimal, term_lo, term_hi, k)
    return jnp.where(prefix_len > 0, multi, single)


# ==========================================================================
# Batch-native engines (ISSUE 2 tentpole)
#
# Same math as the per-query engines above, restructured so the batch is the
# leading axis of every state array and each inner-loop step performs ONE
# batched RMQ (``RangeMin.query_batch`` over the concatenated left/right
# subranges of all lanes) or ONE ``conjunctive_scan`` tile for the whole
# batch. Outputs are bit-identical to ``vmap``-ing the per-query reference
# (tests/test_batched_engines.py).
# ==========================================================================
# VMEM ceiling for the heap_topk kernel: the engine's source arrays (RMQ
# values + sparse table + ib windows as int32, offsets, and raw OR
# compressed postings) stay resident for the whole launch, so they must fit
# on-chip with headroom for the heap scratch. The ceiling is platform-
# resolved (``compat.default_heap_kernel_max_bytes``, 12 MiB today) and
# caller-overridable (``QACArch.heap_kernel_max_bytes``). Larger corpora
# keep the per-pop batched-RMQ path — unless the compressed postings
# layout (``postings_codec``) shrinks them back under the gate.


def _heap_kernel_fits(index: InvertedIndex, rmq_minimal: RangeMin, *,
                      packed=None, max_bytes: int | None = None) -> bool:
    """Static (shape-level) VMEM-fit check for the heap_topk kernel.

    ``packed`` counts the compressed postings bytes (word stream + block
    directory) instead of raw CSR int32 — the whole point of ISSUE 7: the
    3-5x postings compression becomes a 3-5x larger kernel-eligible corpus.
    """
    if max_bytes is None:
        from ..compat import default_heap_kernel_max_bytes

        max_bytes = default_heap_kernel_max_bytes()
    b = 4 * (rmq_minimal.values.size + rmq_minimal.st_pos.size
             + rmq_minimal.ib.size          # ib is widened to int32 in-kernel
             + index.offsets.size)
    b += packed.nbytes() if packed is not None else 4 * index.postings.size
    return b <= max_bytes


def _resolve_packed(index: InvertedIndex, postings_codec: str | None):
    """Map the ``postings_codec`` knob to the index's PackedPostings.

    None/"auto" -> packed if the index carries one (routing still prefers
    raw when raw fits); "raw" -> never; "ef"/"bitpack" -> the index's
    packed postings, which must exist and match the requested codec.
    """
    codec = "auto" if postings_codec is None else postings_codec
    if codec == "raw":
        return None
    packed = getattr(index, "packed", None)
    if packed is None:
        if codec == "auto":
            return None
        raise ValueError(
            f"postings_codec={codec!r} but the index has no packed postings "
            f"(build it with postings_codec={codec!r})")
    if codec != "auto" and packed.codec != codec:
        raise ValueError(
            f"postings_codec={codec!r} but the index was packed as "
            f"{packed.codec!r}")
    return packed


def describe_single_route(index: InvertedIndex, rmq_minimal: RangeMin, *,
                          use_kernel: bool = False,
                          heap_kernel: bool | None = None,
                          postings_codec: str | None = None,
                          heap_kernel_max_bytes: int | None = None) -> str:
    """Host-side description of the single-term route
    ``single_term_topk_bounded_batch`` will take (ISSUE 10 tracing): the
    routing below is STATIC — a pure function of index shapes and knobs,
    decided at trace time — so observability can name it without running
    the engine. Mirrors the routing block in
    ``single_term_topk_bounded_batch`` and must stay in sync with it.
    Returns e.g. ``"heap_topk[raw]"``, ``"heap_topk[ef]"``,
    ``"per_pop_rmq[kernel]"``, ``"per_pop_rmq[xla]"``.
    """
    packed = _resolve_packed(index, postings_codec)
    explicit = postings_codec not in (None, "auto", "raw")
    if heap_kernel is None:
        heap_kernel = False
        if use_kernel:
            fit_raw = _heap_kernel_fits(index, rmq_minimal,
                                        max_bytes=heap_kernel_max_bytes)
            fit_pk = packed is not None and _heap_kernel_fits(
                index, rmq_minimal, packed=packed,
                max_bytes=heap_kernel_max_bytes)
            if explicit:
                heap_kernel = fit_pk
            elif fit_raw:
                heap_kernel, packed = True, None
            elif fit_pk:
                heap_kernel = True
    elif heap_kernel and not explicit:
        packed = None
    if heap_kernel:
        codec = packed.codec if packed is not None else "raw"
        return f"heap_topk[{codec}]"
    return f"per_pop_rmq[{'kernel' if use_kernel else 'xla'}]"


def single_term_topk_bounded_batch(index: InvertedIndex,
                                   rmq_minimal: RangeMin, term_lo, term_hi,
                                   k: int, trips: int, *,
                                   use_kernel: bool = False,
                                   interpret: bool | None = None,
                                   heap_kernel: bool | None = None,
                                   postings_codec: str | None = None,
                                   heap_kernel_max_bytes: int | None = None):
    """Batch-native ``single_term_topk_bounded``: term_lo/hi int32[B].

    Returns (out int32[B, k], done bool[B]), bit-identical to vmap of the
    per-query engine. Kernel routing (ROADMAP PR 3 + 7): ``use_kernel=True``
    first tries the fused heap_topk kernel — the WHOLE trip loop in one
    Pallas launch with the heap state in VMEM scratch — whenever the
    engine's source arrays statically fit on-chip; otherwise each pop's RMQ
    dispatches to the batched-RMQ kernel. ``heap_kernel`` overrides the
    automatic fit gate (None = auto; True forces the heap_topk subsystem,
    whose ops layer still honors ``use_kernel`` for its Pallas-vs-XLA
    choice). The default XLA path is the in-block-window gather formulation
    of ``RangeMin.query_batch``.

    ``postings_codec`` picks the kernel's postings representation:
    None/"auto" keeps raw CSR when it fits the VMEM gate and falls back to
    the index's compressed layout (``index.packed``) when only that fits;
    "raw" pins raw; "ef"/"bitpack" pin the compressed layout (in-kernel
    ``codecs.packed_lookup`` decode — bit-identical either way). The
    per-pop fallback path always reads raw CSR (it lives in HBM there; no
    VMEM gate to win back). ``heap_kernel_max_bytes`` overrides the
    platform ceiling (None = ``compat.default_heap_kernel_max_bytes``).
    """
    trips = min(trips, 2 * k)
    bad = term_lo >= term_hi
    packed = _resolve_packed(index, postings_codec)
    explicit = postings_codec not in (None, "auto", "raw")
    if heap_kernel is None:
        heap_kernel = False
        if use_kernel:
            fit_raw = _heap_kernel_fits(index, rmq_minimal,
                                        max_bytes=heap_kernel_max_bytes)
            fit_pk = packed is not None and _heap_kernel_fits(
                index, rmq_minimal, packed=packed,
                max_bytes=heap_kernel_max_bytes)
            if explicit:          # caller pinned the codec: packed or bust
                heap_kernel = fit_pk
            elif fit_raw:         # auto: raw wins when it fits (no decode)
                heap_kernel, packed = True, None
            elif fit_pk:          # auto: compression extends the gate
                heap_kernel = True
    elif heap_kernel and not explicit:
        packed = None             # forced kernel route defaults to raw
    if heap_kernel:
        from ..kernels.heap_topk.ops import heap_topk

        out, done = heap_topk(
            rmq_minimal.values, rmq_minimal.st_pos, rmq_minimal.ib,
            index.offsets, index.postings, term_lo, term_hi,
            k=k, trips=trips, n=rmq_minimal.n, n_terms=index.n_terms,
            use_kernel=use_kernel, interpret=interpret, packed=packed)
    else:
        # same engine loop, one pop at a time (the ONE copy lives in
        # kernels/heap_topk/ref.py); the rmq_fn hook lets each pop's 2B-lane
        # RMQ route through the batched-RMQ Pallas kernel or the XLA
        # gather formulation per ``use_kernel``
        from ..kernels.heap_topk.ref import heap_topk_ref

        out, done = heap_topk_ref(
            rmq_minimal.values, rmq_minimal.st_pos, rmq_minimal.ib,
            index.offsets, index.postings, term_lo, term_hi,
            k=k, trips=trips, n=rmq_minimal.n, n_terms=index.n_terms,
            rmq_fn=lambda p, q: rmq_minimal.query_batch(
                p, q, use_kernel=use_kernel, interpret=interpret))
    done = bad | done | (trips >= 2 * k)
    return jnp.where(bad[:, None], INF_DOCID, out), done


def single_term_topk_batch(index: InvertedIndex, rmq_minimal: RangeMin,
                           term_lo, term_hi, k: int, *,
                           use_kernel: bool = False,
                           interpret: bool | None = None,
                           heap_kernel: bool | None = None,
                           postings_codec: str | None = None,
                           heap_kernel_max_bytes: int | None = None):
    """Batch-native ``single_term_topk`` (full 2k-trip budget, always exact)."""
    out, _ = single_term_topk_bounded_batch(
        index, rmq_minimal, term_lo, term_hi, k, 2 * k,
        use_kernel=use_kernel, interpret=interpret, heap_kernel=heap_kernel,
        postings_codec=postings_codec,
        heap_kernel_max_bytes=heap_kernel_max_bytes)
    return out


def _extract_rows(completions, docids):
    """Batched forward-index rows [..., M] via the object's own ``extract``
    (Completions or LocalFwd) — the docid->row contract stays in one place."""
    fn = lambda d: completions.extract(d)[0]
    for _ in range(docids.ndim):
        fn = jax.vmap(fn)
    return fn(docids)


def conjunctive_multi_batch(index: InvertedIndex, completions, prefix_ids,
                            prefix_len, term_lo, term_hi, k: int,
                            *, tile: int = 128, max_tiles: int = 4096,
                            use_kernel: bool = False,
                            interpret: bool | None = None,
                            list_pad: int = 8192, probe_iters: int = 0,
                            postings_codec: str | None = None):
    """Batch-native ``conjunctive_multi``: prefix_ids int32[B, PMAX], the
    rest int32[B]. Bit-identical to vmap of the per-query engine.

    Each step processes one ``tile``-wide candidate chunk for ALL lanes:
    the membership probes + forward-range check either run as batched
    ranged binary searches (XLA path) or as ONE fused
    ``kernels.intersect.ops.conjunctive_scan`` call (``use_kernel=True``).
    The kernel path holds the probe lists in VMEM, so it requires every
    needed probe list to fit in ``list_pad`` (a power of two); callers with
    host visibility (serve/frontend.py) check the bound before dispatching.
    Per-lane progress is masked exactly like vmap's batched ``while_loop``:
    a finished lane stops advancing while others continue.

    The XLA probes run as PMAX sequential [B, tile] ranged searches — one
    per prefix slot — NOT one [B, PMAX, tile] fused search: the fused form's
    per-iteration temporaries blow the cache on CPU (measured 4.5x slower at
    B=256) while the per-slot form keeps the tile resident; the results are
    bit-identical (PR 3 fused-path regression fix). ``probe_iters`` caps the
    binary-search depth — callers that host-verify the longest probe list
    (serve/frontend.py) pass ``log2(list_pad)+1`` instead of the global
    ``log2(n_postings)+1`` bound; 0 keeps the global bound.

    ``postings_codec`` (kernel path only): "ef"/"bitpack" switch the probes
    to ``kernels.intersect.ops.conjunctive_scan_packed`` — no [B, P, L]
    probe-list gather at all; the kernel pins the compressed postings index
    in VMEM and binary-searches each [start, end) span with in-kernel
    decode. The fit condition becomes the packed index bytes (the caller
    verifies it on the host, like list_pad), and ``list_pad`` no longer
    truncates. Bit-identical to the raw probes.
    """
    B, PMAX = prefix_ids.shape
    rows = jnp.arange(B)
    valid_t = jnp.arange(PMAX)[None, :] < prefix_len[:, None]      # [B, PMAX]
    starts, ends = index.list_bounds(prefix_ids)                   # [B, PMAX]
    lens = jnp.where(valid_t, ends - starts, INT32_MAX)
    driver = jnp.argmin(lens, axis=1)                              # [B]
    d_start = starts[rows, driver]
    d_end = ends[rows, driver]
    d_len = d_end - d_start

    n_post = index.postings.shape[0]
    lane = jnp.arange(tile, dtype=jnp.int32)
    need = valid_t & (jnp.arange(PMAX)[None, :] != driver[:, None])  # [B, PMAX]

    packed = _resolve_packed(index, postings_codec) if (
        use_kernel and postings_codec not in (None, "auto", "raw")) else None
    if use_kernel and packed is not None:
        from ..kernels.intersect.ops import conjunctive_scan_packed

        # compressed probe route: per-slot [start, end) spans instead of
        # gathered list tiles; start == end marks unused/empty slots and an
        # empty-but-needed list still kills its lane outright
        k_starts = jnp.where(need, starts, 0).astype(jnp.int32)
        k_ends = jnp.where(need, ends, 0).astype(jnp.int32)
        lane_dead = jnp.any(need & (ends == starts), axis=1)       # [B]
    elif use_kernel:
        from ..kernels.intersect.ops import conjunctive_scan

        assert list_pad & (list_pad - 1) == 0, "list_pad must be a power of two"
        # probe lists gathered once to [B, PMAX, L] (VMEM-resident in the
        # kernel); unused slots get length 0. An empty-but-needed list (a
        # stripe holding none of a term's postings) kills its lane outright.
        lpos = jnp.arange(list_pad)
        g_idx = jnp.minimum(starts[:, :, None] + lpos[None, None, :],
                            n_post - 1)
        in_l = (starts[:, :, None] + lpos[None, None, :]) < ends[:, :, None]
        lists = jnp.where(in_l & need[:, :, None], index.postings[g_idx],
                          INF_DOCID)
        k_lens = jnp.where(need, jnp.minimum(ends - starts, list_pad), 0)
        lane_dead = jnp.any(need & (ends == starts), axis=1)       # [B]

    def active_of(state):
        t, found, _ = state
        return (t * tile < d_len) & (found < k) & (t < max_tiles)

    def cond(state):
        return jnp.any(active_of(state))

    def body(state):
        t, found, res = state
        active = active_of(state)
        base = d_start + t * tile
        idx = jnp.minimum(base[:, None] + lane[None, :], n_post - 1)
        cand = index.postings[idx]                                  # [B, T]
        in_list = (base[:, None] + lane[None, :]) < d_end[:, None]
        if use_kernel and packed is not None:
            mask = conjunctive_scan_packed(
                jnp.where(in_list, cand, INF_DOCID), k_starts, k_ends,
                _extract_rows(completions, cand), term_lo, term_hi, packed,
                use_kernel=True, interpret=interpret,
                probe_iters=probe_iters)
            hits = mask & in_list & ~lane_dead[:, None]
        elif use_kernel:
            mask = conjunctive_scan(
                jnp.where(in_list, cand, INF_DOCID), lists, k_lens,
                _extract_rows(completions, cand), term_lo, term_hi,
                use_kernel=True, interpret=interpret)
            hits = mask & in_list & ~lane_dead[:, None]
        else:
            # PMAX sequential [B, T] ranged searches (cache-resident tiles;
            # see the docstring) — bit-identical to the fused [B, PMAX, T]
            # form and to the scalar/vmap per-list probes
            member = jnp.ones((B, tile), bool)
            for j in range(PMAX):
                pos = ranged_searchsorted(
                    index.postings, cand,
                    jnp.broadcast_to(starts[:, j:j + 1], (B, tile)),
                    jnp.broadcast_to(ends[:, j:j + 1], (B, tile)),
                    side="left", max_iters=probe_iters)
                hit = (pos < ends[:, j:j + 1]) & (
                    index.postings[jnp.minimum(pos, n_post - 1)] == cand)
                member &= jnp.where(need[:, j:j + 1], hit, True)
            fwd_rows = _extract_rows(completions, cand)             # [B, T, M]
            fwd_ok = jnp.any((fwd_rows >= term_lo[:, None, None])
                             & (fwd_rows < term_hi[:, None, None]), axis=2)
            hits = in_list & member & fwd_ok
        hits &= active[:, None]                # frozen lanes make no progress
        # first-k compaction in docid order (per lane)
        pos_out = found[:, None] + jnp.cumsum(hits.astype(jnp.int32), 1) - 1
        write = hits & (pos_out < k)
        res = res.at[rows[:, None], jnp.where(write, pos_out, k)].set(
            jnp.where(write, cand,
                      res[rows[:, None], jnp.minimum(pos_out, k)]),
            mode="drop")
        found = jnp.minimum(found + hits.sum(axis=1, dtype=jnp.int32), k)
        return jnp.where(active, t + 1, t), found, res

    res0 = jnp.full((B, k + 1), INF_DOCID, jnp.int32)
    t0 = jnp.zeros((B,), jnp.int32)
    _, _, res = lax.while_loop(cond, body, (t0, t0, res0))
    bad = ((term_lo >= term_hi) | (prefix_len <= 0)
           | jnp.any(jnp.where(valid_t, prefix_ids == 0, False), axis=1))
    return jnp.where(bad[:, None], INF_DOCID, res[:, :k])


def complete_conjunctive_batch(index, completions, rmq_minimal,
                               prefix_ids, prefix_len, term_lo, term_hi,
                               k: int, *, use_kernel: bool = False,
                               interpret: bool | None = None,
                               heap_kernel: bool | None = None,
                               postings_codec: str | None = None,
                               heap_kernel_max_bytes: int | None = None,
                               **kw):
    """Batch-native fused Complete(): both engines + branchless select.

    The fallback for call sites that cannot partition by query class (the
    shard_map striped path, mixed jit-only batches); class-pure traffic
    should go through ``serve.frontend.QACFrontend``.

    Each engine runs under a ``lax.cond`` on whether its class is present
    at all, so a class-pure batch (every lane single-term, or every lane
    multi-term) skips the other engine entirely instead of computing and
    discarding it — the jit-only analogue of the frontend's host routing
    (PR 3 fused-path fix). Mixed batches still pay for both engines; the
    select stays branchless and bit-identical either way, because a lane
    only ever reads the engine of its own class.

    ``use_kernel`` routes the single-term engine through Pallas (the fused
    heap_topk kernel when the index statically fits VMEM, else the per-pop
    batched-RMQ kernel). The intersect kernel is deliberately NOT enabled
    here: it is only correct when every probe list fits its static
    ``list_pad``, a bound that needs host visibility — jit-only call sites
    cannot verify it, so they keep the XLA probe path (see the ROADMAP
    kernel-routing policy).
    """
    is_multi = prefix_len > 0
    absent = jnp.full((prefix_len.shape[0], k), INF_DOCID, jnp.int32)
    multi = lax.cond(
        jnp.any(is_multi),
        lambda: conjunctive_multi_batch(index, completions, prefix_ids,
                                        prefix_len, term_lo, term_hi, k,
                                        use_kernel=False,
                                        interpret=interpret, **kw),
        lambda: absent)
    single = lax.cond(
        jnp.any(~is_multi),
        lambda: single_term_topk_batch(
            index, rmq_minimal, term_lo, term_hi, k, use_kernel=use_kernel,
            interpret=interpret, heap_kernel=heap_kernel,
            postings_codec=postings_codec,
            heap_kernel_max_bytes=heap_kernel_max_bytes),
        lambda: absent)
    return jnp.where(is_multi[:, None], multi, single)
