"""Range-minimum queries + heap-free top-k-in-range (paper §3.2).

TPU adaptation (DESIGN.md §2): the succinct BP cartesian tree (2n+o(n) bits,
pointer-chasing rank/select) is replaced by a two-level structure that is
VPU-idiomatic:

  * 128-wide blocks; a block min is one masked lane reduction (one VREG op);
  * a sparse table of argmin positions over the ~n/128 block minima.

A query is <= 4 candidate positions (left partial block, two overlapping
sparse-table windows, right partial block) -> one small argmin. The paper's
Θ(k log k) heap-of-subranges top-k becomes a fixed k-step loop over a dense
(k+1)-slot buffer: pop = argmin over slots, push = write two subranges. For
k = 10 a dense argmin beats heap bookkeeping on vector hardware and returns
identical results.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from .types import INF_DOCID, pytree_dataclass

BLOCK = 128


@pytree_dataclass(meta_fields=("n", "n_blocks", "levels"))
class RangeMin:
    values: jnp.ndarray      # int32[n_pad] (INF padded)
    st_pos: jnp.ndarray      # int32[levels, n_blocks]: global argmin positions
    n: int
    n_blocks: int
    levels: int

    @staticmethod
    def build(values: np.ndarray) -> "RangeMin":
        v = np.asarray(values, dtype=np.int64)
        n = len(v)
        n_pad = ((n + BLOCK - 1) // BLOCK) * BLOCK
        vp = np.full(n_pad, INF_DOCID, dtype=np.int64)
        vp[:n] = v
        nb = n_pad // BLOCK
        blocks = vp.reshape(nb, BLOCK)
        base = np.arange(nb) * BLOCK
        pos0 = base + blocks.argmin(axis=1)
        levels = max(1, int(np.ceil(np.log2(max(nb, 1)))) + 1)
        st = np.zeros((levels, nb), dtype=np.int32)
        st[0] = pos0
        for j in range(1, levels):
            half = 1 << (j - 1)
            prev = st[j - 1]
            other = st[j - 1][np.minimum(np.arange(nb) + half, nb - 1)]
            take_other = vp[other] < vp[prev]
            st[j] = np.where(take_other, other, prev)
        return RangeMin(
            values=jnp.asarray(vp.astype(np.int32)),
            st_pos=jnp.asarray(st),
            n=n,
            n_blocks=nb,
            levels=levels,
        )

    # -- single query (vmap for batches) --------------------------------------
    def query(self, p, q):
        """argmin over values[p..q] inclusive -> (pos, val).

        Invalid (p > q, or empty structure) -> (0, INF).
        """
        p = jnp.clip(p, 0, max(self.n - 1, 0)).astype(jnp.int32)
        qc = jnp.clip(q, 0, max(self.n - 1, 0)).astype(jnp.int32)
        bp, bq = p // BLOCK, qc // BLOCK
        lane = jnp.arange(BLOCK, dtype=jnp.int32)

        def partial(block, lo_lane, hi_lane):
            vals = lax.dynamic_slice(self.values, (block * BLOCK,), (BLOCK,))
            m = (lane >= lo_lane) & (lane <= hi_lane)
            vals = jnp.where(m, vals, INF_DOCID)
            a = jnp.argmin(vals)
            return block * BLOCK + a, vals[a]

        same = bp == bq
        # candidate 1: left partial block [p .. end or q]
        c1_pos, c1_val = partial(bp, p % BLOCK, jnp.where(same, qc % BLOCK, BLOCK - 1))
        # candidate 2: right partial block [start .. q]
        c2_pos, c2_val = partial(bq, 0, qc % BLOCK)
        c2_val = jnp.where(same, INF_DOCID, c2_val)
        # candidates 3,4: sparse table over middle blocks [bp+1 .. bq-1]
        cnt = bq - bp - 1
        has_mid = cnt > 0
        j = jnp.where(has_mid, 31 - lax.clz(jnp.maximum(cnt, 1)), 0)
        jc = jnp.minimum(j, self.levels - 1)
        lo_b = jnp.minimum(bp + 1, self.n_blocks - 1)
        hi_b = jnp.clip(bq - (1 << jc), 0, self.n_blocks - 1)
        c3_pos = self.st_pos[jc, lo_b]
        c4_pos = self.st_pos[jc, hi_b]
        c3_val = jnp.where(has_mid, self.values[c3_pos], INF_DOCID)
        c4_val = jnp.where(has_mid, self.values[c4_pos], INF_DOCID)

        pos = jnp.stack([c1_pos, c2_pos, c3_pos, c4_pos])
        val = jnp.stack([c1_val, c2_val, c3_val, c4_val])
        invalid = (p > qc) | (self.n == 0)
        val = jnp.where(invalid, INF_DOCID, val)
        best = jnp.argmin(val)
        return pos[best].astype(jnp.int32), val[best].astype(jnp.int32)

    def space_bytes(self) -> int:
        return int(self.st_pos.nbytes)  # values are shared with the owner


def topk_in_range(rmq: RangeMin, p, q, k: int):
    """k smallest values in rmq.values[p..q-1] (half-open), ascending.

    Returns (vals int32[k], pos int32[k]) padded with (INF, -1). This is the
    paper's heap-of-subranges algorithm with a dense (k+1)-slot buffer.
    """
    qi = q - 1  # inclusive
    pos0, val0 = rmq.query(p, qi)
    K = k + 1
    slot_lo = jnp.full((K,), 0, jnp.int32).at[0].set(p)
    slot_hi = jnp.full((K,), -1, jnp.int32).at[0].set(qi)
    slot_pos = jnp.zeros((K,), jnp.int32).at[0].set(pos0)
    slot_val = jnp.full((K,), INF_DOCID, jnp.int32).at[0].set(
        jnp.where(p <= qi, val0, INF_DOCID)
    )
    out_v = jnp.full((k,), INF_DOCID, jnp.int32)
    out_p = jnp.full((k,), -1, jnp.int32)

    def body(i, state):
        slot_lo, slot_hi, slot_pos, slot_val, out_v, out_p = state
        best = jnp.argmin(slot_val)
        bval = slot_val[best]
        found = bval < INF_DOCID
        out_v = out_v.at[i].set(bval)
        out_p = out_p.at[i].set(jnp.where(found, slot_pos[best], -1))
        lo, hi, pos = slot_lo[best], slot_hi[best], slot_pos[best]
        # left subrange replaces the popped slot
        l_lo, l_hi = lo, pos - 1
        lpos, lval = rmq.query(l_lo, l_hi)
        lval = jnp.where((l_lo <= l_hi) & found, lval, INF_DOCID)
        slot_lo = slot_lo.at[best].set(l_lo)
        slot_hi = slot_hi.at[best].set(l_hi)
        slot_pos = slot_pos.at[best].set(lpos)
        slot_val = slot_val.at[best].set(lval)
        # right subrange goes to the fresh slot i+1
        r_lo, r_hi = pos + 1, hi
        rpos, rval = rmq.query(r_lo, r_hi)
        rval = jnp.where((r_lo <= r_hi) & found, rval, INF_DOCID)
        slot_lo = slot_lo.at[i + 1].set(r_lo)
        slot_hi = slot_hi.at[i + 1].set(r_hi)
        slot_pos = slot_pos.at[i + 1].set(rpos)
        slot_val = slot_val.at[i + 1].set(rval)
        return slot_lo, slot_hi, slot_pos, slot_val, out_v, out_p

    state = (slot_lo, slot_hi, slot_pos, slot_val, out_v, out_p)
    state = lax.fori_loop(0, k, body, state)
    return state[4], state[5]
