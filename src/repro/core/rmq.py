"""Range-minimum queries + heap-free top-k-in-range (paper §3.2).

TPU adaptation (DESIGN.md §2): the succinct BP cartesian tree (2n+o(n) bits,
pointer-chasing rank/select) is replaced by a two-level structure that is
VPU-idiomatic:

  * 128-wide blocks; a block min is one masked lane reduction (one VREG op);
  * a sparse table of argmin positions over the ~n/128 block minima.

A query is <= 4 candidate positions (left partial block, two overlapping
sparse-table windows, right partial block) -> one small argmin. The paper's
Θ(k log k) heap-of-subranges top-k becomes a fixed k-step loop over a dense
(k+1)-slot buffer: pop = argmin over slots, push = write two subranges. For
k = 10 a dense argmin beats heap bookkeeping on vector hardware and returns
identical results.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from .types import INF_DOCID, pytree_dataclass

BLOCK = 128
IB_LEVELS = 7            # in-block windows 2^1 .. 2^7 (= BLOCK)


def build_inblock_table(vp: np.ndarray) -> np.ndarray:
    """int8[IB_LEVELS, n_pad] leftmost-argmin offsets of in-block windows.

    ``ib[j-1, i]`` is the offset (relative to i) of the leftmost minimum of
    ``vp[i : i + 2^j]`` clipped to i's 128-block. Two overlapping windows at
    level floor(log2(len)) cover any in-block range [lo, hi], turning the
    batched engines' partial-block scans into four scalar gathers — the
    gather/masked-reduction pass of the batch-native query (ROADMAP PR 2).
    Level 0 (window length 1, offset 0) is implicit.
    """
    n_pad = len(vp)
    nb = n_pad // BLOCK
    v = vp.reshape(nb, BLOCK).astype(np.int64)
    lane = np.arange(BLOCK)
    cur = np.zeros((nb, BLOCK), np.int32)
    ib = np.zeros((IB_LEVELS, nb, BLOCK), np.int8)
    for j in range(1, IB_LEVELS + 1):
        half = 1 << (j - 1)
        other_i = np.minimum(lane + half, BLOCK - 1)
        abs1 = lane[None, :] + cur
        abs2 = other_i[None, :] + cur[:, other_i]
        cross = (lane + half) > (BLOCK - 1)
        take2 = (np.take_along_axis(v, abs2, 1)
                 < np.take_along_axis(v, abs1, 1)) & ~cross[None, :]
        absm = np.where(take2, abs2, abs1)
        cur = (absm - lane[None, :]).astype(np.int32)
        ib[j - 1] = cur.astype(np.int8)
    return ib.reshape(IB_LEVELS, n_pad)


@pytree_dataclass(meta_fields=("n", "n_blocks", "levels"))
class RangeMin:
    values: jnp.ndarray      # int32[n_pad] (INF padded)
    st_pos: jnp.ndarray      # int32[levels, n_blocks]: global argmin positions
    ib: jnp.ndarray          # int8[IB_LEVELS, n_pad]: in-block window argmins
    n: int
    n_blocks: int
    levels: int

    @staticmethod
    def build(values: np.ndarray) -> "RangeMin":
        v = np.asarray(values, dtype=np.int64)
        n = len(v)
        n_pad = ((n + BLOCK - 1) // BLOCK) * BLOCK
        vp = np.full(n_pad, INF_DOCID, dtype=np.int64)
        vp[:n] = v
        nb = n_pad // BLOCK
        blocks = vp.reshape(nb, BLOCK)
        base = np.arange(nb) * BLOCK
        pos0 = base + blocks.argmin(axis=1)
        levels = max(1, int(np.ceil(np.log2(max(nb, 1)))) + 1)
        st = np.zeros((levels, nb), dtype=np.int32)
        st[0] = pos0
        for j in range(1, levels):
            half = 1 << (j - 1)
            prev = st[j - 1]
            other = st[j - 1][np.minimum(np.arange(nb) + half, nb - 1)]
            take_other = vp[other] < vp[prev]
            st[j] = np.where(take_other, other, prev)
        return RangeMin(
            values=jnp.asarray(vp.astype(np.int32)),
            st_pos=jnp.asarray(st),
            ib=jnp.asarray(build_inblock_table(vp)),
            n=n,
            n_blocks=nb,
            levels=levels,
        )

    # -- single query (vmap for batches) --------------------------------------
    def query(self, p, q):
        """argmin over values[p..q] inclusive -> (pos, val).

        Invalid (p > q, or empty structure) -> (0, INF).
        """
        p = jnp.clip(p, 0, max(self.n - 1, 0)).astype(jnp.int32)
        qc = jnp.clip(q, 0, max(self.n - 1, 0)).astype(jnp.int32)
        bp, bq = p // BLOCK, qc // BLOCK
        lane = jnp.arange(BLOCK, dtype=jnp.int32)

        def partial(block, lo_lane, hi_lane):
            vals = lax.dynamic_slice(self.values, (block * BLOCK,), (BLOCK,))
            m = (lane >= lo_lane) & (lane <= hi_lane)
            vals = jnp.where(m, vals, INF_DOCID)
            a = jnp.argmin(vals)
            return block * BLOCK + a, vals[a]

        same = bp == bq
        # candidate 1: left partial block [p .. end or q]
        c1_pos, c1_val = partial(bp, p % BLOCK, jnp.where(same, qc % BLOCK, BLOCK - 1))
        # candidate 2: right partial block [start .. q]
        c2_pos, c2_val = partial(bq, 0, qc % BLOCK)
        c2_val = jnp.where(same, INF_DOCID, c2_val)
        # candidates 3,4: sparse table over middle blocks [bp+1 .. bq-1]
        cnt = bq - bp - 1
        has_mid = cnt > 0
        j = jnp.where(has_mid, 31 - lax.clz(jnp.maximum(cnt, 1)), 0)
        jc = jnp.minimum(j, self.levels - 1)
        lo_b = jnp.minimum(bp + 1, self.n_blocks - 1)
        hi_b = jnp.clip(bq - (1 << jc), 0, self.n_blocks - 1)
        c3_pos = self.st_pos[jc, lo_b]
        c4_pos = self.st_pos[jc, hi_b]
        c3_val = jnp.where(has_mid, self.values[c3_pos], INF_DOCID)
        c4_val = jnp.where(has_mid, self.values[c4_pos], INF_DOCID)

        pos = jnp.stack([c1_pos, c2_pos, c3_pos, c4_pos])
        val = jnp.stack([c1_val, c2_val, c3_val, c4_val])
        invalid = (p > qc) | (self.n == 0)
        val = jnp.where(invalid, INF_DOCID, val)
        best = jnp.argmin(val)
        return pos[best].astype(jnp.int32), val[best].astype(jnp.int32)

    # -- natively batched query (the serving hot path, ROADMAP PR 2) ----------
    def query_batch(self, p, q, *, use_kernel: bool = False,
                    interpret: bool | None = None):
        """Batched argmin over values[p[i]..q[i]] -> (pos int32[B], val int32[B]).

        Contract vs the scalar :meth:`query` under vmap: ``val`` is
        bit-identical always; ``pos`` is bit-identical whenever
        ``val < INF_DOCID`` (for empty/invalid ranges the two formulations
        return different — and equally meaningless — positions; no engine
        reads ``pos`` of an INF pop).

        ``use_kernel=True`` dispatches to the Pallas kernel
        (``kernels.rmq.ops.rmq_query``); the default is the XLA reference
        formulation: both partial blocks resolve via two overlapping in-block
        windows (four ``ib`` + four ``values`` gathers), the middle via the
        block-level sparse table — one fused gather per source array, no
        per-lane ``dynamic_slice`` scans.
        """
        n = self.n
        p = jnp.clip(p, 0, max(n - 1, 0)).astype(jnp.int32)
        qc = jnp.clip(q, 0, max(n - 1, 0)).astype(jnp.int32)
        invalid = (p > qc) | (n == 0)
        if use_kernel:
            from ..kernels.rmq.ops import rmq_query

            B = p.shape[0]
            pad = (-B) % BLOCK if B > BLOCK else 0
            pk = jnp.pad(p, (0, pad)) if pad else p
            qk = jnp.pad(qc, (0, pad)) if pad else qc
            pos, val = rmq_query(self.values, self.st_pos, pk, qk,
                                 use_kernel=True, interpret=interpret)
            pos, val = pos[:B], val[:B]
            return (pos.astype(jnp.int32),
                    jnp.where(invalid, INF_DOCID, val).astype(jnp.int32))

        # the two-overlapping-window gather formulation lives in ONE place —
        # kernels/rmq/ref.py — shared with the heap_topk kernel body and the
        # kernel oracles (lazy import: core never pulls Pallas at import time)
        from ..kernels.rmq.ref import rmq_window_batch

        return rmq_window_batch(
            self.values, self.ib.reshape(-1), self.st_pos.reshape(-1), p, qc,
            n=n, levels=self.levels, n_blocks=self.n_blocks,
            nb_stride=self.n_blocks, n_pad=self.values.shape[0])

    def space_bytes(self) -> int:
        # values are shared with the owner
        return int(self.st_pos.nbytes + self.ib.nbytes)


def topk_in_range(rmq: RangeMin, p, q, k: int):
    """k smallest values in rmq.values[p..q-1] (half-open), ascending.

    Returns (vals int32[k], pos int32[k]) padded with (INF, -1). This is the
    paper's heap-of-subranges algorithm with a dense (k+1)-slot buffer.
    """
    qi = q - 1  # inclusive
    pos0, val0 = rmq.query(p, qi)
    K = k + 1
    slot_lo = jnp.full((K,), 0, jnp.int32).at[0].set(p)
    slot_hi = jnp.full((K,), -1, jnp.int32).at[0].set(qi)
    slot_pos = jnp.zeros((K,), jnp.int32).at[0].set(pos0)
    slot_val = jnp.full((K,), INF_DOCID, jnp.int32).at[0].set(
        jnp.where(p <= qi, val0, INF_DOCID)
    )
    out_v = jnp.full((k,), INF_DOCID, jnp.int32)
    out_p = jnp.full((k,), -1, jnp.int32)

    def body(i, state):
        slot_lo, slot_hi, slot_pos, slot_val, out_v, out_p = state
        best = jnp.argmin(slot_val)
        bval = slot_val[best]
        found = bval < INF_DOCID
        out_v = out_v.at[i].set(bval)
        out_p = out_p.at[i].set(jnp.where(found, slot_pos[best], -1))
        lo, hi, pos = slot_lo[best], slot_hi[best], slot_pos[best]
        # left subrange replaces the popped slot
        l_lo, l_hi = lo, pos - 1
        lpos, lval = rmq.query(l_lo, l_hi)
        lval = jnp.where((l_lo <= l_hi) & found, lval, INF_DOCID)
        slot_lo = slot_lo.at[best].set(l_lo)
        slot_hi = slot_hi.at[best].set(l_hi)
        slot_pos = slot_pos.at[best].set(lpos)
        slot_val = slot_val.at[best].set(lval)
        # right subrange goes to the fresh slot i+1
        r_lo, r_hi = pos + 1, hi
        rpos, rval = rmq.query(r_lo, r_hi)
        rval = jnp.where((r_lo <= r_hi) & found, rval, INF_DOCID)
        slot_lo = slot_lo.at[i + 1].set(r_lo)
        slot_hi = slot_hi.at[i + 1].set(r_hi)
        slot_pos = slot_pos.at[i + 1].set(rpos)
        slot_val = slot_val.at[i + 1].set(rval)
        return slot_lo, slot_hi, slot_pos, slot_val, out_v, out_p

    state = (slot_lo, slot_hi, slot_pos, slot_val, out_v, out_p)
    state = lax.fori_loop(0, k, body, state)
    return state[4], state[5]


def topk_in_range_batch(rmq: RangeMin, p, q, k: int, *,
                        use_kernel: bool = False,
                        interpret: bool | None = None):
    """Batch-native :func:`topk_in_range`: p, q int32[B] half-open ranges.

    Returns (vals int32[B, k], pos int32[B, k]), bit-identical to
    ``vmap(topk_in_range)``. Each pop issues ONE batched RMQ over the 2B
    left/right split subranges of all lanes instead of 2B scalar queries
    under vmap (ISSUE 2 tentpole).
    """
    B = p.shape[0]
    rows = jnp.arange(B)
    qi = q - 1
    pos0, val0 = rmq.query_batch(p, qi, use_kernel=use_kernel,
                                 interpret=interpret)
    K = k + 1
    slot_lo = jnp.zeros((B, K), jnp.int32).at[:, 0].set(p)
    slot_hi = jnp.full((B, K), -1, jnp.int32).at[:, 0].set(qi)
    slot_pos = jnp.zeros((B, K), jnp.int32).at[:, 0].set(pos0)
    slot_val = jnp.full((B, K), INF_DOCID, jnp.int32).at[:, 0].set(
        jnp.where(p <= qi, val0, INF_DOCID))
    out_v = jnp.full((B, k), INF_DOCID, jnp.int32)
    out_p = jnp.full((B, k), -1, jnp.int32)

    def body(i, state):
        slot_lo, slot_hi, slot_pos, slot_val, out_v, out_p = state
        best = jnp.argmin(slot_val, axis=1)
        bval = slot_val[rows, best]
        found = bval < INF_DOCID
        out_v = out_v.at[:, i].set(bval)
        out_p = out_p.at[:, i].set(jnp.where(found, slot_pos[rows, best], -1))
        lo = slot_lo[rows, best]
        hi = slot_hi[rows, best]
        pos = slot_pos[rows, best]
        l_lo, l_hi = lo, pos - 1
        r_lo, r_hi = pos + 1, hi
        pos2, val2 = rmq.query_batch(jnp.concatenate([l_lo, r_lo]),
                                     jnp.concatenate([l_hi, r_hi]),
                                     use_kernel=use_kernel,
                                     interpret=interpret)
        lval = jnp.where((l_lo <= l_hi) & found, val2[:B], INF_DOCID)
        rval = jnp.where((r_lo <= r_hi) & found, val2[B:], INF_DOCID)
        # left subrange replaces the popped slot; right takes fresh slot i+1
        slot_lo = slot_lo.at[rows, best].set(l_lo)
        slot_hi = slot_hi.at[rows, best].set(l_hi)
        slot_pos = slot_pos.at[rows, best].set(pos2[:B])
        slot_val = slot_val.at[rows, best].set(lval)
        slot_lo = slot_lo.at[:, i + 1].set(r_lo)
        slot_hi = slot_hi.at[:, i + 1].set(r_hi)
        slot_pos = slot_pos.at[:, i + 1].set(pos2[B:])
        slot_val = slot_val.at[:, i + 1].set(rval)
        return slot_lo, slot_hi, slot_pos, slot_val, out_v, out_p

    state = (slot_lo, slot_hi, slot_pos, slot_val, out_v, out_p)
    state = lax.fori_loop(0, k, body, state)
    return state[4], state[5]
