"""Two-level Front Coding string store (paper §3.2, Table 3).

Bucket layout follows the paper: every (B+1)-th string is an uncompressed
*header*; the B strings after it store (lcp, suffix) relative to their
predecessor. Space accounting matches a byte-oriented FC encoding (1-2 byte
lcp/len + suffix bytes).

TPU adaptation of decode (DESIGN.md §2): reconstructing string ``p`` of a
bucket needs, for every char position j, the *last* predecessor q <= p whose
lcp <= j — a masked argmax over the (B+1, T) bucket, one vector op, instead of
the sequential C++ scan. Extract / Locate / LocatePrefix are all batched.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .types import pytree_dataclass
from .strings import encode_strings, pack_chars, prefix_bound_keys
from .searching import ranged_searchsorted_keys, _lex_lt


def _lcp(a: bytes, b: bytes) -> int:
    m = min(len(a), len(b))
    for i in range(m):
        if a[i] != b[i]:
            return i
    return m


@pytree_dataclass(meta_fields=("n_strings", "bucket_size", "max_chars", "n_buckets"))
class FrontCodedStore:
    header_chars: jnp.ndarray   # uint8[NB, T]
    header_keys: jnp.ndarray    # int32[NB, C]
    lcps: jnp.ndarray           # int32[NB, B+1] (col 0 == 0 for the header)
    slens: jnp.ndarray          # int32[NB, B+1] (suffix lengths)
    suf_off: jnp.ndarray        # int32[NB, B+1] offsets into suffix_chars
    suffix_chars: jnp.ndarray   # uint8[total_suffix]
    n_strings: int
    bucket_size: int
    max_chars: int
    n_buckets: int

    # -- construction -------------------------------------------------------
    @staticmethod
    def build(strings_sorted, bucket_size: int = 16, max_chars: int = 64):
        B = bucket_size
        enc = [
            (s.encode("utf-8")[:max_chars] if isinstance(s, str) else bytes(s)[:max_chars])
            for s in strings_sorted
        ]
        n = len(enc)
        nb = (n + B) // (B + 1)
        headers, lcps, slens, offs, chunks = [], [], [], [], []
        total = 0
        for b in range(nb):
            base = b * (B + 1)
            group = enc[base : base + B + 1]
            headers.append(group[0])
            row_l, row_s, row_o = [0], [len(group[0])], [total]
            chunks.append(group[0])
            total += len(group[0])
            for prev, cur in zip(group, group[1:]):
                l = _lcp(prev, cur)
                row_l.append(l)
                row_s.append(len(cur) - l)
                row_o.append(total)
                chunks.append(cur[l:])
                total += len(cur) - l
            while len(row_l) < B + 1:  # pad short last bucket
                row_l.append(0)
                row_s.append(0)
                row_o.append(total)
            lcps.append(row_l)
            slens.append(row_s)
            offs.append(row_o)
        hdr = encode_strings(headers, max_chars)
        suffix = np.frombuffer(b"".join(chunks), dtype=np.uint8).copy()
        if suffix.size == 0:
            suffix = np.zeros(1, dtype=np.uint8)
        return FrontCodedStore(
            header_chars=jnp.asarray(hdr),
            header_keys=jnp.asarray(pack_chars(hdr)),
            lcps=jnp.asarray(np.asarray(lcps, dtype=np.int32)),
            slens=jnp.asarray(np.asarray(slens, dtype=np.int32)),
            suf_off=jnp.asarray(np.asarray(offs, dtype=np.int32)),
            suffix_chars=jnp.asarray(suffix),
            n_strings=n,
            bucket_size=B,
            max_chars=max_chars,
            n_buckets=nb,
        )

    # -- decode --------------------------------------------------------------
    def _decode_bucket(self, b: jnp.ndarray) -> jnp.ndarray:
        """Decode all B+1 strings of bucket b -> uint8[B+1, T]."""
        Bp1 = self.bucket_size + 1
        T = self.max_chars
        lcp = self.lcps[b]                      # [B+1]
        slen = self.slens[b]
        off = self.suf_off[b]
        j = jnp.arange(T, dtype=jnp.int32)      # char positions
        q = jnp.arange(Bp1, dtype=jnp.int32)    # in-bucket string index
        #   writer[q, j] == True where string q wrote char j
        writer = lcp[:, None] <= j[None, :]                       # [B+1, T]
        # for target p: last q <= p with writer[q, j]
        #   q_star[p, j] = max over q<=p of q * writer  (−1 if none; header q=0
        #   has lcp 0 so there is always one)
        w = jnp.where(writer, q[:, None], -1)                     # [B+1, T]
        q_star = jax.lax.cummax(w, axis=0)                        # [B+1, T]
        qs = jnp.maximum(q_star, 0)
        char_pos = off[qs] + (j[None, :] - lcp[qs])               # [B+1, T]
        ch = self.suffix_chars[jnp.clip(char_pos, 0, self.suffix_chars.shape[0] - 1)]
        lengths = lcp + slen                                      # [B+1]
        valid = (j[None, :] < lengths[qs]) & (j[None, :] < (lcp[qs] + slen[qs]))
        return jnp.where(valid, ch, 0).astype(jnp.uint8)

    def extract(self, ids: jnp.ndarray) -> jnp.ndarray:
        """ids[B] 0-based ranks -> uint8[B, T]."""
        Bp1 = self.bucket_size + 1

        def one(i):
            b = i // Bp1
            within = i % Bp1
            return self._decode_bucket(b)[within]

        return jax.vmap(one)(jnp.clip(ids, 0, self.n_strings - 1))

    # -- searches ------------------------------------------------------------
    def _bucket_of_key(self, key: jnp.ndarray, side: str) -> jnp.ndarray:
        z = jnp.int32(0)
        nb = jnp.int32(self.n_buckets)
        pos = ranged_searchsorted_keys(self.header_keys, key, z, nb, side=side)
        return jnp.maximum(pos - 1, 0)

    def _rank_of_key(self, key: jnp.ndarray, side: str) -> jnp.ndarray:
        """Global insertion rank of a packed key among all strings."""
        Bp1 = self.bucket_size + 1
        b = self._bucket_of_key(key, side)
        bucket = self._decode_bucket(b)                   # [B+1, T]
        bkeys = pack_chars(bucket)
        in_bucket = ranged_searchsorted_keys(
            bkeys, key, jnp.int32(0), jnp.int32(Bp1), side=side
        )
        return jnp.minimum(b * Bp1 + in_bucket, self.n_strings)

    def locate(self, q_chars: jnp.ndarray) -> jnp.ndarray:
        """uint8[B, T] -> 0-based rank, -1 if absent."""
        keys = pack_chars(q_chars)

        def one(k, qc):
            pos = self._rank_of_key(k, "left")
            row = self.extract(pos[None])[0]
            hit = (pos < self.n_strings) & jnp.all(row == qc)
            return jnp.where(hit, pos, -1).astype(jnp.int32)

        return jax.vmap(one)(keys, q_chars)

    def locate_prefix(self, q_chars: jnp.ndarray, q_len: jnp.ndarray):
        """-> (l, r) half-open 0-based rank range of strings with the prefix."""
        lo_keys, hi_keys = prefix_bound_keys(q_chars, q_len, self.max_chars)

        def one(lk, hk):
            return self._rank_of_key(lk, "left"), self._rank_of_key(hk, "right")

        return jax.vmap(one)(lo_keys, hi_keys)

    # -- space accounting (paper-style encoded size) -------------------------
    def encoded_bytes(self) -> int:
        """Byte-oriented FC size: headers + (lcp,len) bytes + suffix bytes."""
        lcp = np.asarray(self.lcps)
        slen = np.asarray(self.slens)
        hdr_lens = (np.asarray(self.header_chars) != 0).sum()
        meta = int((lcp.size - self.n_buckets) * 2)  # 1B lcp + 1B len per string
        return int(hdr_lens + meta + int(np.asarray(self.suffix_chars).shape[0]))

    def space_bytes(self) -> int:
        """In-memory (TPU array) footprint."""
        return int(
            self.header_chars.nbytes + self.header_keys.nbytes + self.lcps.nbytes
            + self.slens.nbytes + self.suf_off.nbytes + self.suffix_chars.nbytes
        )
