"""The inverted index (paper §3.2) in CSR form + the ``minimal`` array.

Lists are docid-ascending == score-descending (the paper's invariant), so
"first k" == "top-k". NextGeq is a ranged binary search. The `minimal`
array (first docid of every list) feeds the single-term RMQ algorithm
(paper §3.3). ``packed`` optionally carries the same postings in the
device block format (``codecs.PackedPostings``) so the fused kernels can
decode on-chip — the raw CSR arrays stay authoritative (XLA/off-TPU
reference); the two are interchangeable by the
``unpack_postings(packed) == postings`` contract the builder asserts.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .types import INF_DOCID, pytree_dataclass
from .searching import ranged_searchsorted, next_geq
from .codecs import PackedPostings, pack_postings, unpack_postings
from .rmq import RangeMin


@pytree_dataclass(meta_fields=("n_terms", "n_postings"))
class InvertedIndex:
    postings: jnp.ndarray    # int32[P] concatenated docid lists (ascending)
    offsets: jnp.ndarray     # int32[V+2] list boundaries, indexed by 1-based term id
    minimal: jnp.ndarray     # int32[V+2] first docid per list (INF if empty)
    n_terms: int
    n_postings: int
    packed: PackedPostings | None = None   # device block format (optional)

    @staticmethod
    def build(term_rows: np.ndarray, docid_of_row: np.ndarray, n_terms: int,
              postings_codec: str | None = "ef"):
        """term_rows int32[N, M] (1-based ids, 0 pad); docid_of_row int32[N].

        ``postings_codec``: "ef" (default) or "bitpack" additionally emits
        the compressed device layout into ``.packed``; None skips it.
        """
        term_rows = np.asarray(term_rows, dtype=np.int64)
        n, m = term_rows.shape
        docs = np.broadcast_to(np.asarray(docid_of_row, dtype=np.int64)[:, None], (n, m))
        mask = term_rows != 0
        t = term_rows[mask]
        d = docs[mask]
        # dedup (term, doc) pairs — a term may repeat inside one completion
        key = t * (np.int64(docid_of_row.max()) + 1) + d
        uniq = np.unique(key)
        t = (uniq // (np.int64(docid_of_row.max()) + 1)).astype(np.int64)
        d = (uniq % (np.int64(docid_of_row.max()) + 1)).astype(np.int64)
        order = np.lexsort((d, t))
        t, d = t[order], d[order]
        cnt = np.bincount(t, minlength=n_terms + 1)  # indexed by 1-based term id
        offsets = np.zeros(n_terms + 2, dtype=np.int32)
        offsets[1 : len(cnt) + 1] = np.cumsum(cnt)
        offsets[len(cnt) + 1 :] = len(d)
        minimal = np.full(n_terms + 2, INF_DOCID, dtype=np.int32)
        starts = offsets[:-1]
        ends = offsets[1:]
        nonempty = ends > starts
        minimal[:-1][nonempty] = d[starts[nonempty]]
        packed = None
        if postings_codec is not None:
            packed = pack_postings(d.astype(np.int32), postings_codec)
            got = unpack_postings(packed)
            assert (got == d).all(), "packed postings round-trip broke"
        return InvertedIndex(
            postings=jnp.asarray(d.astype(np.int32)),
            offsets=jnp.asarray(offsets),
            minimal=jnp.asarray(minimal),
            n_terms=n_terms,
            n_postings=len(d),
            packed=packed,
        )

    # -- primitives -----------------------------------------------------------
    def list_bounds(self, term_id):
        t = jnp.clip(term_id, 0, self.n_terms)
        return self.offsets[t], self.offsets[t + 1]

    def list_len(self, term_id):
        s, e = self.list_bounds(term_id)
        return e - s

    def next_geq(self, term_id, x):
        s, e = self.list_bounds(term_id)
        val, _ = next_geq(self.postings, s, e, x, INF_DOCID)
        return val

    def contains(self, term_id, x):
        return self.next_geq(term_id, x) == x

    def space_bytes(self) -> int:
        return int(self.postings.nbytes + self.offsets.nbytes)

    def build_minimal_rmq(self) -> RangeMin:
        """RMQ over the minimal array for single-term queries (paper §3.3)."""
        return RangeMin.build(np.asarray(self.minimal))
