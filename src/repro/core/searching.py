"""Batched, range-restricted binary search primitives.

These are the TPU-native replacement for every pointer walk in the paper:
dictionary lookups, trie-level descents, and NextGeq all reduce to a fixed
31-step binary search (log2 of the int32 universe), expressed with
``lax.fori_loop`` so it vmaps and shards cleanly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

_ITERS = 31  # ceil(log2(2^31)): always enough; extra iterations are no-ops


def _lex_lt(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Lexicographic a < b over trailing chunk axis. a,b: int32[C]."""
    neq = a != b
    idx = jnp.argmax(neq)  # first differing chunk (0 if all equal)
    return jnp.where(jnp.any(neq), a[idx] < b[idx], False)


def ranged_searchsorted(arr, query, lo, hi, *, side: str,
                        max_iters: int = 0) -> jnp.ndarray:
    """Insertion point of ``query`` into sorted ``arr[lo:hi]`` (scalar int32).

    ``arr`` is int32[N]; lo/hi are scalars; returns position in [lo, hi].
    ``max_iters=0`` uses the static bound ceil(log2(len(arr)))+1 — a §Perf
    win over the worst-case 31 (a 2M-posting stripe needs 22, not 31).
    """
    assert side in ("left", "right")
    iters = max_iters or min(_ITERS, max(1, (arr.shape[0]).bit_length()))

    def body(_, state):
        lo_, hi_ = state
        mid = (lo_ + hi_) // 2
        v = arr[mid]
        go_right = (v < query) if side == "left" else (v <= query)
        new_lo = jnp.where(go_right, mid + 1, lo_)
        new_hi = jnp.where(go_right, hi_, mid)
        valid = lo_ < hi_
        return (jnp.where(valid, new_lo, lo_), jnp.where(valid, new_hi, hi_))

    lo, hi = lax.fori_loop(0, iters, body, (lo.astype(jnp.int32), hi.astype(jnp.int32)))
    return lo


def ranged_searchsorted_keys(keys, query, lo, hi, *, side: str) -> jnp.ndarray:
    """Like :func:`ranged_searchsorted` over lexicographic chunk keys.

    keys: int32[N, C] sorted lexicographically; query: int32[C].
    """
    assert side in ("left", "right")
    iters = min(_ITERS, max(1, (keys.shape[0]).bit_length()))

    def body(_, state):
        lo_, hi_ = state
        mid = (lo_ + hi_) // 2
        row = keys[mid]
        if side == "left":
            go_right = _lex_lt(row, query)
        else:
            go_right = ~_lex_lt(query, row)
        new_lo = jnp.where(go_right, mid + 1, lo_)
        new_hi = jnp.where(go_right, hi_, mid)
        valid = lo_ < hi_
        return (jnp.where(valid, new_lo, lo_), jnp.where(valid, new_hi, hi_))

    lo, hi = lax.fori_loop(0, iters, body, (lo.astype(jnp.int32), hi.astype(jnp.int32)))
    return lo


def batched_membership(sorted_list, starts, ends, values) -> jnp.ndarray:
    """For each v in values[T], is v present in sorted_list[starts:ends)?

    The SIMD intersection probe (DESIGN.md §2): every lane runs its own binary
    search. Returns bool[T].
    """
    def probe(v):
        pos = ranged_searchsorted(sorted_list, v, starts, ends, side="left")
        in_range = pos < ends
        return in_range & (sorted_list[jnp.minimum(pos, sorted_list.shape[0] - 1)] == v)

    return jax.vmap(probe)(values)


def next_geq(sorted_list, start, end, x, inf):
    """Paper's NextGeq primitive: smallest element >= x in list[start:end)."""
    pos = ranged_searchsorted(sorted_list, x, start, end, side="left")
    val = sorted_list[jnp.minimum(pos, sorted_list.shape[0] - 1)]
    return jnp.where(pos < end, val, inf), pos
