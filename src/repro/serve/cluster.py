"""Overload-hardened multi-replica QAC serving cluster (ISSUE 8 tentpole).

The paper's system replaced SOLR because SOLR "was not always able to meet
the required service-level-agreement". ``serve/runtime.py`` gives us one
fast replica, but a single replica with an unbounded queue has no SLA
story: past saturation the queue grows without bound and p99 is unbounded.
This module is the production topology on top:

  * **N replicas** — each a ``QACOnlineRuntime`` wrapping a ``QACFrontend``
    (full-index copies; ``core.striped.local_index(striped, s)`` is the
    host-side hook for stripe-resident replicas). Every replica owns a
    bounded queue feeding its micro-batch executor — the BatchingQueue ->
    GPUExecutor shape of torchrec's inference pipeline, with the queue
    bound enforced at admission instead of blocking the producer.
  * **session-affinity dispatch** — rendezvous (highest-random-weight)
    hashing on the session id over the replicas the dispatcher believes
    alive. Keystroke locality means the runtime's session-cache tier only
    pays off if a session sticks to one replica; rendezvous hashing gives
    stickiness AND minimal re-shuffling when the alive set changes (only
    the dead replica's sessions move).
  * **admission control** — per-request SLA classes and a queue-pressure
    estimator; the state machine is below.
  * **replica fault handling** — ``HeartbeatRegistry`` liveness + a
    ``FaultInjector``-driven drill mode (kill/stall windows on the virtual
    clock). The dispatcher detects the missed heartbeat, re-routes the
    dead replica's buffered/queued requests to the survivors (their
    session caches are lost; answers stay bit-identical to the uncached
    frontend oracle — caches are exact, so WHERE a request is served can
    never change WHAT it answers), and re-admits the replica when it
    heartbeats again (a killed replica returns with cold caches; a
    stalled one keeps its state).

SLA classes and the degradation/shed state machine
--------------------------------------------------

Every request carries an SLA class: ``"interactive"`` (a human is typing;
the paper's SLA applies) or ``"bulk"`` (batch rescoring, prefetchers,
crawlers — latency-tolerant, first to degrade). Admission happens at the
dispatcher, per request, from the target replica's *queue pressure*:

    est_wait_us = backlog + queue_depth * EWMA(per-request service time)

where backlog is how far the replica's virtual server clock is behind the
arrival and the EWMA comes from a ``runtime.fault.StepMonitor`` fed by the
runtime's ``on_dispatch`` hook. The decision ladder, in order:

    queue_depth >= max_queue               -> REJECT ("queue_full", any class)
    est >= shed_pressure_us                -> REJECT ("shed_overload", any)
    est >= shed_bulk_pressure_us and bulk  -> REJECT ("shed_bulk")
    est >= degrade_pressure_us             -> DEGRADE:
        bulk multi-term                    -> REJECT ("degrade_skip_multi")
                                              (the conjunctive engine is the
                                              expensive class; bulk traffic
                                              loses it first)
        otherwise                          -> serve at k' = min(k, degraded_k)
                                              (a smaller top-k bucket: fewer
                                              heap pops per lane, and the
                                              engines' prefix-stable top-k
                                              makes the k'-answer exactly the
                                              first k' rows of the full one)
    otherwise                              -> serve at full k

A REJECTED result is explicit (``ClusterResult.status == "rejected"`` with
the shed reason) — the overloaded cluster says no in microseconds instead
of blowing the deadline for everyone. Every served row remains
bit-identical to ``frontend.complete`` at the k it was served with, so
degradation never trades away correctness, only result count.

Time model: identical to ``serve/runtime.py`` — virtual microsecond clock
for arrivals/queueing, measured wall time for engine service. Replica
clocks advance independently, which is exactly a cluster of parallel
servers simulated on one host. Heartbeats piggyback on the event loop
(every arrival observes every replica), so detection latency is the
heartbeat timeout plus the gap to the next arrival.
"""
from __future__ import annotations

import dataclasses
from collections import Counter

import numpy as np

from ..obs.metrics import percentiles
from ..runtime.fault import (FaultInjector, HeartbeatRegistry, ReplicaFault,
                             StepMonitor)
from .frontend import QACFrontend
from .runtime import QACOnlineRuntime, QACRequest, RuntimeConfig

SERVED = "ok"
REJECTED = "rejected"

_M64 = (1 << 64) - 1


def _mix(a: int, b: int) -> int:
    """Deterministic 64-bit hash of (a, b) — splitmix64-style finalizer.

    Python's ``hash`` is salted for str/bytes and implementation-defined;
    routing must be stable across processes (a restarted dispatcher must
    route sessions the same way), so the mix is explicit.
    """
    x = ((a + 0x9E3779B97F4A7C15) * 0xBF58476D1CE4E5B9 + b) & _M64
    x ^= x >> 31
    x = (x * 0x94D049BB133111EB) & _M64
    return x ^ (x >> 29)


def rendezvous_route(session: int, replicas) -> int | None:
    """Highest-random-weight hash: argmax over replicas of mix(session, r).

    Stickiness: a session routes to the same replica while the alive set
    is unchanged. Minimal disruption: removing a replica re-routes ONLY
    the sessions whose argmax it was; every other session keeps its
    replica (and therefore its warm session cache).
    """
    best, best_w = None, -1
    for rid in replicas:
        w = _mix(int(session), int(rid))
        if w > best_w:
            best, best_w = rid, w
    return best


def assign_sla(reqs, *, bulk_fraction: float = 0.25, seed: int = 0):
    """Deterministic per-session SLA classes: ``bulk_fraction`` of sessions
    (by hash, so the assignment is stable across runs and every request of
    a session shares its class) are ``"bulk"``, the rest ``"interactive"``.
    """
    if not 0.0 <= bulk_fraction <= 1.0:
        raise ValueError(f"bulk_fraction must be in [0, 1], "
                         f"got {bulk_fraction}")
    cut = int(bulk_fraction * (1 << 32))
    return ["bulk" if _mix(r.session, 0xB01D + seed) % (1 << 32) < cut
            else "interactive" for r in reqs]


@dataclasses.dataclass
class ClusterConfig:
    """Dispatcher + admission-control knobs. The pressure thresholds are
    estimated-wait budgets in microseconds and must be ordered
    ``degrade <= shed_bulk <= shed`` — the ladder in the module docstring.
    ``float("inf")`` thresholds disable that tier (the unbounded baseline
    the saturation bench compares against)."""

    n_replicas: int = 2
    max_queue: int = 256                    # bounded per-replica queue
    degrade_pressure_us: float = 25_000.0   # -> smaller k, bulk loses multi
    shed_bulk_pressure_us: float = 50_000.0  # -> bulk rejected
    shed_pressure_us: float = 100_000.0     # -> everything rejected
    degraded_k: int = 4                     # k bucket served under degrade
    heartbeat_timeout_us: float = 200_000.0  # missed-beat death deadline

    def __post_init__(self):
        if self.n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, "
                             f"got {self.n_replicas}")
        if self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if self.degraded_k < 1:
            raise ValueError(f"degraded_k must be >= 1, "
                             f"got {self.degraded_k}")
        if not self.degrade_pressure_us > 0:
            raise ValueError(f"degrade_pressure_us must be positive, "
                             f"got {self.degrade_pressure_us}")
        if not (self.degrade_pressure_us <= self.shed_bulk_pressure_us
                <= self.shed_pressure_us):
            raise ValueError(
                "pressure thresholds must be ordered degrade <= shed_bulk "
                f"<= shed, got {self.degrade_pressure_us} / "
                f"{self.shed_bulk_pressure_us} / {self.shed_pressure_us}")
        if not self.heartbeat_timeout_us > 0:
            raise ValueError(f"heartbeat_timeout_us must be positive, "
                             f"got {self.heartbeat_timeout_us}")


@dataclasses.dataclass
class ClusterResult:
    """One request's outcome. ``status == "ok"``: ``row`` is int32[k_served]
    (INF-padded), bit-identical to an uncached ``frontend.complete`` call at
    ``k_served``; degraded requests have ``k_served < k`` and the row is the
    first ``k_served`` entries of the full answer (prefix-stable top-k).
    ``status == "rejected"``: ``row`` is None and ``reason`` names the shed
    tier."""

    status: str
    row: np.ndarray | None
    k_served: int
    replica: int | None
    sla: str
    degraded: bool
    rerouted: bool
    reason: str = ""
    # freshness (ISSUE 9): the index generation whose frontend answered —
    # the time-indexed parity oracle replays each row against a
    # from-scratch build of exactly this generation
    gen: int = 0


class ClusterTelemetry:
    """Per-class latency + admission/fault counters; ``snapshot()`` -> dict.

    Latencies are measured from each request's ORIGINAL arrival to its
    virtual completion — a re-routed request pays its detection delay here,
    which is what ``failover_p99_us`` reports.
    """

    def __init__(self):
        self.lat_us: dict[str, list[float]] = {"interactive": [], "bulk": []}
        self.degraded_lat_us: list[float] = []
        self.shed: Counter = Counter()          # (sla, reason) -> count
        self.rerouted = 0
        self.failover_lat_us: list[float] = []
        self.per_replica: Counter = Counter()   # rid -> served count
        self.deaths: list[tuple[float, int]] = []
        self.readmissions: list[tuple[float, int]] = []
        # freshness: one (t_us, generation) entry per cluster-wide swap
        self.swaps: list[tuple[float, int]] = []

    @staticmethod
    def _pct(lat) -> dict:
        # the repo's ONE percentile implementation (obs.metrics): an SLA
        # class that served nothing reports explicit None, never a fake 0us
        return percentiles(lat, mean=True)

    def snapshot(self) -> dict:
        served = sum(len(v) for v in self.lat_us.values())
        rejected = sum(self.shed.values())
        n = served + rejected
        out = {
            "n_requests": n,
            "served": served,
            "rejected": rejected,
            "shed_rate": rejected / max(n, 1),
            "degrade_rate": len(self.degraded_lat_us) / max(n, 1),
            "rerouted": self.rerouted,
            "shed": {f"{sla}:{reason}": c
                     for (sla, reason), c in sorted(self.shed.items())},
            "per_replica": dict(sorted(self.per_replica.items())),
            "deaths": list(self.deaths),
            "readmissions": list(self.readmissions),
            "swaps": list(self.swaps),
        }
        for cls, lat in self.lat_us.items():
            for key, v in self._pct(lat).items():
                out[f"{cls}_{key}"] = v
            out[f"{cls}_served"] = len(lat)
        for key, v in self._pct(self.failover_lat_us).items():
            out[f"failover_{key}"] = v
        return out


class _Replica:
    """One replica slot: its runtime, its service-time monitor, and the
    limbo buffer of requests sent to it while it was (undetectably) down."""

    def __init__(self, rid: int, runtime: QACOnlineRuntime):
        self.rid = rid
        self.runtime = runtime
        self.limbo: list[tuple[QACRequest, str, float]] = []  # (r, sla, t0)
        self.seen_fault: ReplicaFault | None = None
        self._n_dispatch = 0
        self.fresh_monitor()
        runtime.on_dispatch = self._on_dispatch

    def fresh_monitor(self):
        # responsive EWMA: overload onset must move the estimate within a
        # few dispatches, not a few hundred
        self.monitor = StepMonitor(alpha=0.3, warmup=0)

    def _on_dispatch(self, batch_size: int, wall_us: float, t_start: float):
        self._n_dispatch += 1
        self.monitor.record(self._n_dispatch, wall_us / max(batch_size, 1))

    def est_wait_us(self, now: float) -> float:
        """The admission pressure estimate: how long a request admitted at
        ``now`` would wait before service begins."""
        per_req = self.monitor.mean or 0.0
        backlog = max(0.0, self.runtime._server_free - now)
        return backlog + len(self.runtime.queue) * per_req

    def depth(self) -> int:
        return len(self.runtime.queue) + len(self.limbo)


class QACServingCluster:
    """N ``QACOnlineRuntime`` replicas behind a session-affinity dispatcher
    with SLA-class admission control and heartbeat-driven failover (module
    docstring has the full state machine).

    ``frontends`` may be supplied explicitly — one per replica for the
    production shape, or the SAME warm instance repeated to share its jit
    cache (``complete`` is a pure function, so sharing never changes
    results; tests and benches use this to compile each variant once).
    ``injector`` carries the drill schedule (``ReplicaFault`` windows);
    the default injector has none, i.e. a healthy cluster.
    """

    def __init__(self, qidx=None, cfg: ClusterConfig | None = None,
                 rt_cfg: RuntimeConfig | None = None, *,
                 frontends: list[QACFrontend] | None = None,
                 injector: FaultInjector | None = None,
                 frontend_kwargs: dict | None = None,
                 tracer=None, registry=None):
        self.cfg = cfg if cfg is not None else ClusterConfig()
        self.rt_cfg = rt_cfg if rt_cfg is not None else RuntimeConfig()
        # observability (ISSUE 10): the tracer is shared with every replica
        # runtime (reset() threads it through); admission/fault/swap
        # decision points emit instants. None = no overhead.
        self.tracer = tracer
        if registry is not None:
            registry.register_collector("cluster",
                                        lambda: self.telemetry.snapshot())
        self.injector = injector if injector is not None else FaultInjector([])
        if frontends is None:
            if qidx is None:
                raise ValueError("provide qidx or explicit frontends")
            kw = dict(specialize_list_pad=False)   # closed jit-variant space
            kw.update(frontend_kwargs or {})
            frontends = [QACFrontend(qidx, **kw)
                         for _ in range(self.cfg.n_replicas)]
        if len(frontends) != self.cfg.n_replicas:
            raise ValueError(f"{len(frontends)} frontends for "
                             f"{self.cfg.n_replicas} replicas")
        self.frontends = frontends
        self.qidx = qidx if qidx is not None else frontends[0].qidx
        # index capacity: a request can never return more than every
        # completion; catch the misconfiguration here with a nameable
        # error instead of deep inside an engine dispatch
        self.capacity = int(self.qidx.completions.n)
        if self.cfg.degraded_k > self.capacity:
            raise ValueError(
                f"degraded_k={self.cfg.degraded_k} exceeds index capacity "
                f"({self.capacity} completions)")
        for f in self.injector.replica_faults:
            if not 0 <= f.replica < self.cfg.n_replicas:
                raise ValueError(f"fault targets replica {f.replica} of "
                                 f"{self.cfg.n_replicas}")
        self.reset()

    def reset(self):
        """Fresh cluster state (queues, caches, liveness, telemetry); the
        frontends' warm jit caches survive."""
        self.replicas = [
            _Replica(i, QACOnlineRuntime(fe, self.rt_cfg,
                                         tracer=self.tracer))
            for i, fe in enumerate(self.frontends)]
        self._now = 0.0
        self.registry = HeartbeatRegistry(
            timeout_s=self.cfg.heartbeat_timeout_us,
            clock=lambda: self._now)
        for rep in self.replicas:
            self.registry.beat(rep.rid)
        self.dead: set[int] = set()
        self.telemetry = ClusterTelemetry()
        self._results: dict[int, ClusterResult] = {}
        # idx -> admission record (replica, sla, degraded, rerouted,
        # orig_t, orig_k); rewritten if the request is re-routed
        self._meta: dict[int, dict] = {}

    # -- liveness -------------------------------------------------------------
    def _observe(self, now: float):
        """One heartbeat/detection pass over every replica at virtual time
        ``now``: beat the live ones, detect deaths past the timeout (and
        fail their orphans over), re-admit recoveries."""
        for rep in self.replicas:
            rid = rep.rid
            fault = self.injector.down(rid, now)
            if fault is not None:
                rep.seen_fault = fault
                if fault.kind == "stall":
                    # a stalled server is busy-equivalent until recovery:
                    # nothing it has queued may dispatch inside the window,
                    # and the pressure estimator sees the backlog
                    rep.runtime._server_free = max(
                        rep.runtime._server_free, fault.t_up_us)
                if rid not in self.dead:
                    last = self.registry.last.get(rid, 0.0)
                    if now - last > self.cfg.heartbeat_timeout_us:
                        self.dead.add(rid)
                        self.telemetry.deaths.append((now, rid))
                        if self.tracer is not None:
                            self.tracer.instant(
                                "replica.death", now, cat="cluster",
                                replica=rid, kind=fault.kind)
                        self._failover(rep, now)
                continue
            self.registry.beat(rid)
            if rep.seen_fault is None:
                continue
            # recovery: the replica heartbeats again
            pending = list(rep.limbo)
            rep.limbo = []
            if rep.seen_fault.kind == "kill":
                # the restarted process lost queue AND caches; whatever it
                # had queued must be retried, served results survive (they
                # were answered before the kill)
                pending += self._drain_queue(rep)
                self._harvest(rep)
                rep.runtime.reset()
                rep.runtime.on_dispatch = rep._on_dispatch
                rep.fresh_monitor()
            rep.seen_fault = None
            if rid in self.dead:
                self.dead.discard(rid)
                self.telemetry.readmissions.append((now, rid))
                if self.tracer is not None:
                    self.tracer.instant("replica.readmit", now,
                                        cat="cluster", replica=rid)
            for (q, sla, orig_t) in pending:
                # re-admitted to the SAME replica (recovered before any
                # re-route happened) — delayed, not rerouted
                self._admit(rep, q, sla, now=now, orig_t=orig_t,
                            rerouted=False)

    def _drain_queue(self, rep: _Replica):
        """Pull every unserved request out of a replica's runtime queue,
        restoring each one's pre-degradation k from the admission record."""
        out = []
        while rep.runtime.queue:
            q = rep.runtime.queue.popleft()
            meta = self._meta[q.idx]
            if q.k != meta["orig_k"]:
                q = dataclasses.replace(q, k=meta["orig_k"])
            out.append((q, meta["sla"], meta["orig_t"]))
        return out

    def _failover(self, rep: _Replica, now: float):
        """A detected death: re-route the dead replica's limbo + queued
        requests to the surviving replicas (fresh rendezvous, which only
        moves the dead replica's sessions)."""
        pending = list(rep.limbo) + self._drain_queue(rep)
        rep.limbo = []
        for (q, sla, orig_t) in pending:
            target = self._route(q.session)
            if target is None:
                self._reject(q, sla, "no_replica", rerouted=True)
                continue
            self._deliver(self.replicas[target], q, sla, now=now,
                          orig_t=orig_t, rerouted=True)

    # -- dispatch -------------------------------------------------------------
    def _route(self, session: int) -> int | None:
        alive = [rep.rid for rep in self.replicas if rep.rid not in self.dead]
        return rendezvous_route(session, alive)

    def submit(self, r: QACRequest, sla: str = "interactive"):
        """One arriving request: heartbeat pass, session-affinity route,
        admission ladder, then either the replica's runtime or an explicit
        REJECTED result. Call in arrival-time order."""
        if sla not in ("interactive", "bulk"):
            raise ValueError(f"unknown SLA class {sla!r}")
        self._now = max(self._now, r.t_us)
        self._observe(self._now)
        rid = self._route(r.session)
        if rid is None:
            self._reject(r, sla, "no_replica", rerouted=False)
            return
        self._deliver(self.replicas[rid], r, sla, now=self._now,
                      orig_t=r.t_us, rerouted=False)

    def _deliver(self, rep: _Replica, r: QACRequest, sla: str, *,
                 now: float, orig_t: float, rerouted: bool):
        """Hand a routed request to its replica. If the replica is inside
        a not-yet-detected fault window the request is delivered into the
        void (kill) or a frozen accept queue (stall) and sits in limbo
        until detection or recovery; the queue bound still applies —
        back-pressure does not need a live heartbeat."""
        if self.injector.down(rep.rid, now) is not None:
            if rep.depth() >= self.cfg.max_queue:
                self._reject(r, sla, "queue_full", rerouted)
            else:
                rep.limbo.append((r, sla, orig_t))
            return
        self._admit(rep, r, sla, now=now, orig_t=orig_t, rerouted=rerouted)

    def _admit(self, rep: _Replica, r: QACRequest, sla: str, *, now: float,
               orig_t: float, rerouted: bool):
        """The admission ladder (module docstring): full service ->
        degraded service -> explicit shed."""
        cfg = self.cfg
        if rep.depth() >= cfg.max_queue:
            self._reject(r, sla, "queue_full", rerouted)
            return
        est = rep.est_wait_us(now)
        if est >= cfg.shed_pressure_us:
            self._reject(r, sla, "shed_overload", rerouted)
            return
        if sla == "bulk" and est >= cfg.shed_bulk_pressure_us:
            self._reject(r, sla, "shed_bulk", rerouted)
            return
        degraded = bool(est >= cfg.degrade_pressure_us)
        if degraded and sla == "bulk" and r.plen > 0:
            # degrade tier: bulk traffic loses the conjunctive engine
            self._reject(r, sla, "degrade_skip_multi", rerouted)
            return
        k = min(r.k, cfg.degraded_k) if degraded else r.k
        tr = self.tracer
        if tr is not None and tr.want(r.idx):
            tr.instant("admission", now, cat="cluster", req=r.idx,
                       decision="degrade" if degraded else "admit_full",
                       est_wait_us=est, replica=rep.rid, sla=sla,
                       k_served=k, rerouted=rerouted)
        self._meta[r.idx] = dict(replica=rep.rid, sla=sla, degraded=degraded,
                                 rerouted=rerouted, orig_t=orig_t,
                                 orig_k=r.k)
        if k != r.k or now != r.t_us:
            r = dataclasses.replace(r, t_us=now, k=k, deadline=0.0)
        rep.runtime.submit(r)

    def _reject(self, r: QACRequest, sla: str, reason: str, rerouted: bool):
        tr = self.tracer
        if tr is not None and tr.want(r.idx):
            tr.instant("admission", self._now, cat="cluster", req=r.idx,
                       decision="shed", reason=reason, sla=sla,
                       rerouted=rerouted)
        self.telemetry.shed[(sla, reason)] += 1
        if rerouted:
            self.telemetry.rerouted += 1
        self._results[r.idx] = ClusterResult(
            status=REJECTED, row=None, k_served=0, replica=None, sla=sla,
            degraded=False, rerouted=rerouted, reason=reason)

    # -- results --------------------------------------------------------------
    def _harvest(self, rep: _Replica):
        """Move the replica runtime's finished rows into cluster results,
        measuring latency from each request's ORIGINAL arrival."""
        rt = rep.runtime
        for idx, row in rt._results.items():
            meta = self._meta[idx]
            lat = rt.done_t_us[idx] - meta["orig_t"]
            self.telemetry.lat_us[meta["sla"]].append(lat)
            self.telemetry.per_replica[rep.rid] += 1
            if meta["degraded"]:
                self.telemetry.degraded_lat_us.append(lat)
            if meta["rerouted"]:
                self.telemetry.rerouted += 1
                self.telemetry.failover_lat_us.append(lat)
            self._results[idx] = ClusterResult(
                status=SERVED, row=row, k_served=int(row.shape[0]),
                replica=rep.rid, sla=meta["sla"], degraded=meta["degraded"],
                rerouted=meta["rerouted"],
                gen=rt.done_gen.get(idx, rt.generation))
        rt._results.clear()
        rt.done_t_us.clear()
        rt.done_path.clear()
        rt.done_gen.clear()

    def propagate_swap(self, generation: int,
                       frontends: list[QACFrontend], *, t_us: float = 0.0):
        """Cluster-wide generation swap: for every replica, flush its
        runtime queue (queued requests were admitted against the old
        generation and must be answered by it), harvest the finished rows
        with their old-generation tag, then install the new frontend —
        which invalidates both cache tiers exactly once per replica.
        ``frontends`` follows the constructor's contract (one per replica,
        or a shared warm instance repeated)."""
        if len(frontends) != self.cfg.n_replicas:
            raise ValueError(f"{len(frontends)} frontends for "
                             f"{self.cfg.n_replicas} replicas")
        self._now = max(self._now, t_us)
        for rep, fe in zip(self.replicas, frontends):
            if self.injector.down(rep.rid, self._now) is None:
                rep.runtime.drain()
            else:
                # a down replica cannot serve its old-generation queue; park
                # the requests in limbo (recovery/failover re-admits them
                # against whatever generation then serves, with original k)
                rep.limbo.extend(self._drain_queue(rep))
            self._harvest(rep)
            rep.runtime.install_generation(generation, fe)
        # reset() builds replicas from self.frontends — keep it current so
        # a post-swap reset restarts on the NEW generation
        self.frontends = list(frontends)
        self.telemetry.swaps.append((self._now, generation))
        if self.tracer is not None:
            self.tracer.instant("generation.swap", self._now, cat="cluster",
                                generation=generation)

    def drain(self):
        """End of trace: advance past the heartbeat timeout so any
        still-down replica is detected and its orphans re-route, flush
        every live queue, harvest everything."""
        self._now += self.cfg.heartbeat_timeout_us + 1.0
        self._observe(self._now)
        for rep in self.replicas:
            if self.injector.down(rep.rid, self._now) is None:
                rep.runtime.drain()
            self._harvest(rep)

    # -- drivers --------------------------------------------------------------
    def run_trace(self, reqs: list[QACRequest], sla=None):
        """Replay a timestamped request list -> list[ClusterResult] in
        trace order. ``sla`` is None (all interactive), one class name, or
        a per-request sequence."""
        sla = self._sla_list(reqs, sla)
        kmax = max((r.k for r in reqs), default=0)
        if kmax > self.capacity:
            raise ValueError(f"requested k={kmax} exceeds index capacity "
                             f"({self.capacity} completions)")
        last = -np.inf
        for r, s in zip(reqs, sla):
            if r.t_us < last:
                raise ValueError("trace must be sorted by arrival time")
            last = r.t_us
            self.submit(r, s)
        self.drain()
        missing = [r.idx for r in reqs if r.idx not in self._results]
        assert not missing, f"requests lost by the cluster: {missing[:5]}"
        return [self._results[r.idx] for r in reqs]

    def replay(self, reqs: list[QACRequest], sla=None, *, warm: bool = True):
        """The measured-replay protocol (same shape as the runtime's): one
        full warm pass compiles every jit variant the trace + drill can
        form, then a reset and a measured pass."""
        if warm:
            self.run_trace(reqs, sla)
            self.reset()
        return self.run_trace(reqs, sla)

    @staticmethod
    def _sla_list(reqs, sla) -> list[str]:
        if sla is None:
            return ["interactive"] * len(reqs)
        if isinstance(sla, str):
            return [sla] * len(reqs)
        sla = list(sla)
        if len(sla) != len(reqs):
            raise ValueError(f"{len(sla)} SLA classes for "
                             f"{len(reqs)} requests")
        return sla


def check_cluster_parity_timed(frontends_by_gen: dict,
                               reqs: list[QACRequest],
                               results: list[ClusterResult]) -> int:
    """The time-indexed parity oracle (ISSUE 9): every served result row
    must be bit-identical to the uncached frontend of the generation that
    ANSWERED it (``ClusterResult.gen``), truncated to its served k — the
    first ``k_served`` entries of that generation's full-k answer, by
    prefix-stable top-k. Returns the number of rows checked.

    ``frontends_by_gen`` maps generation id -> a ``QACFrontend`` over a
    from-scratch build of that generation's corpus. A request that crossed
    a swap (admitted under gen g, answered under g+1 — e.g. re-routed out
    of a dead replica) is checked against the generation that actually
    produced its docids; an unknown generation in the results is a hard
    failure, not a skip.
    """
    checked = 0
    for r, res in zip(reqs, results):
        if res.status != SERVED:
            continue
        if res.gen not in frontends_by_gen:
            raise AssertionError(
                f"request {r.idx} answered by unknown generation {res.gen} "
                f"(oracle has {sorted(frontends_by_gen)})")
        fe = frontends_by_gen[res.gen]
        want = np.asarray(fe.complete(
            r.pids[None], np.asarray([r.plen], np.int32), r.suf[None],
            np.asarray([r.slen], np.int32), k=r.k))[0]
        np.testing.assert_array_equal(
            res.row, want[: res.k_served],
            err_msg=(f"cluster parity break at request {r.idx} "
                     f"({r.query!r}, k_served={res.k_served}, "
                     f"replica={res.replica}, rerouted={res.rerouted}, "
                     f"gen={res.gen})"))
        checked += 1
    return checked


def check_cluster_parity(frontend: QACFrontend, reqs: list[QACRequest],
                         results: list[ClusterResult]) -> int:
    """Assert the fault-drill correctness gate: every served (non-REJECTED)
    result row is bit-identical to the uncached frontend oracle at its
    served k. The single-generation view of ``check_cluster_parity_timed``
    (one code path): every generation the results mention maps to the one
    frontend, which is exact whenever the cluster never swapped.

    ``run_naive_trace`` rows work as the oracle too; this helper exists so
    tests, the launcher smoke, and the bench all assert the same contract
    through one code path.
    """
    gens = {res.gen for res in results if res.status == SERVED}
    return check_cluster_parity_timed({g: frontend for g in gens or {0}},
                                      reqs, results)
