"""QAC serving: batched single-device path + docid-striped distributed path.

Distributed plan (DESIGN.md §4): requests are data-parallel over
(pod, data); the index is docid-striped over ``model``. Each stripe answers
every one of its queries locally (conjunctive or single-term), then the
k-candidate lists are all-gathered over ``model`` and min-k merged — O(k·S)
bytes per query, the production scatter/gather plan.

Engine policy (ISSUE 2): every serve entry point runs the batch-native
engines from ``core.search`` — one batched RMQ / conjunctive tile per inner
step across all B lanes — with a platform-aware kernel toggle
(``use_kernel=None`` -> Pallas on TPU, XLA reference elsewhere; see
``repro.compat.default_use_kernel``). The intersect kernel additionally
needs a host-verified probe-list bound, so only ``serve.frontend`` (which
routes on the host) enables it. The old vmap-of-scalar forms are kept as
``*_vmap`` parity references and benchmark baselines.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import shard_map, default_use_kernel

from ..core.search import (complete_conjunctive, complete_conjunctive_batch,
                           conjunctive_multi, conjunctive_multi_batch,
                           single_term_topk_batch,
                           single_term_topk_bounded,
                           single_term_topk_bounded_batch)
from ..core.striped import StripedQACIndex, local_index
from ..core.builder import QACIndex
from ..distributed.sharding import get_mesh


def qac_serve_step(qidx: QACIndex, prefix_ids, prefix_len, suffix_chars,
                   suffix_len, *, k: int = 10, tile: int = 128,
                   max_tiles: int = 4096, use_kernel: bool | None = None,
                   interpret: bool | None = None,
                   heap_kernel: bool | None = None,
                   postings_codec: str | None = None,
                   heap_kernel_max_bytes: int | None = None):
    """Fused single-index batched serve: -> docids int32[B, k] (INF padded).

    Every lane pays for BOTH engines (branchless select). This is the
    reference/fallback path; class-partitioned traffic should go through
    ``serve.frontend.QACFrontend``, which dispatches each class to only its
    engine via ``serve_single_term`` / ``serve_multi_term`` below.

    ``postings_codec`` (ISSUE 7) selects the postings device layout for the
    kernel routes — None/"auto" prefers raw CSR when it fits the
    ``heap_kernel_max_bytes`` VMEM gate and falls back to the compressed
    stream; "ef"/"bitpack" force the in-kernel decode route.
    """
    use_kernel = default_use_kernel() if use_kernel is None else use_kernel
    term_lo, term_hi = qidx.dictionary.locate_prefix(suffix_chars, suffix_len)
    return complete_conjunctive_batch(
        qidx.index, qidx.completions, qidx.rmq_minimal,
        prefix_ids, prefix_len, term_lo, term_hi, k,
        tile=tile, max_tiles=max_tiles, use_kernel=use_kernel,
        interpret=interpret, heap_kernel=heap_kernel,
        postings_codec=postings_codec,
        heap_kernel_max_bytes=heap_kernel_max_bytes)


def qac_serve_step_vmap(qidx: QACIndex, prefix_ids, prefix_len, suffix_chars,
                        suffix_len, *, k: int = 10, tile: int = 128,
                        max_tiles: int = 4096):
    """vmap-of-scalar fused serve — the parity/benchmark reference."""
    term_lo, term_hi = qidx.dictionary.locate_prefix(suffix_chars, suffix_len)

    def one(pids, plen, tl, th):
        return complete_conjunctive(
            qidx.index, qidx.completions, qidx.rmq_minimal,
            pids, plen, tl, th, k, tile=tile, max_tiles=max_tiles)

    return jax.vmap(one)(prefix_ids, prefix_len, term_lo, term_hi)


# -- split engines (class-pure batches; used by serve/frontend.py) ------------
def serve_single_term(qidx: QACIndex, suffix_chars, suffix_len, *, k: int = 10,
                      trips: int | None = None,
                      use_kernel: bool | None = None,
                      interpret: bool | None = None,
                      heap_kernel: bool | None = None,
                      postings_codec: str | None = None,
                      heap_kernel_max_bytes: int | None = None):
    """Batched single-term serve (paper §3.3) -> (docids int32[B, k], done).

    For a batch known to be 100% single-term (empty prefix). ``trips`` bounds
    the heap pops per lane (default k + 2 covers everything but pathological
    duplicate runs); ``done[b]`` is False where the budget was too small and
    the caller must fall back to the full 2k-trip engine for exact results.
    ``postings_codec``/``heap_kernel_max_bytes`` tune the heap-kernel VMEM
    routing (ISSUE 7): compressed postings decoded in-kernel when raw CSR
    does not fit the ceiling, or forced with an explicit codec.
    """
    trips = (k + 2) if trips is None else trips
    use_kernel = default_use_kernel() if use_kernel is None else use_kernel
    term_lo, term_hi = qidx.dictionary.locate_prefix(suffix_chars, suffix_len)
    return single_term_topk_bounded_batch(
        qidx.index, qidx.rmq_minimal, term_lo, term_hi, k, trips,
        use_kernel=use_kernel, interpret=interpret, heap_kernel=heap_kernel,
        postings_codec=postings_codec,
        heap_kernel_max_bytes=heap_kernel_max_bytes)


def serve_single_term_vmap(qidx: QACIndex, suffix_chars, suffix_len, *,
                           k: int = 10, trips: int | None = None):
    """vmap-of-scalar single-term serve — the parity/benchmark reference."""
    trips = (k + 2) if trips is None else trips
    term_lo, term_hi = qidx.dictionary.locate_prefix(suffix_chars, suffix_len)

    def one(tl, th):
        return single_term_topk_bounded(qidx.index, qidx.rmq_minimal, tl, th,
                                        k, trips)

    return jax.vmap(one)(term_lo, term_hi)


def serve_single_term_full(qidx: QACIndex, suffix_chars, suffix_len, *,
                           k: int = 10, use_kernel: bool | None = None,
                           interpret: bool | None = None,
                           heap_kernel: bool | None = None,
                           postings_codec: str | None = None,
                           heap_kernel_max_bytes: int | None = None):
    """Batched single-term serve, full 2k-trip budget (always exact)."""
    use_kernel = default_use_kernel() if use_kernel is None else use_kernel
    term_lo, term_hi = qidx.dictionary.locate_prefix(suffix_chars, suffix_len)
    return single_term_topk_batch(
        qidx.index, qidx.rmq_minimal, term_lo, term_hi, k,
        use_kernel=use_kernel, interpret=interpret, heap_kernel=heap_kernel,
        postings_codec=postings_codec,
        heap_kernel_max_bytes=heap_kernel_max_bytes)


def serve_multi_term(qidx: QACIndex, prefix_ids, prefix_len, suffix_chars,
                     suffix_len, *, k: int = 10, tile: int = 128,
                     max_tiles: int = 4096, use_kernel: bool = False,
                     interpret: bool | None = None, list_pad: int = 8192,
                     probe_iters: int = 0,
                     postings_codec: str | None = None):
    """Batched conjunctive serve (Fig 5 Fwd) for a 100%-multi-term batch.

    ``use_kernel`` here defaults to False (not platform-resolved): the
    intersect kernel holds probe lists in VMEM and is only correct when
    every needed list fits in ``list_pad``, a bound the caller must verify
    on the host (``serve.frontend.QACFrontend`` does — and, having
    verified it, also passes the matching ``probe_iters`` binary-search
    depth for the XLA probe path). With an explicit ``postings_codec``
    ("ef"/"bitpack", ISSUE 7) the kernel instead probes the compressed
    postings stream directly — no [B, P, L] list gather and no ``list_pad``
    bound at all, so it needs no host-side length check.
    """
    term_lo, term_hi = qidx.dictionary.locate_prefix(suffix_chars, suffix_len)
    return conjunctive_multi_batch(qidx.index, qidx.completions, prefix_ids,
                                   prefix_len, term_lo, term_hi, k, tile=tile,
                                   max_tiles=max_tiles, use_kernel=use_kernel,
                                   interpret=interpret, list_pad=list_pad,
                                   probe_iters=probe_iters,
                                   postings_codec=postings_codec)


def serve_multi_term_vmap(qidx: QACIndex, prefix_ids, prefix_len,
                          suffix_chars, suffix_len, *, k: int = 10,
                          tile: int = 128, max_tiles: int = 4096):
    """vmap-of-scalar conjunctive serve — the parity/benchmark reference."""
    term_lo, term_hi = qidx.dictionary.locate_prefix(suffix_chars, suffix_len)

    def one(pids, plen, tl, th):
        return conjunctive_multi(qidx.index, qidx.completions, pids, plen,
                                 tl, th, k, tile=tile, max_tiles=max_tiles)

    return jax.vmap(one)(prefix_ids, prefix_len, term_lo, term_hi)


def _local_serve(striped: StripedQACIndex, prefix_ids, prefix_len,
                 term_lo, term_hi, k: int, tile: int, max_tiles: int,
                 use_kernel: bool = False, interpret: bool | None = None,
                 heap_kernel: bool | None = None,
                 postings_codec: str | None = None,
                 heap_kernel_max_bytes: int | None = None):
    """Runs on one stripe (inside shard_map): [B_loc, k] local top-k.

    Batch-native fused engines; ``use_kernel`` routes the per-pop RMQ
    through the Pallas kernel (the intersect kernel stays off here — no
    host-side probe-list bound is available inside shard_map).
    ``postings_codec`` reaches the single-term heap route: when the stripe
    carries packed postings (``build_striped`` codec) the heap kernel can
    decode them in VMEM instead of raw CSR.
    """
    idx, fwd, rmq_min = local_index(striped)
    return complete_conjunctive_batch(idx, fwd, rmq_min, prefix_ids,
                                      prefix_len, term_lo, term_hi, k,
                                      tile=tile, max_tiles=max_tiles,
                                      use_kernel=use_kernel,
                                      interpret=interpret,
                                      heap_kernel=heap_kernel,
                                      postings_codec=postings_codec,
                                      heap_kernel_max_bytes=heap_kernel_max_bytes)


def qac_serve_striped(striped: StripedQACIndex, dictionary, prefix_ids,
                      prefix_len, suffix_chars, suffix_len, *, k: int = 10,
                      tile: int = 128, max_tiles: int = 4096, mesh=None,
                      merge: str = "gather", use_kernel: bool | None = None,
                      interpret: bool | None = None,
                      heap_kernel: bool | None = None,
                      postings_codec: str | None = None,
                      heap_kernel_max_bytes: int | None = None):
    """Distributed serve over the (pod?, data, model) mesh.

    Returns global top-k docids int32[B, k]. Without a mesh, runs a loop over
    stripes host-side (same math; used by tests).

    ``merge``: "gather" = one k-wide all-gather + min-k (baseline);
    "butterfly" = log2(S) XOR-pair exchange-merges (ppermute) — each round
    keeps min-k of (mine, partner's), so the wire carries k·log2(S) ints per
    query instead of k·S (§Perf iteration for the qac cells).
    """
    use_kernel = default_use_kernel() if use_kernel is None else use_kernel
    term_lo, term_hi = dictionary.locate_prefix(suffix_chars, suffix_len)
    mesh = mesh or get_mesh()
    S = striped.n_stripes

    if mesh is None or "model" not in mesh.axis_names or mesh.shape["model"] != S:
        # reference path: loop over stripes, merge
        parts = []
        for s in range(S):
            sub = jax.tree_util.tree_map(lambda a: a[s : s + 1], striped)
            parts.append(_local_serve(sub, prefix_ids, prefix_len,
                                      term_lo, term_hi, k, tile, max_tiles,
                                      use_kernel, interpret, heap_kernel,
                                      postings_codec, heap_kernel_max_bytes))
        allk = jnp.concatenate(parts, axis=1)              # [B, S*k]
        return lax.top_k(-allk, k)[0] * -1

    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = P(dp_axes if dp_axes else None)

    def local_fn(st, pids, plen, tl, th):
        local = _local_serve(st, pids, plen, tl, th, k, tile, max_tiles,
                             use_kernel, interpret, heap_kernel,
                             postings_codec, heap_kernel_max_bytes)
        if merge == "butterfly":
            nsh = mesh.shape["model"]
            cur = local
            for bit in range(nsh.bit_length() - 1):
                perm = [(i, i ^ (1 << bit)) for i in range(nsh)]
                other = lax.ppermute(cur, "model", perm)
                both = jnp.concatenate([cur, other], axis=1)
                cur = lax.top_k(-both, k)[0] * -1
            return cur
        gathered = lax.all_gather(local, "model", axis=1, tiled=True)  # [B, S*k]
        return lax.top_k(-gathered, k)[0] * -1

    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(P("model"), bspec, bspec, bspec, bspec),
        out_specs=bspec,
        check_vma=False,
    )(striped, prefix_ids, prefix_len, term_lo, term_hi)
