"""LM serving steps: prefill (full forward) and KV-cache decode.

These are the functions the dry-run lowers for ``prefill_*`` / ``decode_*`` /
``long_*`` shapes. Long-context decode relies on GSPMD sequence-parallelism:
the KV cache is sharded on its sequence axis over ``model``, so the decode
attention becomes local partial-softmax + a tiny cross-shard reduction
(distributed LSE merge) inserted by the partitioner.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..models.transformer import TransformerLM


def prefill_step(model: TransformerLM, params, tokens):
    """tokens int32[B, S] -> logits of the LAST position [B, V]."""
    logits, _, _ = model.forward(params, tokens)
    return logits[:, -1, :]


def make_decode_step(model: TransformerLM):
    """-> decode_step(params, cache, tokens[B]) -> (logits [B, V], cache)."""

    def step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return step


def greedy_generate(model: TransformerLM, params, prompt, max_new: int,
                    max_len: int):
    """Host loop: prefill via repeated decode (simple reference generator)."""
    B, S = prompt.shape
    cache = model.init_cache(B, max_len)
    logits = None
    for t in range(S):
        logits, cache = model.decode_step(params, cache, prompt[:, t])
    out = [jnp.argmax(logits, -1)]
    for _ in range(max_new - 1):
        logits, cache = model.decode_step(params, cache, out[-1])
        out.append(jnp.argmax(logits, -1))
    return jnp.stack(out, axis=1)
