from .qac import (  # noqa: F401
    qac_serve_step,
    qac_serve_step_vmap,
    qac_serve_striped,
    serve_single_term,
    serve_single_term_vmap,
    serve_single_term_full,
    serve_multi_term,
    serve_multi_term_vmap,
)
from .frontend import QACFrontend, route_classes  # noqa: F401
from .runtime import (  # noqa: F401
    QACOnlineRuntime,
    RuntimeConfig,
    QACRequest,
    prepare_requests,
    run_naive_trace,
)
from .cluster import (  # noqa: F401
    ClusterConfig,
    ClusterResult,
    QACServingCluster,
    assign_sla,
    check_cluster_parity,
    rendezvous_route,
)
from .lm import prefill_step, make_decode_step  # noqa: F401
