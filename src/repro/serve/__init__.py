from .qac import qac_serve_step, qac_serve_striped  # noqa: F401
from .lm import prefill_step, make_decode_step  # noqa: F401
