"""QAC serving stack, bottom to top (each layer only knows the one below):

  frontend   (frontend.py)  — batch-in/batch-out routed engine dispatch:
                              class routing (single vs conjunctive), pow2
                              batch/k buckets, per-variant jit cache.
  runtime    (runtime.py)   — ONE replica: deadline-aware micro-batching
                              over individually-arriving keystrokes, plus
                              the generation-tagged exact-prefix LRU and
                              session-filter cache tiers.
  cluster    (cluster.py)   — N replicas behind session-affinity dispatch:
                              SLA admission ladder, heartbeat failover,
                              cluster-wide generation swap propagation.
  freshness  (freshness.py) — live index updates: the in-memory delta
                              tier merged exactly over the immutable main
                              index per answer, and the rebuild-and-swap
                              path minting new generations under a
                              monotone generation id.

Correctness is one invariant all the way up: every fast path answers
bit-identically to its in-tree oracle — the engines to the host reference,
the runtime/cluster rows to an uncached frontend of the generation that
answered (``check_cluster_parity_timed``), and merged freshness answers to
a from-scratch build of their visible (generation, seq) version
(``GenerationalQAC.check_parity``).
"""
from .qac import (  # noqa: F401
    qac_serve_step,
    qac_serve_step_vmap,
    qac_serve_striped,
    serve_single_term,
    serve_single_term_vmap,
    serve_single_term_full,
    serve_multi_term,
    serve_multi_term_vmap,
)
from .frontend import QACFrontend, route_classes  # noqa: F401
from .runtime import (  # noqa: F401
    QACOnlineRuntime,
    RuntimeConfig,
    QACRequest,
    prepare_requests,
    run_naive_trace,
)
from .cluster import (  # noqa: F401
    ClusterConfig,
    ClusterResult,
    QACServingCluster,
    assign_sla,
    check_cluster_parity,
    check_cluster_parity_timed,
    rendezvous_route,
)
from .freshness import (  # noqa: F401
    FreshnessConfig,
    FreshResult,
    GenerationalQAC,
)
from .lm import prefill_step, make_decode_step  # noqa: F401
