"""Generational QAC serving: delta tier + exact k-way merge + atomic swap
(ISSUE 9 tentpole).

``GenerationalQAC`` is the freshness layer over the whole serving stack:
it owns a chain of immutable index *generations* (each a full
``build_qac_index`` artifact with its warmed ``QACFrontend``), the current
generation's ``core.delta.DeltaIndex`` absorbing live inserts, and ONE
``QACOnlineRuntime`` whose caches carry the generation tag. Three moving
parts:

  * **k-way merge serving** — every answered request is merged on the host
    from two sorted streams: the main tier's engine row (k smallest
    matching docids, which IS (-score, lexicographic-row) order) and the
    delta tier's matches at the request's visible sequence number. Merge
    key: ``(-score, token tuple)`` — term ids are lexicographic ranks, so
    comparing token tuples compares term rows, and the key survives
    dictionary regeneration across generations. Shadowed main docids
    (delta raised their score) are suppressed; the same completion
    re-enters from the delta stream. INF-padding semantics are preserved:
    fewer than k visible matches -> the answer is padded.

    The merge is *provably* exact per answer: the engine row's fetch
    horizon is its deepest examined docid, and every unfetched main match
    sorts strictly after it. If the merged k-th item does not sort at or
    before the horizon (delta entries displaced main items past it, or
    shadows consumed fetched slots), the layer ESCALATES — re-fetches the
    main tier at the next pow2 k (pow2 ks share the frontend's jit
    variants) until the bound holds or the tier is exhausted. Multi-term
    requests whose conjunctive driver scan would truncate
    (``tile * max_tiles``) skip the engine row and take a host-exact scan
    of the generation's forward index instead, so merged answers are true
    top-k even where the engine's budget is not.

  * **generation-tagged caches (cache-below-merge)** — the runtime's LRU
    and session tiers sit BELOW the merge and hold main-tier rows only.
    A main row is valid for the entire generation (the immutable tier
    never changes), so inserts never invalidate anything; the delta is
    merged on top at answer time with the request's own visible sequence
    number. A generation swap invalidates both tiers exactly once
    (``QACOnlineRuntime.install_generation``), extending the PR 4 cache
    exactness proofs to "exact w.r.t. the generation that answered".

  * **rebuild-and-swap** — when the delta reaches ``swap_threshold``
    visible changes, the delta folds into a fresh immutable build
    (``build_qac_index`` over base + applied entries + deferred OOV — the
    same builder, so the new generation is bit-identical to a from-scratch
    build by construction), the new frontend pre-warms its jit variants,
    and the swap itself is only: drain the runtime (queued requests were
    admitted against the old generation and must be answered by it),
    absorb their answers at the old version, install the new frontend
    under the next monotone generation id. ``swap_log`` records the
    background rebuild wall time and the (much smaller) swap stall
    separately.

Visible version = ``(generation, seq)``: a request's answer reflects the
generation installed when it was answered plus the first ``seq`` visible
delta changes. The time-indexed oracle (``oracle_answer`` /
``check_parity``) rebuilds that exact corpus from scratch per distinct
version and asserts every answer matches it — the freshness extension of
the repo's parity-oracle discipline. Event ordering makes the version
well-defined: a mutation first ticks the runtime clock (deadline
dispatches for earlier arrivals fire first, at the pre-mutation state),
then pending answers are absorbed, then the mutation applies.

Answers are completion STRINGS (k-tuples, None-padded), not docids —
docids are generation-local names and do not survive a swap; strings are
the stable identity the oracle can compare across builds.
"""
from __future__ import annotations

import dataclasses
import time
from collections import Counter, deque

import numpy as np

from ..core.builder import build_qac_index, parse_queries
from ..core.delta import DeltaIndex, MainCorpusView
from ..core.types import INF_DOCID
from ..obs.metrics import percentiles
from .frontend import QACFrontend
from .runtime import (QACOnlineRuntime, QACRequest, RuntimeConfig,
                      prepare_requests)


@dataclasses.dataclass
class FreshnessConfig:
    """Delta-tier + swap knobs, validated at construction like
    ``RuntimeConfig``/``ClusterConfig``. ``swap_threshold`` counts VISIBLE
    delta changes (applied inserts + in-place score raises); it must fit
    inside ``delta_capacity`` so the delta can never overflow between
    swaps, and the capacity must hold at least one full answer."""

    k: int = 10
    delta_capacity: int = 4096
    swap_threshold: int = 1024

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")
        if self.delta_capacity < self.k:
            raise ValueError(
                f"delta_capacity ({self.delta_capacity}) must be >= k "
                f"({self.k}) — the delta alone may have to fill an answer")
        if not 1 <= self.swap_threshold <= self.delta_capacity:
            raise ValueError(
                f"swap_threshold ({self.swap_threshold}) must be in "
                f"[1, delta_capacity={self.delta_capacity}]")


@dataclasses.dataclass
class _Generation:
    """One immutable tier: its build artifacts, host mirrors, warmed
    frontend, and the delta absorbing inserts while it is current."""

    gen: int
    qidx: object
    kept: list
    scores: np.ndarray
    view: MainCorpusView
    frontend: QACFrontend
    delta: DeltaIndex
    fwd: np.ndarray          # host forward index [N, M] for exact scans


@dataclasses.dataclass
class FreshResult:
    """One merged answer. ``strings``/``scores`` are k-tuples (None/0.0
    padded); ``gen``/``seq`` is the visible version the answer reflects
    (what the oracle rebuilds); ``n_delta`` counts items served from the
    delta tier; ``path`` is the runtime cache path of the main-tier row."""

    idx: int
    query: str
    k: int
    gen: int
    seq: int
    strings: tuple
    scores: tuple
    path: str
    n_delta: int
    escalations: int
    lat_us: float


class GenerationalQAC:
    """The freshness subsystem (module docstring): generations + delta +
    merge over one generation-tagged ``QACOnlineRuntime``."""

    def __init__(self, queries, scores, *, cfg: FreshnessConfig | None = None,
                 rt_cfg: RuntimeConfig | None = None,
                 frontend_kwargs: dict | None = None,
                 postings_codec: str | None = "ef",
                 tracer=None, registry=None):
        self.cfg = cfg if cfg is not None else FreshnessConfig()
        self.rt_cfg = rt_cfg if rt_cfg is not None else RuntimeConfig()
        # observability (ISSUE 10): shared with the runtime (reset threads
        # it through); merge/rebuild/swap emit their own spans here.
        self.tracer = tracer
        if registry is not None:
            registry.register_collector("freshness",
                                        lambda: self.snapshot())
        self._postings_codec = postings_codec
        self._fe_kwargs = dict(specialize_list_pad=False)
        self._fe_kwargs.update(frontend_kwargs or {})
        qidx, kept, sc = build_qac_index(
            list(queries), list(scores), k_default=self.cfg.k,
            postings_codec=postings_codec)
        self._g0 = self._make_generation(0, qidx, kept, sc,
                                         QACFrontend(qidx, **self._fe_kwargs))
        self.reset()

    def _make_generation(self, gen, qidx, kept, sc, fe) -> _Generation:
        view = MainCorpusView(qidx, kept, sc)
        return _Generation(
            gen=gen, qidx=qidx, kept=list(kept),
            scores=np.asarray(sc, np.float64), view=view, frontend=fe,
            delta=DeltaIndex(view, capacity=self.cfg.delta_capacity),
            fwd=np.asarray(qidx.completions.fwd_terms))

    def reset(self):
        """Fresh serving state back at generation 0 (measured-replay
        protocol); generation 0's warm frontend jit cache survives."""
        g0 = self._g0
        self.history: dict[int, _Generation] = {
            0: self._make_generation(0, g0.qidx, g0.kept, g0.scores,
                                     g0.frontend)}
        self.rt = QACOnlineRuntime(g0.frontend, self.rt_cfg,
                                   tracer=self.tracer)
        self.answers: dict[int, FreshResult] = {}
        self._req_by_idx: dict[int, QACRequest] = {}
        self._recent: deque = deque(maxlen=64)   # warm fodder for swaps
        self.apply_log: list[dict] = []
        self.swap_log: list[dict] = []
        self._oracle_cache: dict[tuple[int, int], tuple] = {}

    def _cur(self) -> _Generation:
        return self.history[self.rt.generation]

    # -- merge ----------------------------------------------------------------
    @staticmethod
    def _scan_exact_gen(g: _Generation, r: QACRequest) -> bool:
        """Mirror of ``QACOnlineRuntime._scan_exact`` against generation
        g's own posting lists (the request was parsed under g, so its term
        ids index g's lists, not whatever is installed now)."""
        if r.plen == 0:
            return True
        ll = g.frontend._list_lens
        terms = np.clip(r.pids[: r.plen], 0, len(ll) - 1)
        return int(ll[terms].min()) <= g.frontend.tile * g.frontend.max_tiles

    def _main_key(self, g: _Generation, d: int) -> tuple:
        return (-float(g.view.score_of_docid[d]), g.view.tokens_of_docid[d])

    def _merge(self, g: _Generation, r: QACRequest, row: np.ndarray,
               seq: int):
        """Merge the main-tier row with the delta at sequence ``seq`` into
        the exact top-k (strings, scores, n_delta, escalations)."""
        delta = g.delta
        d_ids = delta.matches(r.pids, r.plen, r.lo, r.hi, upto=seq)
        d_items = [(-delta.entries[i].score_at(seq), delta.entries[i].tokens,
                    delta.entries[i].query) for i in d_ids]
        shadowed = delta.shadowed(seq)
        escalations = 0
        if not self._scan_exact_gen(g, r):
            # the engine's conjunctive driver scan would truncate on this
            # request: take the host-exact scan of g's forward index so the
            # merged answer is true top-k regardless of the engine budget
            rows = g.fwd
            keep = ((rows >= r.lo) & (rows < r.hi)).any(axis=1)
            for t in set(int(x) for x in r.pids[: r.plen]):
                keep &= (rows == t).any(axis=1)
            fetched = np.nonzero(keep)[0].tolist()
            exhausted = True
            escalations = -1            # sentinel: host-exact path taken
        else:
            fetched = [int(d) for d in row if d != INF_DOCID]
            exhausted = len(fetched) < len(row)
        kprime = max(r.k, 1)
        n_main = int(g.view.score_of_docid.shape[0])
        while True:
            m_items = [self._main_key(g, d) + (g.view.string_of_docid[d],)
                       for d in fetched if d not in shadowed]
            merged = sorted(d_items + m_items)
            if exhausted:
                break
            horizon = self._main_key(g, fetched[-1]) if fetched else None
            if (len(merged) >= r.k
                    and (horizon is None
                         or merged[r.k - 1][:2] <= horizon)):
                break
            # escalate: deeper main fetch at the next pow2 k
            escalations += 1
            kprime = max(kprime * 2, 2)
            kprime = 1 << (kprime - 1).bit_length()
            if self.tracer is not None and self.tracer.want(r.idx):
                self.tracer.instant("merge.escalate", r.t_us,
                                    cat="freshness", req=r.idx,
                                    kprime=kprime, gen=g.gen)
            out = np.asarray(g.frontend.complete(
                r.pids[None], np.asarray([r.plen], np.int32), r.suf[None],
                np.asarray([r.slen], np.int32), k=min(kprime, n_main)))[0]
            fetched = [int(d) for d in out if d != INF_DOCID]
            exhausted = len(fetched) < out.shape[0] or kprime >= n_main
        top = merged[: r.k]
        strings = tuple(t[2] for t in top) + (None,) * (r.k - len(top))
        scs = tuple(-t[0] for t in top) + (0.0,) * (r.k - len(top))
        n_delta = sum(1 for t in top if t[:2] in
                      {(s, tk) for s, tk, _ in d_items})
        return strings, scs, n_delta, max(escalations, 0)

    def _absorb(self):
        """Move finished runtime rows into merged answers at the CURRENT
        visible version (absorb always runs before a mutation applies or a
        swap installs, so "current" is exactly what those rows saw)."""
        rt = self.rt
        if not rt._results:
            return
        tr = self.tracer
        for idx, row in rt._results.items():
            r = self._req_by_idx.pop(idx)
            g = self.history[rt.done_gen[idx]]
            seq = g.delta.seq
            traced = tr is not None and tr.want(idx)
            t0 = time.perf_counter() if traced else 0.0
            strings, scs, n_delta, esc = self._merge(g, r, row, seq)
            if traced:
                tr.span("merge.kway", rt.done_t_us[idx],
                        (time.perf_counter() - t0) * 1e6, cat="freshness",
                        req=idx, n_delta=n_delta, escalations=esc,
                        seq=seq, gen=g.gen)
            self.answers[idx] = FreshResult(
                idx=idx, query=r.query, k=r.k, gen=g.gen, seq=seq,
                strings=strings, scores=scs, path=rt.done_path[idx],
                n_delta=n_delta, escalations=esc,
                lat_us=rt.done_t_us[idx] - r.t_us)
        rt._results.clear()
        rt.done_t_us.clear()
        rt.done_path.clear()
        rt.done_gen.clear()

    # -- mutations ------------------------------------------------------------
    def insert(self, query: str, score: float, t_us: float = 0.0) -> str:
        """Apply one live mutation at virtual time ``t_us``: tick the
        runtime (deadline dispatches for earlier arrivals fire at the
        pre-mutation state), absorb their answers, apply the insert, and
        rebuild-and-swap if the delta crossed the threshold. Returns the
        ``DeltaIndex.insert`` outcome kind."""
        self.rt.tick(t_us)
        self._absorb()
        g = self._cur()
        t0 = time.perf_counter()
        out = g.delta.insert(query, score)
        self.apply_log.append(dict(
            t_us=float(t_us), outcome=out, gen=g.gen,
            wall_us=(time.perf_counter() - t0) * 1e6))
        if self.tracer is not None:
            self.tracer.instant("delta.apply", float(t_us), cat="freshness",
                                outcome=out, gen=g.gen, seq=g.delta.seq)
        if g.delta.seq >= self.cfg.swap_threshold:
            self._rebuild_and_swap(t_us)
        return out

    def _warm_frontend(self, fe: QACFrontend):
        """Pre-compile the new generation's jit variants from recent
        traffic (pow2 sweep, both engine classes) — part of the BACKGROUND
        rebuild cost, never the swap stall."""
        good = [r for r in self._recent if not QACOnlineRuntime._is_bad(r)]
        for rs in ([r for r in good if r.plen == 0],
                   [r for r in good if r.plen > 0]):
            if not rs:
                continue
            b = 1
            while b <= max(self.rt_cfg.max_batch, 1):
                take = [rs[i % len(rs)] for i in range(b)]
                fe.complete(
                    np.stack([r.pids for r in take]),
                    np.asarray([r.plen for r in take], np.int32),
                    np.stack([r.suf for r in take]),
                    np.asarray([r.slen for r in take], np.int32),
                    k=np.asarray([r.k for r in take], np.int32))
                if b == self.rt_cfg.max_batch:
                    break
                b = min(b * 2, self.rt_cfg.max_batch)

    def _rebuild_and_swap(self, t_us: float):
        """Fold the delta into a fresh immutable build, then atomically
        install it. The rebuild + new-frontend warm happen "in background"
        (their wall time is ``rebuild_wall_us``); the swap stall is only
        drain + absorb + install."""
        g = self._cur()
        t0 = time.perf_counter()
        dq, ds = g.delta.fold_corpus()
        qidx, kept, sc = build_qac_index(
            g.kept + dq, list(g.scores) + ds, k_default=self.cfg.k,
            postings_codec=self._postings_codec)
        fe = QACFrontend(qidx, **self._fe_kwargs)
        self._warm_frontend(fe)
        rebuild_us = (time.perf_counter() - t0) * 1e6
        t1 = time.perf_counter()
        self.rt.drain()
        self._absorb()                      # old-version answers, pre-swap
        new_gen = g.gen + 1
        self.history[new_gen] = self._make_generation(
            new_gen, qidx, kept, sc, fe)
        self.rt.install_generation(new_gen, fe)
        stall_us = (time.perf_counter() - t1) * 1e6
        self.swap_log.append(dict(
            t_us=float(t_us), gen=new_gen, rebuild_wall_us=rebuild_us,
            swap_stall_us=stall_us, folded=g.delta.n,
            folded_seq=g.delta.seq, deferred=len(g.delta.deferred)))
        if self.tracer is not None:
            self.tracer.span("generation.rebuild", float(t_us), rebuild_us,
                             cat="freshness", gen=new_gen, folded=g.delta.n)
            self.tracer.span("generation.swap_stall", float(t_us), stall_us,
                             cat="freshness", gen=new_gen)
            self.tracer.instant("generation.swap", float(t_us),
                                cat="freshness", generation=new_gen)

    # -- serving --------------------------------------------------------------
    def _flush_requests(self, buf: list, k: int):
        """Parse a run of buffered request events against the CURRENT
        generation's dictionary and submit them in arrival order. Safe to
        batch: between two mutations the runtime is driven purely by
        ``submit`` at each request's own timestamp."""
        if not buf:
            return
        g = self._cur()
        reqs = parse_and_prepare(g.qidx, [(t, s, q) for _, t, s, q in buf],
                                 k=k)
        for (gidx, _, _, _), r in zip(buf, reqs):
            r.idx = gidx
            self._req_by_idx[gidx] = r
            self._recent.append(r)
            self.rt.submit(r)

    def run_mutation_trace(self, events, *, k: int | None = None):
        """Replay a mutation trace (``text.synth.generate_mutation_trace``
        events or (t_us, kind, session, query, score) tuples) -> list of
        ``FreshResult`` in request order."""
        k = self.cfg.k if k is None else k
        buf, req_order = [], []
        last = -np.inf
        for gidx, ev in enumerate(events):
            t, kind, sess, q, sc = _norm_event(ev)
            if t < last:
                raise ValueError("trace must be sorted by event time")
            last = t
            if kind == "request":
                buf.append((gidx, t, sess, q))
                req_order.append(gidx)
            elif kind in ("insert", "trend"):
                self._flush_requests(buf, k)
                buf = []
                self.insert(q, sc, t)
            else:
                raise ValueError(f"unknown event kind {kind!r}")
        self._flush_requests(buf, k)
        self.rt.drain()
        self._absorb()
        missing = [i for i in req_order if i not in self.answers]
        assert not missing, f"requests lost by freshness layer: {missing[:5]}"
        return [self.answers[i] for i in req_order]

    def replay(self, events, *, k: int | None = None, warm: bool = True):
        """Measured-replay protocol (runtime/cluster shape): one full warm
        pass compiles generation 0's variants and exercises every swap the
        trace will perform, then reset + measured pass."""
        if warm:
            self.run_mutation_trace(events, k=k)
            self.reset()
        return self.run_mutation_trace(events, k=k)

    def complete_batch(self, raw_queries, *, k: int | None = None):
        """Batched merged path, no runtime/caches: parse + main-tier
        ``frontend.complete`` + per-row delta merge at the current version.
        The bench's merged-vs-immutable comparison point. Returns
        list[tuple[str | None, ...]] of length k each."""
        k = self.cfg.k if k is None else k
        g = self._cur()
        reqs = parse_and_prepare(
            g.qidx, [(0.0, 0, q) for q in raw_queries], k=k)
        out = np.asarray(g.frontend.complete(
            np.stack([r.pids for r in reqs]),
            np.asarray([r.plen for r in reqs], np.int32),
            np.stack([r.suf for r in reqs]),
            np.asarray([r.slen for r in reqs], np.int32), k=k))
        seq = g.delta.seq
        return [self._merge(g, r, out[i, : k], seq)[0]
                for i, r in enumerate(reqs)]

    # -- the time-indexed oracle ----------------------------------------------
    def oracle_index(self, gen: int, seq: int):
        """From-scratch build of visible version (gen, seq): the
        generation's base corpus + its delta oplog replayed to ``seq``,
        through the ONE production builder. Cached per distinct version."""
        key = (gen, seq)
        hit = self._oracle_cache.get(key)
        if hit is not None:
            return hit
        g = self.history[gen]
        ops = g.delta.oplog[:seq]
        qidx, kept, sc = build_qac_index(
            g.kept + [q for q, _ in ops],
            list(g.scores) + [s for _, s in ops],
            k_default=self.cfg.k, postings_codec=self._postings_codec)
        view = MainCorpusView(qidx, kept, sc)
        fwd = np.asarray(qidx.completions.fwd_terms)
        self._oracle_cache[key] = (qidx, view, fwd)
        return self._oracle_cache[key]

    def oracle_answer(self, raw_query: str, gen: int, seq: int,
                      k: int) -> tuple:
        """The ground truth for one answer: parse ``raw_query`` against the
        from-scratch index of version (gen, seq) and take its exact top-k
        (smallest matching docids == (-score, lexicographic row) order),
        decoded to strings. This is what every served ``FreshResult`` must
        equal, bit for bit."""
        qidx, view, fwd = self.oracle_index(gen, seq)
        pids, plen, pok, suf, slen = parse_queries(qidx.dictionary,
                                                   [raw_query])
        lo, hi = (int(np.asarray(a)[0]) for a in
                  qidx.dictionary.locate_prefix(suf, slen))
        pl = int(plen[0])
        if hi <= lo or (pl > 0 and bool((pids[0, :pl] == 0).any())):
            return (None,) * k
        keep = ((fwd >= lo) & (fwd < hi)).any(axis=1)
        for t in set(int(x) for x in pids[0, :pl]):
            keep &= (fwd == t).any(axis=1)
        docids = np.nonzero(keep)[0][:k]
        strings = tuple(view.string_of_docid[int(d)] for d in docids)
        return strings + (None,) * (k - len(strings))

    def check_parity(self, results, *, sample_every: int = 1) -> int:
        """Assert the time-indexed parity gate over served results: every
        (sampled) answer's strings equal the from-scratch oracle at its own
        visible version. Returns the number of answers checked."""
        checked = 0
        for res in results[::max(sample_every, 1)]:
            want = self.oracle_answer(res.query, res.gen, res.seq, res.k)
            assert res.strings == want, (
                f"freshness parity break at request {res.idx} "
                f"({res.query!r}, gen={res.gen}, seq={res.seq}): "
                f"served {res.strings[:3]}... vs oracle {want[:3]}...")
            checked += 1
        return checked

    # -- reporting ------------------------------------------------------------
    def snapshot(self) -> dict:
        """Freshness counters + the runtime telemetry snapshot."""
        served = list(self.answers.values())
        # the shared percentile helper; the `or [0.0]` fallback is kept so
        # a zero-mutation replay still reports floats (this snapshot's
        # long-standing contract, unlike the runtime/cluster latency keys)
        ap = percentiles([a["wall_us"] for a in self.apply_log] or [0.0],
                         (50, 99))
        st = percentiles([s["swap_stall_us"] for s in self.swap_log]
                         or [0.0], (99,))
        return {
            "generation": self.rt.generation,
            "n_swaps": len(self.swap_log),
            "n_mutations": len(self.apply_log),
            "mutation_outcomes": dict(
                Counter(a["outcome"] for a in self.apply_log)),
            "delta_stats": self._cur().delta.stats(),
            "delta_hit_answers": sum(1 for r in served if r.n_delta > 0),
            "escalations": sum(r.escalations for r in served),
            "apply_p50_us": ap["p50_us"],
            "apply_p99_us": ap["p99_us"],
            "swap_stall_p99_us": st["p99_us"],
            "rebuild_wall_us": [s["rebuild_wall_us"] for s in self.swap_log],
            "runtime": self.rt.telemetry.snapshot(),
        }


def _norm_event(ev):
    """(t_us, kind, session, query, score) from a MutationEvent-like
    object or a plain tuple."""
    if hasattr(ev, "kind"):
        return (float(ev.t_us), ev.kind, int(ev.session), ev.query,
                float(ev.score))
    t, kind, sess, q, sc = ev
    return float(t), kind, int(sess), q, float(sc)


def parse_and_prepare(qidx, trace, *, k: int = 10):
    """``runtime.prepare_requests`` under its freshness-layer name: one
    batched parse of (t_us, session, query) events against a SPECIFIC
    generation's dictionary — requests are generation-local, so the
    freshness layer re-parses per generation rather than once per trace."""
    return prepare_requests(qidx, trace, k=k)
