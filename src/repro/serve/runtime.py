"""Online QAC serving runtime (ISSUE 4 tentpole).

Everything below ``QACFrontend`` is batch-in/batch-out; production QAC
traffic is neither — requests arrive one at a time, keystroke by keystroke
per session, and the paper's whole motivation is an SLA the old system
missed under that load. This module is the layer in between:

  * **micro-batch scheduler** — individually-arriving timestamped requests
    join a FIFO queue; a batch dispatches when ``max_batch`` requests are
    waiting (the bucket is full) OR the oldest request's slack expires
    (``deadline = arrival + slack_us``). Batches go straight into
    ``QACFrontend.complete``, whose pow2 bucketing + per-(engine, bucket, k)
    jit cache means steady-state traffic never recompiles.
  * **prefix-result cache + session store** — QAC keystroke streams are
    pathologically cacheable: sessions retype the same popular prefixes
    (exact-hit LRU, keyed by the *parsed* query so whitespace variants
    share entries), and each keystroke extends the session's previous
    prefix by one character. When the previous answer was *complete*
    (fewer than k matches — an INF_DOCID-padded row IS the whole match
    set) and the extension provably shrinks the match set, the new answer
    is computed by filtering the cached set on the host — no engine
    dispatch at all. Results are bit-identical to an uncached
    ``QACFrontend`` call by construction (tests/test_serve_runtime.py
    checks every interleaving against direct per-request calls).
  * **telemetry** — per-request latency percentiles (p50/p95/p99), queue
    depth (max-depth gauge), deadline-violation counter, batch-size
    histogram, dispatch triggers, cache hit rate.

One instance of this class is ONE serving replica, and on its own it never
sheds load: the queue is unbounded and every admitted request is served no
matter how late. That is deliberate — overload policy is a *cluster*
concern. ``serve/cluster.py::QACServingCluster`` runs N of these replicas
behind a session-affinity dispatcher and owns the SLA-class admission
state machine (serve -> degrade -> shed; see that module's docstring);
its hooks into this runtime are ``on_dispatch`` (per-dispatch service
telemetry feeding the queue-pressure estimator) and ``done_t_us``
(virtual completion times, so re-routed requests can be measured from
their original arrival).

Time model: the runtime runs on an explicit clock in MICROSECONDS. Trace
replay (``run_trace``) uses the trace's virtual arrival times for queueing
decisions and *measured wall time* for engine service, the standard
queueing-simulation hybrid — so reported latency includes real queueing
behind a busy server. A live deployment would feed ``submit`` with
``time.monotonic()``-derived stamps instead, plus a periodic ``tick(now)``
so deadlines fire during traffic lulls. One simplification: a
dispatched batch's results are visible to the cache immediately rather
than at completion time; at keystroke cadence (~100ms) vs batch service
(~ms) the distinction is noise, and it cannot affect parity.

The exactness argument for the session filter path, spelled out. A request
parses to prefix term-ids ``P`` and a suffix term range ``[lo, hi)``; the
engine returns the k smallest docids d with ``P ⊆ T(d)`` and
``T(d) ∩ [lo, hi) ≠ ∅`` (T(d) = the completion's term set, docid order ==
score order). For a previous request (P0, [lo0, hi0)) and a new one
(P, [lo, hi)), the new match set is a subset of the old when

  ``P0 ⊆ P``  AND  ( ``[lo, hi) ⊆ [lo0, hi0)``                — suffix grew
                 OR  ``∃ t ∈ P \\ P0 with lo0 <= t < hi0`` )   — term completed

(the second disjunct is the just-promoted term witnessing the old suffix
condition). Both keystroke moves — append a character, or complete a term
with a space — satisfy one of these, so a session's chain of complete
results survives the whole tail of a query. Backtracking (deleted
characters) GROWS the match set, so it can never reuse the session entry —
it hits the exact LRU instead, which still holds the shorter prefixes.
"""
from __future__ import annotations

import dataclasses
import time
from collections import Counter, OrderedDict, deque

import numpy as np

from ..core.builder import QACIndex, parse_queries
from ..core.types import INF_DOCID
from ..obs.metrics import percentiles
from .frontend import QACFrontend


@dataclasses.dataclass
class RuntimeConfig:
    """Scheduler + cache knobs. These defaults suit host-CPU demo scale;
    ``QACArch.online_*`` / ``runtime_config()`` is the production-scale
    preset (bigger batches and caches) and what ``launch/serve.py
    --online`` starts from."""

    max_batch: int = 64          # dispatch as soon as this many misses queue
    slack_us: float = 20_000.0   # batching deadline per request (NOT the SLA)
    cache_entries: int = 1 << 16   # exact prefix-result LRU capacity; 0 = off
    session_entries: int = 1 << 16  # session store capacity; 0 = off

    def __post_init__(self):
        # fail at construction with a nameable field, not deep inside a
        # dispatch (ISSUE 8 satellite). slack_us == 0 is legal (dispatch
        # immediately); a negative deadline is not.
        if self.max_batch <= 0:
            raise ValueError(f"max_batch must be positive, "
                             f"got {self.max_batch}")
        if self.slack_us < 0:
            raise ValueError(f"slack_us must be >= 0, got {self.slack_us}")
        if self.cache_entries < 0:
            raise ValueError(f"cache_entries must be >= 0, "
                             f"got {self.cache_entries}")
        if self.session_entries < 0:
            raise ValueError(f"session_entries must be >= 0, "
                             f"got {self.session_entries}")


@dataclasses.dataclass
class QACRequest:
    """One timestamped keystroke request, pre-parsed for the engines.

    ``key`` is the parsed identity (prefix ids + suffix bytes) — the cache
    key, so queries that parse identically share results. ``lo``/``hi`` is
    the suffix's term range from ``dictionary.locate_prefix``; the session
    fast path needs it on the host, and it is bit-for-bit what the engine
    recomputes on device (same structure, same search).
    """

    idx: int
    t_us: float
    session: int
    query: str
    k: int
    pids: np.ndarray      # int32[MAX_TERMS]
    plen: int
    ok: bool              # parse's prefix_ok (every prefix term known)
    suf: np.ndarray       # uint8[MAX_TERM_CHARS]
    slen: int
    lo: int
    hi: int
    key: tuple
    deadline: float = 0.0


def prepare_requests(qidx: QACIndex, trace, *, k: int | np.ndarray = 10):
    """(t_us, session, query) events -> list[QACRequest], one batched parse.

    ``trace`` is what ``text.synth.generate_keystroke_trace`` emits (any
    iterable of timestamped (t_us, session_id, raw_query) works). ``k`` may
    be a scalar or a per-request array (the frontend's per-request-k path
    serves mixed-k batches exactly).
    """
    trace = list(trace)
    raw = [q for _, _, q in trace]
    pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, raw)
    lo, hi = qidx.dictionary.locate_prefix(suf, slen)
    pids, plen, suf, slen, lo, hi = (
        np.asarray(a) for a in (pids, plen, suf, slen, lo, hi))
    karr = np.broadcast_to(np.asarray(k, np.int32), (len(raw),))
    reqs = []
    for i, (t, sess, q) in enumerate(trace):
        pl, sl = int(plen[i]), int(slen[i])
        key = (pl, pids[i, :pl].tobytes(), sl, suf[i, :sl].tobytes())
        reqs.append(QACRequest(
            idx=i, t_us=float(t), session=int(sess), query=q,
            k=int(karr[i]), pids=pids[i], plen=pl, ok=bool(pok[i]),
            suf=suf[i], slen=sl, lo=int(lo[i]), hi=int(hi[i]), key=key))
    return reqs


@dataclasses.dataclass
class _SessionEntry:
    """Last answered request of a session: its parse + (when complete) the
    FULL ascending match set. ``full is None`` == truncated, no reuse.
    ``gen`` is the index generation that produced the match set — docids
    from another generation name different completions, so reuse requires
    ``gen == runtime.generation`` (enforced in ``_reusable``)."""

    pid_set: frozenset
    lo: int
    hi: int
    full: np.ndarray | None
    gen: int = 0


class RuntimeTelemetry:
    """Latency/cache/batch counters; ``snapshot()`` -> flat dict."""

    def __init__(self):
        self.lat_us: list[float] = []
        self.paths: Counter = Counter()
        self.batch_sizes: list[int] = []
        self.triggers: Counter = Counter()
        self.queue_peak = 0
        self.engine_wall_us = 0.0
        # a deadline violation = a dispatch that STARTED after the oldest
        # batched request's (arrival + slack) deadline — the server was so
        # backed up the batching budget was already blown before service
        # began. The saturation bench (ISSUE 8) gates on this counter and
        # on queue_peak, so both are first-class snapshot() fields.
        self.deadline_violations = 0
        # freshness (ISSUE 9): per-generation path counters + the swap
        # invalidation ledger. paths_by_gen[g] counts hits/misses answered
        # while generation g was installed; invalidations[(old, new)]
        # records each swap's flush exactly once (count, entries dropped
        # per tier) — tests assert count == 1 per transition.
        self.paths_by_gen: dict[int, Counter] = {}
        self.invalidations: dict[tuple[int, int], dict] = {}

    def record(self, path: str, lat_us: float, gen: int | None = None):
        self.paths[path] += 1
        self.lat_us.append(lat_us)
        if gen is not None:
            self.paths_by_gen.setdefault(gen, Counter())[path] += 1

    def record_invalidation(self, old_gen: int, new_gen: int,
                            n_lru: int, n_sessions: int):
        key = (old_gen, new_gen)
        entry = self.invalidations.setdefault(
            key, {"count": 0, "lru_entries": 0, "session_entries": 0})
        entry["count"] += 1
        entry["lru_entries"] += n_lru
        entry["session_entries"] += n_sessions

    def snapshot(self) -> dict:
        n = len(self.lat_us)
        hits = self.paths["hit_exact"] + self.paths["hit_session"]
        hist = {}
        if self.batch_sizes:
            bs = np.asarray(self.batch_sizes)
            sizes, counts = np.unique(bs, return_counts=True)
            hist = {int(s): int(c) for s, c in zip(sizes, counts)}
        snap = {"n_requests": n}
        # the repo's ONE percentile implementation (obs.metrics): a window
        # that served nothing reports explicit None, never a fake 0us
        snap.update(percentiles(self.lat_us, mean=True, vmax=True))
        snap.update({
            "cache_hit_rate": hits / max(n, 1),
            "paths": dict(self.paths),
            "n_batches": len(self.batch_sizes),
            "mean_batch_size": (float(np.mean(self.batch_sizes))
                                if self.batch_sizes else None),
            "batch_hist": hist,
            "triggers": dict(self.triggers),
            "queue_peak": self.queue_peak,
            "max_queue_depth": self.queue_peak,
            "deadline_violations": self.deadline_violations,
            "engine_wall_us": float(self.engine_wall_us),
            "per_generation": {g: dict(c)
                               for g, c in sorted(self.paths_by_gen.items())},
            "invalidations": {f"{o}->{n}": dict(v) for (o, n), v in
                              sorted(self.invalidations.items())},
        })
        return snap


class QACOnlineRuntime:
    """Deadline-aware micro-batching + keystroke-locality caches over a
    ``QACFrontend``. One instance per serving replica; ``reset()`` clears
    queue/caches/telemetry but keeps the frontend's warm jit cache."""

    def __init__(self, frontend: QACFrontend, cfg: RuntimeConfig | None = None,
                 *, tracer=None, registry=None):
        self.fe = frontend
        self.cfg = cfg if cfg is not None else RuntimeConfig()
        # observability (ISSUE 10): every instrumentation site below is
        # behind `if self.tracer is not None` (+ per-request sampling), so
        # tracer=None costs one attribute check per request. The registry
        # collector closes over self, so reset()'s fresh telemetry is
        # picked up without re-registering.
        self.tracer = tracer
        if registry is not None:
            registry.register_collector("runtime",
                                        lambda: self.telemetry.snapshot())
        # host forward index for the session filter path: docid -> term row
        self.fwd = np.asarray(frontend.qidx.completions.fwd_terms)
        # posting-list lengths (host), for the completeness proof below
        self._list_lens = frontend._list_lens
        # cluster hook (serve/cluster.py): called as
        # on_dispatch(batch_size, wall_us, t_start) after every engine
        # dispatch, feeding the dispatcher's per-replica EWMA service-time
        # estimate. None = standalone runtime, no observer.
        self.on_dispatch = None
        # freshness (ISSUE 9): the installed index generation. Cache keys
        # and session entries carry this tag, and ``install_generation``
        # is the ONLY way to advance it — reset() deliberately leaves it
        # alone (it is index identity, not cache state).
        self.generation = 0
        self.reset()

    def reset(self):
        self.cache: OrderedDict = OrderedDict()     # (key, k) -> row int32[k]
        self.sessions: OrderedDict = OrderedDict()  # session -> _SessionEntry
        self.queue: deque = deque()
        self._server_free = 0.0
        self._results: dict[int, np.ndarray] = {}
        # virtual completion time per request idx (t_us + its latency) —
        # the cluster measures re-routed requests from their ORIGINAL
        # arrival, which only it knows, so it reads completion times here
        self.done_t_us: dict[int, float] = {}
        # freshness bookkeeping per answered request: which cache path
        # served it and which generation was installed when it finished —
        # the freshness layer keys its per-answer delta merge and the
        # time-indexed oracle on these.
        self.done_path: dict[int, str] = {}
        self.done_gen: dict[int, int] = {}
        self.telemetry = RuntimeTelemetry()

    def install_generation(self, generation: int, frontend: QACFrontend):
        """Atomically swap in a rebuilt index: flush both cache tiers
        EXACTLY ONCE (recorded in telemetry), rebind the frontend and its
        host mirrors, and advance the generation id. Idempotent on the
        same generation (a re-delivered swap must not double-flush);
        refuses to move backwards; refuses to swap under queued requests
        (the caller drains first — queued requests were admitted against
        the old generation and must be answered by it)."""
        if generation == self.generation:
            return
        if generation < self.generation:
            raise ValueError(f"generation must be monotone: "
                             f"{self.generation} -> {generation}")
        if self.queue:
            raise RuntimeError(
                f"cannot swap generation with {len(self.queue)} queued "
                f"requests; drain() first")
        self.telemetry.record_invalidation(
            self.generation, generation, len(self.cache), len(self.sessions))
        self.cache.clear()
        self.sessions.clear()
        self.fe = frontend
        self.fwd = np.asarray(frontend.qidx.completions.fwd_terms)
        self._list_lens = frontend._list_lens
        self.generation = generation

    # -- host mirrors of the engine's semantics -------------------------------
    @staticmethod
    def _is_bad(r: QACRequest) -> bool:
        """The engines' reject rule, verbatim: empty suffix range always; an
        unknown (id 0) prefix term for the multi-term class. Rejected lanes
        are all-INF on device, so answering INF here is bit-identical."""
        if r.hi <= r.lo:
            return True
        return r.plen > 0 and bool((r.pids[: r.plen] == 0).any())

    def _match_rows(self, docids: np.ndarray, r: QACRequest) -> np.ndarray:
        """bool[n]: which candidate docids match r, by the engine's rule —
        every prefix term present and >= 1 term in [lo, hi)."""
        rows = self.fwd[docids]                                   # [n, M]
        keep = ((rows >= r.lo) & (rows < r.hi)).any(axis=1)
        if r.plen:
            pids = r.pids[: r.plen]
            has = (rows[:, None, :] == pids[None, :, None]).any(axis=2)
            keep &= has.all(axis=1)
        return keep

    def _scan_exact(self, r: QACRequest) -> bool:
        """Can an INF-padded engine row for r be trusted as the COMPLETE
        match set? The single-term engine is always exact (the frontend's
        full-budget fallback guarantees it), but ``conjunctive_multi``
        stops scanning its driver list after ``tile * max_tiles`` docids —
        an INF-padded row from a longer scan may be a truncation, not
        exhaustion. The driver is the SHORTEST prefix posting list, whose
        length the host knows, so exactness is provable per request."""
        if r.plen == 0:
            return True
        terms = np.clip(r.pids[: r.plen], 0, len(self._list_lens) - 1)
        return int(self._list_lens[terms].min()) <= self.fe.tile * self.fe.max_tiles

    def _reusable(self, sess: _SessionEntry | None, r: QACRequest) -> bool:
        """Is r's match set provably a subset of the session's stored one —
        AND would r's own engine dispatch have been exact? (See the module
        docstring for the subset argument.) The second condition matters
        because the contract is bit-identity with the engine INCLUDING its
        ``tile * max_tiles`` driver-scan truncation: on a request whose own
        scan would truncate, the host filter would return matches the
        engine misses, so it must fall through to the engine instead."""
        if sess is None or sess.full is None:
            return False
        if sess.gen != self.generation:
            return False   # docids from another generation are meaningless
        if not self._scan_exact(r):
            return False
        new_pids = frozenset(int(t) for t in r.pids[: r.plen])
        if not sess.pid_set <= new_pids:
            return False
        if sess.lo <= r.lo and r.hi <= sess.hi:
            return True
        return any(sess.lo <= t < sess.hi for t in new_pids - sess.pid_set)

    # -- cache/session bookkeeping --------------------------------------------
    def _remember(self, r: QACRequest, row: np.ndarray,
                  full: np.ndarray | None):
        """Insert an answered request into the LRU and the session store.

        ``full`` is the complete ascending match set when the caller knows
        it (filter path / trivial reject); otherwise it is recovered from
        the row iff the row is INF-padded (fewer than k matches == the row
        IS the whole set)."""
        if self.cfg.cache_entries > 0:
            # the generation tag in the key makes stale hits structurally
            # impossible even if a flush were missed; the swap still
            # flushes so dead-generation entries don't occupy LRU slots
            ck = (self.generation, r.key, r.k)
            # private copy: returned rows are caller-owned, so an in-place
            # consumer edit must never reach the cached entry
            self.cache[ck] = row.copy()
            self.cache.move_to_end(ck)
            while len(self.cache) > self.cfg.cache_entries:
                self.cache.popitem(last=False)
        if self.cfg.session_entries > 0:
            if (full is None and bool((row == INF_DOCID).any())
                    and self._scan_exact(r)):
                full = row[row != INF_DOCID]
            self.sessions[r.session] = _SessionEntry(
                pid_set=frozenset(int(t) for t in r.pids[: r.plen]),
                lo=r.lo, hi=r.hi, full=full, gen=self.generation)
            self.sessions.move_to_end(r.session)
            while len(self.sessions) > self.cfg.session_entries:
                self.sessions.popitem(last=False)

    def _finish(self, r: QACRequest, row: np.ndarray, path: str,
                lat_us: float):
        self._results[r.idx] = row
        self.done_t_us[r.idx] = r.t_us + lat_us
        self.done_path[r.idx] = path
        self.done_gen[r.idx] = self.generation
        self.telemetry.record(path, lat_us, gen=self.generation)

    # -- tracing helpers ------------------------------------------------------
    def _trace_hit(self, r: QACRequest, path: str, lat_us: float, **attrs):
        """Root request span + cache-tier child for a request answered at
        arrival (trivial / hit_exact / hit_session). No-op unless the
        request is sampled."""
        tr = self.tracer
        if tr is None or not tr.want(r.idx):
            return
        root = tr.span("request", r.t_us, lat_us, req=r.idx, path=path,
                       session=r.session, k=r.k, gen=self.generation,
                       query=r.query)
        tr.span(f"cache.{path}", r.t_us, lat_us, cat="cache", req=r.idx,
                parent=root, **attrs)

    def _miss_reason(self, r: QACRequest, sess) -> str:
        """Why the session fast path could not serve r (the exact LRU was
        already probed and absent). Computed only for sampled requests."""
        if self.cfg.session_entries <= 0:
            return "session_disabled"
        if sess is None:
            return "no_session_entry"
        if sess.full is None:
            return "truncated_set"
        if sess.gen != self.generation:
            return "stale_generation"
        if not self._scan_exact(r):
            return "scan_inexact"
        new_pids = frozenset(int(t) for t in r.pids[: r.plen])
        if not sess.pid_set <= new_pids:
            return "not_subset"
        return "suffix_widened"

    # -- scheduler ------------------------------------------------------------
    def submit(self, r: QACRequest):
        """One arriving request: serve it from the caches at arrival, or
        queue it for the next micro-batch. Call in arrival-time order."""
        now = r.t_us
        self._advance(now)
        t0 = time.perf_counter()
        if self._is_bad(r):
            row = np.full(r.k, INF_DOCID, np.int32)
            self._remember(r, row, row[:0])
            lat = (time.perf_counter() - t0) * 1e6
            self._finish(r, row, "trivial", lat)
            self._trace_hit(r, "trivial", lat, reason="engine_reject")
            return
        if self.cfg.cache_entries > 0:
            ck = (self.generation, r.key, r.k)
            hit = self.cache.get(ck)
            if hit is not None:
                self.cache.move_to_end(ck)
                self._remember(r, hit, None)
                lat = (time.perf_counter() - t0) * 1e6
                self._finish(r, hit.copy(), "hit_exact", lat)
                self._trace_hit(r, "hit_exact", lat, reason="lru_exact")
                return
        sess = (self.sessions.get(r.session)
                if self.cfg.session_entries > 0 else None)
        if self._reusable(sess, r):
            cand = sess.full
            keep = cand[self._match_rows(cand, r)] if cand.size else cand
            row = np.full(r.k, INF_DOCID, np.int32)
            row[: min(r.k, keep.size)] = keep[: r.k]
            self._remember(r, row, keep)
            lat = (time.perf_counter() - t0) * 1e6
            self._finish(r, row, "hit_session", lat)
            self._trace_hit(r, "hit_session", lat, reason="subset_filter",
                            n_candidates=int(cand.size))
            return
        if self.tracer is not None and self.tracer.want(r.idx):
            self.tracer.instant("cache.miss", now, cat="cache", req=r.idx,
                                reason=self._miss_reason(r, sess))
        r.deadline = now + self.cfg.slack_us
        self.queue.append(r)
        self.telemetry.queue_peak = max(self.telemetry.queue_peak,
                                        len(self.queue))
        while len(self.queue) >= self.cfg.max_batch:
            self._dispatch(max(now, self._server_free), "full")

    def _advance(self, now: float):
        """Fire every deadline-triggered dispatch that happens before
        ``now`` (multiple can queue up behind a busy server)."""
        while self.queue:
            t_ready = max(self.queue[0].deadline, self._server_free)
            if t_ready >= now:
                break
            self._dispatch(t_ready, "deadline")

    def _dispatch(self, t_start: float, trigger: str):
        """Form one micro-batch (oldest-first, only requests that have
        arrived by t_start) and run it through the frontend; the measured
        wall time advances the virtual server clock."""
        batch = []
        while (self.queue and len(batch) < self.cfg.max_batch
               and self.queue[0].t_us <= t_start):
            batch.append(self.queue.popleft())
        # every call site guarantees t_start >= the head's arrival time
        # (deadline = arrival + slack, full-trigger uses now) — a violation
        # would mean serving a request before it arrived
        assert batch, "dispatch scheduled before the queue head's arrival"
        tr = self.tracer
        traced = tr is not None and any(tr.want(r.idx) for r in batch)
        if traced:
            self.fe.begin_dispatch_log()
        t0 = time.perf_counter()
        pids = np.stack([r.pids for r in batch])
        plen = np.asarray([r.plen for r in batch], np.int32)
        suf = np.stack([r.suf for r in batch])
        slen = np.asarray([r.slen for r in batch], np.int32)
        # the frontend's array-k path owns the scalar-vs-bucketed routing
        # (only the default k collapses to a raw scalar dispatch)
        ks = np.asarray([r.k for r in batch], np.int32)
        out = np.asarray(self.fe.complete(pids, plen, suf, slen, k=ks))
        dt_us = (time.perf_counter() - t0) * 1e6
        self._server_free = t_start + dt_us
        if traced:
            dlog = self.fe.end_dispatch_log()
            tr.span("batch.dispatch", t_start, dt_us, cat="batch",
                    size=len(batch), trigger=trigger,
                    jit_keys=[list(key) for key, _ in dlog],
                    routes=sorted({route for _, route in dlog}))
        tel = self.telemetry
        tel.batch_sizes.append(len(batch))
        tel.triggers[trigger] += 1
        tel.engine_wall_us += dt_us
        tel.deadline_violations += sum(t_start > r.deadline for r in batch)
        if self.on_dispatch is not None:
            self.on_dispatch(len(batch), dt_us, t_start)
        for i, r in enumerate(batch):
            row = out[i, : r.k].copy()
            self._remember(r, row, None)
            lat = self._server_free - r.t_us
            self._finish(r, row, "miss", lat)
            if traced and tr.want(r.idx):
                # queue.wait + engine.service == lat EXACTLY (same clock
                # arithmetic) — obs_report rebuilds p99 from this identity
                root = tr.span("request", r.t_us, lat, req=r.idx,
                               path="miss", session=r.session, k=r.k,
                               gen=self.generation, query=r.query)
                tr.span("queue.wait", r.t_us, t_start - r.t_us,
                        cat="queue", req=r.idx, parent=root,
                        trigger=trigger)
                tr.span("engine.service", t_start, dt_us, cat="engine",
                        req=r.idx, parent=root, batch_size=len(batch))

    def tick(self, now: float):
        """Fire any deadline-expired dispatches up to ``now``. Trace replay
        never needs this (``submit`` advances the clock and ``drain`` ends
        the trace), but a LIVE deployment must call it periodically — a
        traffic lull after fewer than ``max_batch`` arrivals would
        otherwise leave queued requests past their deadlines with nothing
        to trigger the dispatch."""
        self._advance(now)

    def drain(self):
        """Dispatch everything still queued (end of trace / shutdown)."""
        while self.queue:
            self._dispatch(max(self.queue[0].deadline, self._server_free),
                           "drain")

    # -- drivers --------------------------------------------------------------
    def run_trace(self, reqs: list[QACRequest]):
        """Replay a timestamped request list -> result rows in trace order
        (row i is int32[reqs[i].k], INF-padded)."""
        last = -np.inf
        for r in reqs:
            if r.t_us < last:
                raise ValueError("trace must be sorted by arrival time")
            last = r.t_us
            self.submit(r)
        self.drain()
        return [self._results[r.idx] for r in reqs]

    def replay(self, reqs: list[QACRequest], *, warm: bool = True):
        """The ONE copy of the measured-replay protocol (launcher, bench,
        and example all call this): pre-compile the trace's jit variants
        (``warmup`` sweep + one full warm pass, which also compiles the
        batch shapes the schedule itself forms), reset runtime state, then
        replay measured. Telemetry afterwards reflects only the measured
        pass."""
        if warm:
            self.warmup(reqs)
            self.run_trace(reqs)
            self.reset()
        return self.run_trace(reqs)

    def warmup(self, reqs: list[QACRequest]):
        """Pre-compile the (engine, bucket, k) jit variants the trace can
        form: class-pure sweeps at every pow2 batch size up to max_batch,
        drawn cyclically from the trace's own requests so the multi-term
        per-bucket list_pad specialization sees realistic term ids. Leaves
        the runtime's own caches untouched."""
        good = [r for r in reqs if not self._is_bad(r)]
        for rs in ([r for r in good if r.plen == 0],
                   [r for r in good if r.plen > 0]):
            if not rs:
                continue
            b = 1
            while b <= max(self.cfg.max_batch, 1):
                take = [rs[i % len(rs)] for i in range(b)]
                self.fe.complete(
                    np.stack([r.pids for r in take]),
                    np.asarray([r.plen for r in take], np.int32),
                    np.stack([r.suf for r in take]),
                    np.asarray([r.slen for r in take], np.int32),
                    k=np.asarray([r.k for r in take], np.int32))
                if b == self.cfg.max_batch:
                    break
                b = min(b * 2, self.cfg.max_batch)


def run_naive_trace(frontend: QACFrontend, reqs: list[QACRequest],
                    *, warm: bool = True):
    """One-request-per-dispatch baseline: every request runs individually
    through ``frontend.complete`` in arrival order under the same
    virtual-clock queueing model — no micro-batching, no caches. This IS
    uncached per-request QACFrontend serving, so its rows double as the
    parity reference for the runtime. Returns (rows, stats dict).

    ``warm`` pre-compiles one dispatch per distinct (class, k, list_pad)
    the trace touches, so reported latencies measure serving, not XLA."""
    if warm:
        seen = set()
        for r in reqs:
            lp = (frontend._multi_list_pad(r.pids[None], np.asarray([r.plen]))
                  if r.plen > 0 else 0)
            sig = (r.plen > 0, r.k, lp)
            if sig in seen:
                continue
            seen.add(sig)
            frontend.complete(r.pids[None], np.asarray([r.plen], np.int32),
                              r.suf[None], np.asarray([r.slen], np.int32),
                              k=r.k)
    server_free = 0.0
    rows, lats = [], []
    for r in reqs:
        t0 = time.perf_counter()
        out = np.asarray(frontend.complete(
            r.pids[None], np.asarray([r.plen], np.int32), r.suf[None],
            np.asarray([r.slen], np.int32), k=r.k))
        dt_us = (time.perf_counter() - t0) * 1e6
        start = max(r.t_us, server_free)
        server_free = start + dt_us
        lats.append(server_free - r.t_us)
        rows.append(out[0, : r.k].copy())
    stats = {"n_requests": len(lats)}
    stats.update(percentiles(lats, (50, 99), mean=True))
    return rows, stats
