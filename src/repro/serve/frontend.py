"""Class-routed batched QAC serving frontend (ISSUE 1 tentpole).

The fused ``qac_serve_step`` pays for BOTH engines on every lane: the
multi-term conjunctive scan and the single-term RMQ heap run for all B
queries and a branchless select throws one result away. The paper (§3.3)
notes single-term queries dominate production traffic, so that waste sits
exactly on the hot path.

This frontend routes on the host instead:

  1. **partition** the incoming batch by query class — single-term
     (``prefix_len == 0``) vs multi-term (``prefix_len > 0``);
  2. **pad** each class sub-batch up to a power-of-two bucket size (cyclic
     replication of real rows, so padding adds no new compile shapes and no
     pathological lanes);
  3. **dispatch** each sub-batch to *only* its engine under a per-
     (engine, bucket, k) jit cache — single-term additionally runs a short
     trip-budget engine with an exact full-budget fallback on the rare
     incomplete lane (see ``single_term_topk_bounded``);
  4. **scatter** results back into request order.

Results are bit-identical to ``qac_serve_step`` (tests/test_serve_frontend.py
checks element-for-element parity, including INF_DOCID padding and
empty-suffix-range queries).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from ..compat import default_use_kernel
from ..core.types import INF_DOCID, MAX_TERMS
from ..core.builder import QACIndex
from .qac import serve_single_term, serve_single_term_full, serve_multi_term

# VMEM ceiling for the intersect kernel's probe-list pad: beyond this the
# [P, L] block would not fit comfortably next to the candidate tile, so the
# frontend falls back to the XLA probe path for the multi-term class.
MAX_LIST_PAD = 1 << 15
# HBM budget for the [B, PMAX, list_pad] probe-list gather the kernel path
# materializes per multi-term dispatch, checked against the PER-BUCKET
# specialized list_pad (PR 3); buckets whose footprint still exceeds it
# fall back to the XLA probe path
MAX_MULTI_KERNEL_BYTES = 256 << 20


def route_classes(prefix_len):
    """Host-side classification: (single_rows, multi_rows) index arrays."""
    plen = np.asarray(prefix_len)
    return np.flatnonzero(plen <= 0), np.flatnonzero(plen > 0)


class QACFrontend:
    """Batched QAC completion with host-side class routing.

    One instance owns a jit cache keyed by (engine, bucket, k, list_pad);
    reuse it across requests so steady-state traffic never recompiles (the
    per-bucket ``list_pad`` adds at most log2(longest-list) variants per
    bucket). ``trips`` is the single-term pop budget (default k + 2); lanes
    that exhaust it fall back to the exact 2k-trip engine for the whole
    sub-batch. ``heap_kernel`` overrides the single-term engine's automatic
    VMEM-fit routing to the fused heap_topk kernel (None = auto).
    """

    def __init__(self, qidx: QACIndex, *, k: int = 10, tile: int = 128,
                 max_tiles: int = 4096, min_bucket: int = 8,
                 trips: int | None = None, use_kernel: bool | None = None,
                 interpret: bool | None = None,
                 heap_kernel: bool | None = None,
                 specialize_list_pad: bool = True,
                 postings_codec: str | None = None,
                 heap_kernel_max_bytes: int | None = None,
                 auditor=None):
        self.qidx = qidx
        self.k = k
        self.tile = tile
        self.max_tiles = max_tiles
        self.min_bucket = min_bucket
        self.trips = trips
        # postings device layout for the kernel routes (ISSUE 7):
        # None/"auto" = raw CSR preferred, compressed when only it fits the
        # heap-kernel VMEM ceiling; "ef"/"bitpack" force in-kernel decode.
        # An explicit codec also switches the multi-term intersect kernel to
        # the compressed probe route, which needs NO probe-list pad bound —
        # the packed index itself is the (static) VMEM footprint.
        self.postings_codec = postings_codec
        self.heap_kernel_max_bytes = heap_kernel_max_bytes
        self._explicit_packed = (
            postings_codec not in (None, "auto", "raw")
            and getattr(qidx.index, "packed", None) is not None)
        # per-bucket list_pad specialization (PR 3) mints one jit variant per
        # pow2 of the longest list a sub-batch probes — the right trade for
        # big offline batches, but ONLINE micro-batches are small and varied,
        # so the variant space stays open and every new pow2 is a compile
        # stall on the serving path. serve/runtime.py constructs frontends
        # with False: every multi-term dispatch uses the global worst-case
        # pad, closing the jit-variant space so steady state never recompiles
        self.specialize_list_pad = specialize_list_pad
        self.use_kernel = (default_use_kernel() if use_kernel is None
                           else use_kernel)
        self.interpret = interpret
        self.heap_kernel = heap_kernel    # None = static VMEM-fit auto-route
        # host-verified probe-list bound for the intersect kernel: the
        # longest posting list in the index, padded to a power of two. Only
        # the frontend can make this check (it routes on the host), which is
        # why the jit-only fused/striped paths keep the XLA probe path.
        # ``list_pad`` is the global worst case; each multi-term dispatch
        # re-derives the bound from the lists its sub-batch actually probes
        # (per-bucket specialization, see ``_multi_list_pad``).
        offs = np.asarray(qidx.index.offsets)
        self._list_lens = (np.diff(offs) if offs.size > 1
                           else np.zeros(1, np.int64))
        max_list = int(self._list_lens.max()) if offs.size > 1 else 1
        self.list_pad = 1 << max(1, (max_list - 1).bit_length())
        self._cache = {}
        self.stats = {"requests": 0, "single_queries": 0, "multi_queries": 0,
                      "single_fallbacks": 0}
        # observability (ISSUE 10): the jit-variant auditor wraps every
        # newly-minted jit callable so its first invocation (where XLA
        # compiles) is timed + recorded, and post-freeze compiles are
        # flagged as closed-variant violations. None = unaudited.
        self.auditor = auditor
        # per-dispatch key/route log: a tracer-enabled runtime brackets its
        # complete() call with begin/end_dispatch_log to learn which jit
        # variants (and therefore which kernel routes) served the batch.
        # None = disabled — the per-_get cost is one attribute check.
        self._dispatch_log = None
        self._route_desc: dict = {}

    def _multi_list_pad(self, pids, plen) -> int:
        """pow2 pad of the longest probe list THIS sub-batch can touch.

        The global ``self.list_pad`` covers the longest list in the whole
        index; most sub-batches only reference far shorter lists, so the
        [B, PMAX, list_pad] probe-list gather (and the kernel's VMEM block)
        shrinks accordingly. Capped at the global bound by construction.
        """
        if not self.specialize_list_pad:
            return self.list_pad
        valid = np.arange(pids.shape[1])[None, :] < plen[:, None]
        terms = np.clip(pids[valid], 0, len(self._list_lens) - 1)
        max_list = int(self._list_lens[terms].max()) if terms.size else 1
        return 1 << max(1, (max(max_list, 1) - 1).bit_length())

    # -- jit cache ------------------------------------------------------------
    def _bucket(self, n: int) -> int:
        return max(self.min_bucket, 1 << (n - 1).bit_length())

    def describe_route(self, engine: str, bucket: int = 0,
                       list_pad: int = 0) -> str:
        """Which kernel route a dispatch on ``engine`` actually takes, as a
        static host-side string ("heap_topk[raw]", "intersect[packed]",
        "xla_probes", ...). Mirrors the routing ladders in
        ``core.search.single_term_topk_bounded_batch`` (via
        ``describe_single_route``) and the multi-term ``use_k`` gate in
        ``_get`` — routing is static per (engine, bucket, list_pad), so
        the answer is cached."""
        ck = (engine, bucket, list_pad)
        desc = self._route_desc.get(ck)
        if desc is None:
            if engine in ("single", "single_full"):
                from ..core.search import describe_single_route

                desc = describe_single_route(
                    self.qidx.index, self.qidx.rmq_minimal,
                    use_kernel=self.use_kernel,
                    heap_kernel=self.heap_kernel,
                    postings_codec=self.postings_codec,
                    heap_kernel_max_bytes=self.heap_kernel_max_bytes)
            elif engine == "multi":
                # keep in sync with the use_k gate in _get below
                if self.use_kernel and self._explicit_packed:
                    desc = "intersect[packed]"
                elif (self.use_kernel and list_pad <= MAX_LIST_PAD
                        and bucket * MAX_TERMS * list_pad * 4
                        <= MAX_MULTI_KERNEL_BYTES):
                    desc = "intersect[raw]"
                else:
                    desc = "xla_probes"
            else:
                desc = engine
            self._route_desc[ck] = desc
        return desc

    def begin_dispatch_log(self):
        """Start recording (jit-key, route) per ``_get`` dispatch; the
        tracer-enabled runtime brackets each ``complete()`` call with
        begin/end to attribute kernel routes to batch spans."""
        self._dispatch_log = []

    def end_dispatch_log(self) -> list:
        log, self._dispatch_log = self._dispatch_log or [], None
        return log

    def _get(self, engine: str, bucket: int, k: int, list_pad: int = 0):
        key = (engine, bucket, k, list_pad)
        if self._dispatch_log is not None:
            self._dispatch_log.append(
                (key, self.describe_route(engine, bucket, list_pad)))
        fn = self._cache.get(key)
        if fn is None:
            if engine == "single":
                def _single(suf, slen):
                    out, done = serve_single_term(
                        self.qidx, suf, slen, k=k, trips=self.trips,
                        use_kernel=self.use_kernel, interpret=self.interpret,
                        heap_kernel=self.heap_kernel,
                        postings_codec=self.postings_codec,
                        heap_kernel_max_bytes=self.heap_kernel_max_bytes)
                    return out, jnp.all(done)   # scalar: one tiny host sync

                fn = jax.jit(_single)
            elif engine == "single_full":
                fn = jax.jit(lambda suf, slen: serve_single_term_full(
                    self.qidx, suf, slen, k=k, use_kernel=self.use_kernel,
                    interpret=self.interpret, heap_kernel=self.heap_kernel,
                    postings_codec=self.postings_codec,
                    heap_kernel_max_bytes=self.heap_kernel_max_bytes))
            elif engine == "multi":
                # the compressed probe route replaces the [B, P, L] gather
                # with the whole packed index, so the list_pad/HBM gates
                # don't apply to it
                use_k = self.use_kernel and (
                    self._explicit_packed
                    or (list_pad <= MAX_LIST_PAD
                        and bucket * MAX_TERMS * list_pad * 4
                        <= MAX_MULTI_KERNEL_BYTES))
                fn = jax.jit(lambda pids, plen, suf, slen: serve_multi_term(
                    self.qidx, pids, plen, suf, slen, k=k, tile=self.tile,
                    max_tiles=self.max_tiles, use_kernel=use_k,
                    interpret=self.interpret, list_pad=list_pad,
                    probe_iters=list_pad.bit_length(),
                    postings_codec=self.postings_codec))
            else:
                raise ValueError(engine)
            if self.auditor is not None:
                fn = self.auditor.wrap(
                    key, fn, label=self.describe_route(engine, bucket,
                                                       list_pad))
            self._cache[key] = fn
        return fn

    def _k_bucket(self, ki: int) -> int:
        """jit-cache k snap (ISSUE 4 satellite). The frontend's default k
        stays exact — the common case never pays a bigger trip budget — and
        every other requested k rounds up to a power of two, so the long
        tail of large-k requests shares a handful of jit variants instead
        of minting one per distinct k. ``_complete_per_k`` groups rows by
        this bucket before dispatch, so a k=100 straggler no longer drags
        the whole batch's single-term trip budget up with it."""
        ki = int(ki)
        if ki == self.k:
            return ki
        return 1 << max(0, (ki - 1).bit_length())

    # -- serving --------------------------------------------------------------
    def _run_single(self, bucket: int, k: int, suf, slen):
        res, all_done = self._get("single", bucket, k)(suf, slen)
        if not bool(all_done):
            # a lane needed more than `trips` pops (duplicate-docid run):
            # recompute the sub-batch with the exact full-budget engine
            self.stats["single_fallbacks"] += 1
            res = self._get("single_full", bucket, k)(suf, slen)
        return np.asarray(res)

    def complete(self, prefix_ids, prefix_len, suffix_chars, suffix_len, *,
                 k: int | np.ndarray | None = None):
        """Routed batched Complete(): -> host docids int32[B, K] (INF padded),
        in the original request order.

        ``k`` may be a scalar (K = k, the classic contract) or a per-request
        int array (ISSUE 4 satellite): K = max(k), row i holds its exact
        k[i]-result in columns [0, k[i]) and INF_DOCID beyond — bit-identical
        to a scalar call at k[i], because the engines' top-k is prefix-stable
        (the first j results of a k-result are the j-result for j <= k).
        Rows are grouped by ``_k_bucket`` so tail ks share jit variants and
        never inflate the default-k trip budget.

        Inputs may be device or host arrays. The result lives on the host (the
        scatter-back is a host op and serving consumers read results there);
        wrap in ``jnp.asarray`` if device residency is needed.
        """
        k = self.k if k is None else k
        karr = np.asarray(k)
        if karr.ndim:
            karr = karr.astype(np.int64).reshape(-1)
            if karr.size == 0:
                return np.full((0, 0), INF_DOCID, np.int32)
            # collapse to the scalar path only for the frontend's default k:
            # a uniform TAIL k must still go through the bucketed path, or
            # every distinct uniform k would mint its own raw jit variant —
            # reopening the variant space the buckets exist to close
            if bool((karr == self.k).all()):
                return self._complete_scalar(prefix_ids, prefix_len,
                                             suffix_chars, suffix_len,
                                             self.k)
            return self._complete_per_k(prefix_ids, prefix_len, suffix_chars,
                                        suffix_len, karr)
        return self._complete_scalar(prefix_ids, prefix_len, suffix_chars,
                                     suffix_len, int(karr))

    def _complete_per_k(self, prefix_ids, prefix_len, suffix_chars,
                        suffix_len, karr):
        """Mixed-k batch: dispatch each pow2 k-bucket's rows separately."""
        pids = np.asarray(prefix_ids)
        plen = np.asarray(prefix_len)
        suf = np.asarray(suffix_chars)
        slen = np.asarray(suffix_len)
        B = plen.shape[0]
        kmax = int(karr.max())
        out = np.full((B, kmax), INF_DOCID, np.int32)
        buckets = np.asarray([self._k_bucket(ki) for ki in karr])
        for kb in np.unique(buckets):
            idx = np.flatnonzero(buckets == kb)
            sub = np.asarray(self._complete_scalar(
                pids[idx], plen[idx], suf[idx], slen[idx], int(kb)))
            w = min(int(kb), kmax)
            cols = np.arange(w)
            out[idx[:, None], cols[None, :]] = np.where(
                cols[None, :] < karr[idx][:, None], sub[:, :w], INF_DOCID)
        return out

    def _complete_scalar(self, prefix_ids, prefix_len, suffix_chars,
                         suffix_len, k: int):
        plen = np.asarray(prefix_len)
        B = plen.shape[0]
        single_rows, multi_rows = route_classes(plen)
        self.stats["requests"] += 1
        self.stats["single_queries"] += int(single_rows.size)
        self.stats["multi_queries"] += int(multi_rows.size)

        # class-pure batch already at a bucket size: dispatch inputs as-is,
        # no padding copies (the common production case of a class-batched
        # upstream queue). The multi path still reads prefix_ids on the host
        # for the per-bucket list_pad — free when the caller passes
        # parse_queries' numpy output, a device sync otherwise
        if single_rows.size == B and self._bucket(B) == B:
            return self._run_single(B, k, suffix_chars, suffix_len)
        if multi_rows.size == B and self._bucket(B) == B:
            lp = self._multi_list_pad(np.asarray(prefix_ids), plen)
            return np.asarray(self._get("multi", B, k, lp)(
                prefix_ids, plen, suffix_chars, suffix_len))

        pids = np.asarray(prefix_ids)
        suf = np.asarray(suffix_chars)
        slen = np.asarray(suffix_len)
        out = np.full((B, k), INF_DOCID, np.int32)

        if single_rows.size:
            pad = np.resize(single_rows, self._bucket(single_rows.size))
            res = self._run_single(len(pad), k, suf[pad], slen[pad])
            out[single_rows] = res[: single_rows.size]

        if multi_rows.size:
            pad = np.resize(multi_rows, self._bucket(multi_rows.size))
            lp = self._multi_list_pad(pids[pad], plen[pad])
            res = self._get("multi", len(pad), k, lp)(
                pids[pad], plen[pad], suf[pad], slen[pad])
            out[multi_rows] = np.asarray(res)[: multi_rows.size]

        return out
