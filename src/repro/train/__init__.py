from .steps import (  # noqa: F401
    TrainState, make_lm_train_step, make_gnn_train_step, make_recsys_train_step,
    init_train_state,
)
