"""Train-step factories for every model family.

Every step is a pure (state, batch) -> (state, metrics) function suitable for
``jax.jit(..., donate_argnums=0)`` under pjit. Features:
  * microbatch gradient accumulation via ``lax.scan`` (overlaps each
    microbatch's reduce with the next one's compute under XLA latency hiding);
  * optional int8+error-feedback gradient compression on the cross-pod axis
    (shard_map psum; DESIGN.md §6);
  * ZeRO-1: the caller shards ``state.opt`` over the data axis via
    ``distributed.zero1_shardings``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import shard_map

from ..optim.adamw import AdamWConfig, init_opt_state, adamw_update
from ..distributed.compression import psum_compressed, init_ef
from ..distributed.sharding import get_mesh
from ..core.types import pytree_dataclass


@pytree_dataclass(meta_fields=())
class TrainState:
    params: Any
    opt: Any
    ef: Any          # error-feedback buffers (None-like empty dict if unused)


def init_train_state(params, *, compress: bool = False) -> TrainState:
    return TrainState(
        params=params,
        opt=init_opt_state(params),
        ef=init_ef(params) if compress else {},
    )


def _accumulate_grads(loss_fn, params, batch, microbatches: int):
    """lax.scan over microbatch slices; returns (mean_loss, mean_grads)."""
    if microbatches <= 1:
        l, g = jax.value_and_grad(loss_fn)(params, batch)
        return l, g

    def reshape(x):
        return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])

    mb = jax.tree_util.tree_map(reshape, batch)

    def body(carry, mslice):
        acc_l, acc_g = carry
        l, g = jax.value_and_grad(loss_fn)(params, mslice)
        acc_g = jax.tree_util.tree_map(jnp.add, acc_g, g)
        return (acc_l + l, acc_g), None

    zero_g = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (tl, tg), _ = lax.scan(body, (jnp.float32(0.0), zero_g), mb)
    inv = 1.0 / microbatches
    return tl * inv, jax.tree_util.tree_map(lambda g: g * inv, tg)


def _maybe_compress_pod(grads, ef, mesh):
    """int8 psum over the 'pod' axis inside shard_map (grads are summed over
    data by autodiff already when params are replicated; the pod axis is the
    expensive DCN hop)."""
    if mesh is None or "pod" not in mesh.axis_names or mesh.shape["pod"] <= 1:
        return grads, ef

    other = tuple(a for a in mesh.axis_names if a != "pod")

    def comp(g, e):
        def f(g_, e_):
            out, ne = psum_compressed(g_ / mesh.shape["pod"], "pod", e_)
            return out, ne
        return shard_map(
            f, mesh=mesh,
            in_specs=(P(), P()), out_specs=(P(), P()),
            check_vma=False,
        )(g, e)

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(ef)
    outs = [comp(g, e) for g, e in zip(flat_g, flat_e)]
    grads = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    ef = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return grads, ef


def _make_step(loss_fn: Callable, opt_cfg: AdamWConfig, *,
               microbatches: int = 1, compress_pod: bool = False):
    def train_step(state: TrainState, batch):
        loss, grads = _accumulate_grads(loss_fn, state.params, batch, microbatches)
        ef = state.ef
        if compress_pod:
            grads, ef = _maybe_compress_pod(grads, ef, get_mesh())
        params, opt, metrics = adamw_update(opt_cfg, state.params, grads, state.opt)
        metrics["loss"] = loss
        return TrainState(params=params, opt=opt, ef=ef), metrics

    return train_step


# -- family-specific wrappers -------------------------------------------------
def make_lm_train_step(model, opt_cfg: AdamWConfig, *, microbatches: int = 1,
                       compress_pod: bool = False):
    def loss_fn(params, batch):
        return model.loss_fn(params, batch["tokens"], batch["targets"],
                             batch["mask"])

    return _make_step(loss_fn, opt_cfg, microbatches=microbatches,
                      compress_pod=compress_pod)


def make_gnn_train_step(model, opt_cfg: AdamWConfig, *, task: str = "energy",
                        n_graphs: int = 1, compress_pod: bool = False):
    from ..models.mace import GraphBatch

    def loss_fn(params, batch):
        gb = GraphBatch(
            positions=batch["positions"], node_feat=batch["node_feat"],
            node_mask=batch["node_mask"], senders=batch["senders"],
            receivers=batch["receivers"], edge_mask=batch["edge_mask"],
            graph_ids=batch["graph_ids"], n_graphs=n_graphs,
        )
        if task == "energy":
            return model.energy_force_loss(params, gb, batch["targets"])
        return model.node_class_loss(params, gb, batch["labels"],
                                     batch["label_mask"])

    return _make_step(loss_fn, opt_cfg, compress_pod=compress_pod)


def make_recsys_train_step(model, opt_cfg: AdamWConfig, *,
                           microbatches: int = 1, compress_pod: bool = False):
    from ..models.recsys import bce_loss

    def loss_fn(params, batch):
        logits = model.forward(params, batch["feats"])
        return bce_loss(logits, batch["labels"])

    return _make_step(loss_fn, opt_cfg, microbatches=microbatches,
                      compress_pod=compress_pod)


def make_fm_sparse_train_step(model, opt_cfg: AdamWConfig):
    """FM train step with lazy sparse-row table updates (§Perf iteration:
    dense AdamW moves 34x table bytes per step; this moves ~12x touched-rows
    bytes — see optim/sparse_adam.py). Dense params (bias) update densely."""
    from ..models.recsys import bce_loss
    from ..optim.sparse_adam import sparse_table_update
    from ..kernels.fm_pairwise import fm_pairwise
    from ..optim.adamw import cosine_lr

    cfg = model.cfg
    V, D, F = cfg.field_vocab, cfg.embed_dim, cfg.n_sparse

    def train_step(state: TrainState, batch):
        params = state.params
        ids = batch["feats"]["sparse_ids"]               # [B, F]
        labels = batch["labels"]
        f_idx = jnp.arange(F)

        def loss_fn(emb_rows, lin_rows, bias):
            pair = fm_pairwise(emb_rows, use_kernel=cfg.use_kernel)
            lin = lin_rows[..., 0].sum(-1)
            return bce_loss(bias + lin + pair, labels)

        emb_rows = params["tables"][f_idx[None, :], ids]     # [B, F, D]
        lin_rows = params["linear"][f_idx[None, :], ids]     # [B, F, 1]
        loss, (g_emb, g_lin, g_bias) = jax.value_and_grad(
            loss_fn, argnums=(0, 1, 2))(emb_rows, lin_rows, params["bias"])

        step = state.opt["step"] + 1
        flat_ids = (f_idx[None, :] * V + ids).reshape(-1)
        t2, mu_t, nu_t = sparse_table_update(
            opt_cfg, params["tables"].reshape(F * V, D),
            g_emb.reshape(-1, D), flat_ids,
            state.opt["mu"]["tables"].reshape(F * V, D),
            state.opt["nu"]["tables"].reshape(F * V, D), step)
        l2, mu_l, nu_l = sparse_table_update(
            opt_cfg, params["linear"].reshape(F * V, 1),
            g_lin.reshape(-1, 1), flat_ids,
            state.opt["mu"]["linear"].reshape(F * V, 1),
            state.opt["nu"]["linear"].reshape(F * V, 1), step)
        # dense bias: inline Adam
        t = step.astype(jnp.float32)
        mu_b = opt_cfg.b1 * state.opt["mu"]["bias"] + (1 - opt_cfg.b1) * g_bias
        nu_b = opt_cfg.b2 * state.opt["nu"]["bias"] + (1 - opt_cfg.b2) * g_bias**2
        upd = (mu_b / (1 - opt_cfg.b1**t)) / (
            jnp.sqrt(nu_b / (1 - opt_cfg.b2**t)) + opt_cfg.eps)
        bias = params["bias"] - cosine_lr(opt_cfg, step) * upd

        new_params = {"tables": t2.reshape(F, V, D),
                      "linear": l2.reshape(F, V, 1), "bias": bias}
        new_opt = {
            "mu": {"tables": mu_t.reshape(F, V, D),
                   "linear": mu_l.reshape(F, V, 1), "bias": mu_b},
            "nu": {"tables": nu_t.reshape(F, V, D),
                   "linear": nu_l.reshape(F, V, 1), "bias": nu_b},
            "step": step,
        }
        metrics = {"loss": loss, "lr": cosine_lr(opt_cfg, step),
                   "grad_norm": jnp.sqrt((g_emb**2).sum() + (g_lin**2).sum()
                                         + g_bias**2)}
        return TrainState(params=new_params, opt=new_opt, ef=state.ef), metrics

    return train_step
