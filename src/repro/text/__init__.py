from .synth import (  # noqa: F401
    SynthLogConfig,
    generate_query_log,
    KeystrokeTraceConfig,
    generate_keystroke_trace,
    make_eval_queries,
)
