from .synth import SynthLogConfig, generate_query_log, make_eval_queries  # noqa: F401
