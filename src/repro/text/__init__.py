from .synth import (  # noqa: F401
    SynthLogConfig,
    generate_query_log,
    KeystrokeTraceConfig,
    generate_keystroke_trace,
    MutationEvent,
    MutationTraceConfig,
    generate_mutation_trace,
    make_eval_queries,
)
