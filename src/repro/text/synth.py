"""Synthetic scored query logs with AOL/MSN/EBAY-like statistics (Table 2).

AOL/MSN are not redistributable in this offline container and the EBAY log is
proprietary, so benchmarks run on generated logs whose shape matches Table 2:
Zipf-distributed term reuse, ~3 terms/query, configurable unique-term count
and term length. Scores are Zipf frequencies (paper: frequency counts).
"""
from __future__ import annotations

import dataclasses

import numpy as np

_ALPHA = np.frombuffer(b"abcdefghijklmnopqrstuvwxyz", dtype=np.uint8)


@dataclasses.dataclass
class SynthLogConfig:
    n_queries: int = 20_000
    vocab_size: int = 4_000
    zipf_s: float = 1.07            # term-draw skew (web-like)
    mean_terms: float = 3.0         # paper Table 2: ~3 terms/query
    mean_term_chars: float = 7.0    # EBAY-like short terms
    max_terms: int = 7
    seed: int = 0


def _make_vocab(rng: np.random.Generator, cfg: SynthLogConfig) -> list[str]:
    vocab = set()
    while len(vocab) < cfg.vocab_size:
        n = cfg.vocab_size - len(vocab)
        lens = np.clip(rng.poisson(cfg.mean_term_chars, n), 2, 16)
        for L in lens:
            chars = _ALPHA[rng.integers(0, 26, int(L))]
            vocab.add(bytes(chars).decode())
    return sorted(vocab)


def generate_query_log(cfg: SynthLogConfig = SynthLogConfig()):
    """-> (queries list[str], scores float64[N]); duplicates possible (scores
    are frequency-like, duplicates are merged by the builder with max score)."""
    rng = np.random.default_rng(cfg.seed)
    vocab = _make_vocab(rng, cfg)
    V = len(vocab)
    # Zipf ranks over a shuffled vocab so lexicographic and popularity order differ
    perm = rng.permutation(V)
    probs = 1.0 / np.arange(1, V + 1) ** cfg.zipf_s
    probs /= probs.sum()
    n_terms = np.clip(rng.poisson(cfg.mean_terms - 1, cfg.n_queries) + 1, 1, cfg.max_terms)
    queries = []
    for nt in n_terms:
        idx = perm[rng.choice(V, size=int(nt), p=probs)]
        queries.append(" ".join(vocab[i] for i in idx))
    # frequency-style scores: Zipf over query popularity ranks
    scores = rng.zipf(1.2, size=cfg.n_queries).astype(np.float64)
    return queries, scores


def make_eval_queries(kept: list[str], rng: np.random.Generator,
                      n_per_bucket: int, retain_pct: int):
    """Paper §4 methodology: sample completions per term-count bucket, keep
    ``retain_pct``% of the final token's characters (0% keeps 1 char).

    Returns dict: n_terms -> list of partial query strings.
    """
    by_terms: dict[int, list[str]] = {}
    for q in kept:
        by_terms.setdefault(len(q.split()), []).append(q)
    out = {}
    for d, qs in sorted(by_terms.items()):
        take = min(n_per_bucket, len(qs))
        sel = rng.choice(len(qs), size=take, replace=False)
        bucket = []
        for i in sel:
            toks = qs[i].split()
            last = toks[-1]
            keep = max(1, int(len(last) * retain_pct / 100))
            bucket.append(" ".join(toks[:-1] + [last[:keep]]))
        out[d] = bucket
    return out
