"""Synthetic scored query logs with AOL/MSN/EBAY-like statistics (Table 2).

AOL/MSN are not redistributable in this offline container and the EBAY log is
proprietary, so benchmarks run on generated logs whose shape matches Table 2:
Zipf-distributed term reuse, ~3 terms/query, configurable unique-term count
and term length. Scores are Zipf frequencies (paper: frequency counts).
"""
from __future__ import annotations

import dataclasses

import numpy as np

_ALPHA = np.frombuffer(b"abcdefghijklmnopqrstuvwxyz", dtype=np.uint8)


@dataclasses.dataclass
class SynthLogConfig:
    n_queries: int = 20_000
    vocab_size: int = 4_000
    zipf_s: float = 1.07            # term-draw skew (web-like)
    mean_terms: float = 3.0         # paper Table 2: ~3 terms/query
    mean_term_chars: float = 7.0    # EBAY-like short terms
    max_terms: int = 7
    seed: int = 0


def _make_vocab(rng: np.random.Generator, cfg: SynthLogConfig) -> list[str]:
    vocab = set()
    while len(vocab) < cfg.vocab_size:
        n = cfg.vocab_size - len(vocab)
        lens = np.clip(rng.poisson(cfg.mean_term_chars, n), 2, 16)
        for L in lens:
            chars = _ALPHA[rng.integers(0, 26, int(L))]
            vocab.add(bytes(chars).decode())
    return sorted(vocab)


def generate_query_log(cfg: SynthLogConfig = SynthLogConfig()):
    """-> (queries list[str], scores float64[N]); duplicates possible (scores
    are frequency-like, duplicates are merged by the builder with max score)."""
    rng = np.random.default_rng(cfg.seed)
    vocab = _make_vocab(rng, cfg)
    V = len(vocab)
    # Zipf ranks over a shuffled vocab so lexicographic and popularity order differ
    perm = rng.permutation(V)
    probs = 1.0 / np.arange(1, V + 1) ** cfg.zipf_s
    probs /= probs.sum()
    n_terms = np.clip(rng.poisson(cfg.mean_terms - 1, cfg.n_queries) + 1, 1, cfg.max_terms)
    queries = []
    for nt in n_terms:
        idx = perm[rng.choice(V, size=int(nt), p=probs)]
        queries.append(" ".join(vocab[i] for i in idx))
    # frequency-style scores: Zipf over query popularity ranks
    scores = rng.zipf(1.2, size=cfg.n_queries).astype(np.float64)
    return queries, scores


@dataclasses.dataclass
class KeystrokeTraceConfig:
    """Synthetic online QAC traffic: concurrent sessions typing queries
    keystroke by keystroke (the AmazonQAC-documented shape of real traffic —
    each request extends the previous prefix by one character, with
    occasional backspace runs)."""

    n_sessions: int = 64
    queries_per_session: int = 1
    mean_keystroke_ms: float = 150.0    # exponential inter-keystroke gap
    session_spread_ms: float = 2000.0   # session start times ~ U[0, spread)
    p_backspace: float = 0.06           # per-keystroke chance of a delete run
    max_backspace: int = 3
    popularity_zipf_s: float = 1.05     # target-query popularity skew
    seed: int = 0
    # open-loop offered load (ISSUE 8): when set, the whole trace's time
    # axis is rescaled so the emitted request rate equals ``target_qps``
    # regardless of how the generated trace was served — arrivals never
    # wait for completions, the definition of an open-loop saturation
    # sweep. Scaling time (rather than resampling sessions) keeps the
    # REQUEST SET identical across offered loads, so a QPS sweep compares
    # the same work at different arrival pressure; crank ``n_sessions``
    # too when the workload should also be *wider* (more concurrent
    # session caches), not just faster. Seeded-deterministic: the rescale
    # is a pure function of the base trace.
    target_qps: float | None = None


def generate_keystroke_trace(queries: list[str],
                             cfg: KeystrokeTraceConfig = KeystrokeTraceConfig()):
    """-> list[(t_us float, session_id int, partial_query str)], time-sorted.

    Each session draws Zipf-popular target queries from ``queries`` and
    emits every prefix on its way to typing them (including prefixes ending
    in a space — a complete term + empty suffix is a valid QAC request).
    Backspace runs re-emit the shorter prefixes, the backtracking pattern a
    prefix cache must survive. Inter-arrival gaps are exponential (Poisson
    keystrokes per session); session starts are staggered so ~all sessions
    overlap — the concurrent-session count IS ``n_sessions``.
    """
    rng = np.random.default_rng(cfg.seed)
    pool = list(queries)
    perm = rng.permutation(len(pool))
    # bounded Zipf over popularity ranks (NOT rng.zipf, whose unbounded tail
    # would clamp a majority of draws onto the single last rank)
    probs = 1.0 / np.arange(1, len(pool) + 1) ** cfg.popularity_zipf_s
    probs /= probs.sum()
    events = []
    for s in range(cfg.n_sessions):
        t = rng.uniform(0.0, cfg.session_spread_ms) * 1e3
        for _ in range(cfg.queries_per_session):
            target = pool[perm[rng.choice(len(pool), p=probs)]]
            n = 1
            while n <= len(target):
                t += rng.exponential(cfg.mean_keystroke_ms) * 1e3
                events.append((t, s, target[:n]))
                if (1 < n < len(target) and rng.random() < cfg.p_backspace):
                    for _ in range(int(rng.integers(1, cfg.max_backspace + 1))):
                        if n <= 1:
                            break
                        n -= 1
                        t += rng.exponential(cfg.mean_keystroke_ms / 2) * 1e3
                        events.append((t, s, target[:n]))
                n += 1
            t += rng.exponential(5 * cfg.mean_keystroke_ms) * 1e3  # dwell
    events.sort(key=lambda e: (e[0], e[1]))
    if cfg.target_qps is not None and len(events) > 1:
        if cfg.target_qps <= 0:
            raise ValueError(f"target_qps must be positive, "
                             f"got {cfg.target_qps}")
        t0, t1 = events[0][0], events[-1][0]
        if t1 > t0:
            # offered QPS of the base trace over its span; scale every
            # timestamp (session starts, keystroke gaps, backspace runs,
            # dwells alike) so the span carries target_qps requests/sec
            base_qps = (len(events) - 1) / (t1 - t0) * 1e6
            scale = base_qps / cfg.target_qps
            events = [((t - t0) * scale, s, q) for t, s, q in events]
    return events


@dataclasses.dataclass
class MutationEvent:
    """One event of a live-index trace. ``kind`` is ``"request"`` (a
    keystroke; ``session`` >= 0, ``score`` unused), ``"insert"`` (a newly
    observed completion enters the corpus) or ``"trend"`` (an existing
    tail completion's score spikes past its old value). Mutations carry
    ``session == -1`` — they come from the ingestion pipeline, not a
    typist."""

    t_us: float
    kind: str
    session: int
    query: str
    score: float = 0.0


@dataclasses.dataclass
class MutationTraceConfig:
    """Keystroke traffic interleaved with live corpus mutations (ISSUE 9).

    The request stream is exactly ``generate_keystroke_trace(queries,
    keystrokes)``; on top, ``max(1, round(mutation_rate * n_requests))``
    mutation events (or exactly ``n_mutations`` when set) land at uniform
    times over the trace span. A ``trend_fraction`` of them are score
    spikes on the bottom ``tail_fraction`` of the score-ranked pool (old
    score x ``trend_boost``, a strict raise — the AmazonQAC popularity
    drift); the rest are inserts of NEW completions recombining pool
    tokens (in-vocabulary, so they become visible immediately;
    ``p_oov_term`` of them instead mint an unseen term, exercising the
    deferred-to-rebuild path). ``follower_sessions`` extra sessions then
    type prefixes of mutated queries AFTER their mutation lands, so a
    correct delta tier must show up in the answers."""

    keystrokes: KeystrokeTraceConfig = dataclasses.field(
        default_factory=KeystrokeTraceConfig)
    mutation_rate: float = 0.02       # mutations per request
    n_mutations: int | None = None    # exact override (launcher knob)
    trend_fraction: float = 0.5       # of mutations that are score spikes
    tail_fraction: float = 0.5        # trend targets: bottom half by score
    trend_boost: float = 4.0          # new score = old_max * boost
    p_oov_term: float = 0.1           # inserts minting an unseen term
    follower_sessions: int = 8        # sessions typing mutated queries
    seed: int = 0

    def __post_init__(self):
        if self.mutation_rate < 0:
            raise ValueError(f"mutation_rate must be >= 0, "
                             f"got {self.mutation_rate}")
        if self.n_mutations is not None and self.n_mutations < 0:
            raise ValueError(f"n_mutations must be >= 0, "
                             f"got {self.n_mutations}")
        for name in ("trend_fraction", "tail_fraction", "p_oov_term"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if self.trend_boost <= 1.0:
            raise ValueError(f"trend_boost must be > 1 (a strict raise), "
                             f"got {self.trend_boost}")
        if self.follower_sessions < 0:
            raise ValueError(f"follower_sessions must be >= 0, "
                             f"got {self.follower_sessions}")


def generate_mutation_trace(queries: list[str], scores,
                            cfg: MutationTraceConfig = MutationTraceConfig()):
    """-> list[MutationEvent], sorted by (t_us, kind, session).

    Invariants (hypothesis-tested in tests/test_mutation_trace.py):
    timestamps are non-decreasing; the request sub-stream is exactly the
    seeded keystroke trace plus follower sessions whose partials are all
    prefixes of their target; the mutation count is exactly
    ``n_mutations`` if set, else ``max(1, round(mutation_rate * n_base))``
    where n_base counts the base keystroke requests; every trend event
    strictly raises its target's max pool score; follower requests only
    occur after their target's mutation time.
    """
    rng = np.random.default_rng(cfg.seed ^ 0x5EED)
    scores = np.asarray(scores, dtype=np.float64)
    if len(queries) != len(scores):
        raise ValueError(f"{len(queries)} queries vs {len(scores)} scores")
    base = generate_keystroke_trace(queries, cfg.keystrokes)
    n_base = len(base)
    n_mut = (cfg.n_mutations if cfg.n_mutations is not None
             else max(1, round(cfg.mutation_rate * n_base)))
    t0 = base[0][0] if base else 0.0
    t1 = base[-1][0] if base else 1e6
    events = [MutationEvent(t_us=t, kind="request", session=s, query=q)
              for t, s, q in base]
    # max score per query string — trends must strictly beat the pool max,
    # or the delta would (correctly) treat the "spike" as a noop
    best: dict[str, float] = {}
    for q, sc in zip(queries, scores):
        best[q] = max(best.get(q, -np.inf), float(sc))
    order = sorted(best, key=lambda q: (best[q], q))
    tail = order[: max(1, int(len(order) * cfg.tail_fraction))]
    vocab = sorted({t for q in queries for t in q.split()})
    mut_times = np.sort(rng.uniform(t0, t1, size=n_mut))
    mutated: list[tuple[float, str]] = []
    for tm in mut_times:
        if rng.random() < cfg.trend_fraction and tail:
            target = tail[int(rng.integers(0, len(tail)))]
            events.append(MutationEvent(
                t_us=float(tm), kind="trend", session=-1, query=target,
                score=best[target] * cfg.trend_boost))
            best[target] *= cfg.trend_boost
            mutated.append((float(tm), target))
        else:
            # recombine pool tokens into a query unseen in the pool
            for _ in range(64):
                nt = int(rng.integers(1, 4))
                toks = [vocab[int(i)] for i in
                        rng.integers(0, len(vocab), size=nt)]
                if rng.random() < cfg.p_oov_term:
                    # mint an unseen term: deferred-to-rebuild path
                    toks[-1] = "zz" + toks[-1] + "q"
                q = " ".join(toks)
                if q not in best:
                    break
            events.append(MutationEvent(
                t_us=float(tm), kind="insert", session=-1, query=q,
                score=float(np.median(scores)) + 1.0
                if scores.size else 1.0))
            best[q] = events[-1].score
            mutated.append((float(tm), q))
    # follower sessions: type prefixes of mutated queries AFTER the
    # mutation lands — the traffic that makes delta-tier hits observable
    n_follow = min(cfg.follower_sessions, len(mutated))
    base_sessions = cfg.keystrokes.n_sessions
    gap_us = cfg.keystrokes.mean_keystroke_ms * 1e3
    for i in range(n_follow):
        tm, q = mutated[int(rng.integers(0, len(mutated)))]
        t = tm + rng.exponential(gap_us)
        for n in range(1, len(q) + 1):
            t += rng.exponential(gap_us)
            events.append(MutationEvent(
                t_us=float(t), kind="request",
                session=base_sessions + i, query=q[:n]))
    events.sort(key=lambda e: (e.t_us, e.kind, e.session))
    return events


def make_eval_queries(kept: list[str], rng: np.random.Generator,
                      n_per_bucket: int, retain_pct: int):
    """Paper §4 methodology: sample completions per term-count bucket, keep
    ``retain_pct``% of the final token's characters (0% keeps 1 char).

    Returns dict: n_terms -> list of partial query strings.
    """
    by_terms: dict[int, list[str]] = {}
    for q in kept:
        by_terms.setdefault(len(q.split()), []).append(q)
    out = {}
    for d, qs in sorted(by_terms.items()):
        take = min(n_per_bucket, len(qs))
        sel = rng.choice(len(qs), size=take, replace=False)
        bucket = []
        for i in sel:
            toks = qs[i].split()
            last = toks[-1]
            keep = max(1, int(len(last) * retain_pct / 100))
            bucket.append(" ".join(toks[:-1] + [last[:keep]]))
        out[d] = bucket
    return out
