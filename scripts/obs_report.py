"""Render a serving trace (tracing JSONL) into a human-readable report.

  PYTHONPATH=src python scripts/obs_report.py TRACE.jsonl [--check]
      [--slo-target-us 50000] [--slo-objective 0.999] [--waterfall N]

Input is the ``Tracer.to_jsonl`` format produced by
``launch/serve.py --observe --trace-out TRACE.jsonl`` (or any obs-wired
runtime). The report has three parts:

  * a per-stage latency budget table: for every child span name
    (queue.wait, engine.service, cache.*, merge.kway, ...) the count,
    mean, p50 and p99 — where the 50 ms interactive budget actually goes;
  * an ASCII waterfall of the N slowest sampled requests — each child
    span drawn in position inside its root ``request`` span;
  * an SLO summary: the spans replayed through ``SLOMonitor`` (same
    multi-window burn-rate ladder the online monitor runs), worst
    long-window burn + which alert pairs would fire.

``--check`` asserts the trace is self-consistent: every child nests
inside its root, per-request child durations sum to the root (the span
identity queue.wait + engine.service == e2e on the miss path), and the
e2e p99 REBUILT from child-span sums alone matches the root-span p99
within 5% — i.e. the trace alone is enough to reconstruct the latency
story, no telemetry snapshot needed.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.metrics import fmt, percentiles           # noqa: E402
from repro.obs.slo import SLOMonitor                     # noqa: E402
from repro.obs.tracing import load_jsonl, request_trees  # noqa: E402

WATERFALL_COLS = 64


def stage_table(trees: dict) -> list[dict]:
    """Per-stage budget rows aggregated over every sampled request."""
    by_name: dict[str, list[float]] = {}
    for _root, kids in trees.values():
        for c in kids:
            by_name.setdefault(c["name"], []).append(c["dur_us"])
    rows = []
    for name in sorted(by_name):
        durs = by_name[name]
        p = percentiles(durs, (50, 99), mean=True)
        rows.append(dict(name=name, count=len(durs), **p))
    return rows


def print_stage_table(rows: list[dict]) -> None:
    print(f"{'stage':<20} {'count':>6} {'mean':>9} {'p50':>9} {'p99':>9}")
    for r in rows:
        print(f"{r['name']:<20} {r['count']:>6} "
              f"{fmt(r['mean_us'], 1e3, 2, 'ms'):>9} "
              f"{fmt(r['p50_us'], 1e3, 2, 'ms'):>9} "
              f"{fmt(r['p99_us'], 1e3, 2, 'ms'):>9}")


def print_waterfall(root: dict, kids: list[dict]) -> None:
    t0, dur = root["t0_us"], max(root["dur_us"], 1e-9)
    attrs = root.get("attrs", {})
    print(f"request {root.get('req')} "
          f"({attrs.get('query', '?')!r}, path={attrs.get('path', '?')}): "
          f"{fmt(dur, 1e3, 2, 'ms')} e2e")
    for c in sorted(kids, key=lambda c: (c["t0_us"], c["name"])):
        lo = int(round((c["t0_us"] - t0) / dur * WATERFALL_COLS))
        hi = int(round((c["t0_us"] + c["dur_us"] - t0) / dur
                       * WATERFALL_COLS))
        lo = min(max(lo, 0), WATERFALL_COLS)
        hi = min(max(hi, lo + 1), WATERFALL_COLS)
        bar = " " * lo + "#" * (hi - lo) + " " * (WATERFALL_COLS - hi)
        print(f"  {c['name']:<16} |{bar}| {fmt(c['dur_us'], 1e3, 2, 'ms')}")


def check_trace(trees: dict, tol: float = 0.05) -> dict:
    """Span-tree self-consistency: nesting, child-sum identity, and the
    e2e p99 rebuilt from child spans vs measured from root spans."""
    root_lat, child_lat = [], []
    for req, (root, kids) in sorted(trees.items()):
        t0, t1 = root["t0_us"], root["t0_us"] + root["dur_us"]
        for c in kids:
            assert c["t0_us"] >= t0 - 1e-6 and \
                   c["t0_us"] + c["dur_us"] <= t1 + 1e-6, \
                f"req {req}: child {c['name']} escapes its root span"
        root_lat.append(root["dur_us"])
        child_lat.append(sum(c["dur_us"] for c in kids))
    n_exact = sum(1 for a, b in zip(root_lat, child_lat)
                  if abs(a - b) <= 1e-6 * max(a, 1.0))
    p99_root = percentiles(root_lat, (99,))["p99_us"]
    p99_child = percentiles(child_lat, (99,))["p99_us"]
    rel = abs(p99_child - p99_root) / max(p99_root, 1e-9)
    assert rel <= tol, \
        (f"e2e p99 rebuilt from child spans ({p99_child:.0f}us) is "
         f"{rel:.1%} off the root-span p99 ({p99_root:.0f}us), tol {tol:.0%}")
    return dict(n_requests=len(root_lat), n_child_sum_exact=n_exact,
                p99_root_us=p99_root, p99_from_children_us=p99_child,
                rel_err=rel)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="JSONL trace from --observe --trace-out")
    ap.add_argument("--check", action="store_true",
                    help="assert span-tree invariants + p99-from-spans "
                         "within 5%% of root p99")
    ap.add_argument("--waterfall", type=int, default=3, metavar="N",
                    help="draw the N slowest sampled requests (default 3)")
    ap.add_argument("--slo-target-us", type=float, default=50_000.0)
    ap.add_argument("--slo-objective", type=float, default=0.999)
    args = ap.parse_args()

    spans, instants = load_jsonl(args.trace)
    trees = request_trees(spans)
    if not trees:
        print(f"no sampled request spans in {args.trace} "
              f"({len(spans)} spans, {len(instants)} instants)")
        sys.exit(1)
    print(f"# {args.trace}: {len(spans)} spans, {len(instants)} instants, "
          f"{len(trees)} sampled requests\n")

    print("## per-stage latency budget")
    print_stage_table(stage_table(trees))

    slowest = sorted(trees.values(), key=lambda t: -t[0]["dur_us"])
    print(f"\n## slowest sampled requests (top {args.waterfall})")
    for root, kids in slowest[: args.waterfall]:
        print_waterfall(root, kids)

    # SLO replay: each sampled request observed at its completion time
    slo = SLOMonitor(target_us=args.slo_target_us,
                     objective=args.slo_objective)
    for root, _kids in sorted(trees.values(), key=lambda t: t[0]["t0_us"]):
        slo.observe(root["t0_us"] + root["dur_us"], root["dur_us"])
    ev = slo.evaluate()
    print(f"\n## SLO ({args.slo_target_us / 1e3:.0f}ms @ "
          f"{args.slo_objective:.3%})")
    print(f"compliance {ev['compliance']:.4f} over {ev['n_requests']} "
          f"sampled requests ({ev['n_violations']} violations)")
    for a in ev["alerts"]:
        burn = a["long_burn"]
        print(f"  window {a['long_window_us'] / 3.6e9:.2f}h/"
              f"{a['short_window_us'] / 6e7:.0f}m thr {a['threshold']:>5}: "
              f"burn {fmt(burn, 1.0, 2)} "
              f"{'FIRING' if a['firing'] else 'ok'}")
    print(f"overall: {'FIRING' if ev['firing'] else 'within budget'}")

    if args.check:
        res = check_trace(trees)
        print(f"\ncheck OK: {res['n_requests']} request trees, "
              f"{res['n_child_sum_exact']} with exact child-sum identity; "
              f"p99 from child spans {fmt(res['p99_from_children_us'], 1e3, 2, 'ms')} "
              f"vs root {fmt(res['p99_root_us'], 1e3, 2, 'ms')} "
              f"({res['rel_err']:.2%} off)")


if __name__ == "__main__":
    main()
