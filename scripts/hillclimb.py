"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> re-analyse.

Each iteration patches the registered arch definition (opt-in knobs only —
baselines stay untouched on disk), re-runs the dry-run cell, and records
(hypothesis, before, after) to dryrun_results/perf/.

  PYTHONPATH=src python scripts/hillclimb.py [--cell smollm-360m:train_4k] [--multi-pod]
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
import argparse
import dataclasses
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
from repro.configs import get_arch  # noqa: E402
from repro.launch.dryrun import run_cell, RESULTS_DIR  # noqa: E402

PERF_DIR = os.path.join(RESULTS_DIR, "perf")


# ---------------------------------------------------------------- patches
def smollm_seq_parallel(arch):
    """H=15 does not divide the 16-way model axis -> GSPMD falls back to
    uneven head sharding with huge f32 activation gathers. Hypothesis:
    Megatron-SP layout (residual stream + attention sharded on SEQ over
    'model', KV all-gathered per layer, no head TP) removes the uneven
    gathers; per-layer comm becomes ~2 bf16 KV gathers + FFN input gathers.
    Predicted: collective term 2.49s -> ~0.3s."""
    arch.rule_overrides = {"heads": None, "kv_heads": None, "seq": "model"}


def smollm_pure_dp(arch):
    """360M params fit in one chip many times over. Hypothesis: at this scale
    ANY tensor parallelism is a loss; pure DP (params replicated, ZeRO-1
    moments sharded, vocab kept sharded for the 49k logits) leaves only the
    gradient all-reduce (~0.72GB bf16) + moment plumbing.
    Predicted: collective -> <0.2s, cell becomes compute-bound (0.045s)."""
    arch.rule_overrides = {"heads": None, "kv_heads": None, "d_ff": None,
                           "seq": None}


def qwen2moe_pad_experts(arch):
    """60 experts forced per-expert TP (dense scan over 60 experts with
    d_expert sharded -> per-expert weight collectives x24 layers = 52.5s).
    Hypothesis: padding the expert arrays to 64 (4 dead experts = 6.7% wasted
    expert FLOPs) makes EP divide the mesh, so the shard_map path (psum
    combine only) applies. Predicted: collective 52.5s -> ~2-4s."""
    arch.cfg = dataclasses.replace(
        arch.cfg,
        moe=dataclasses.replace(arch.cfg.moe, pad_experts_to=64),
        moe_shard_map=True,
    )
    arch.rule_overrides = {"experts": "model", "expert_ff": None}


def qwen2moe_pad_plus_sp(arch):
    """On top of expert padding: Megatron-SP for the attention/residual
    stream (16 heads divide the mesh, but the f32 activation all-reduces
    remain). Hypothesis: SP converts per-layer f32 all-reduces into bf16
    all-gathers (half the bytes, and XLA can't upcast a gather).
    Predicted: another ~30-40% off the collective term."""
    qwen2moe_pad_experts(arch)
    arch.rule_overrides = {"experts": "model", "expert_ff": None,
                           "heads": None, "kv_heads": None, "seq": "model"}


def qwen2moe_dp_attn_ep_moe(arch):
    """it2 refuted SP (it reshards the token stream around every shard_map
    MoE block, which wants tokens replicated over 'model'). New hypothesis:
    attention/shared-expert in pure DP (their 14GB-bf16 params replicate
    fine), experts in EP — the only per-layer collective left is the MoE
    combine psum ([32k,2048] f32 x 24 layers ~ 19GB) + grad all-reduce.
    Predicted: collective 3.5s -> ~0.8-1.0s."""
    qwen2moe_pad_experts(arch)
    arch.rule_overrides = {"experts": "model", "expert_ff": None,
                           "heads": None, "kv_heads": None, "d_ff": None}


def gemma2_pure_dp(arch):
    """2.6B params = 5.2GB bf16 replicated + ZeRO-1 moments over data
    (1.3GB/dev) still fit. Hypothesis: as for smollm, drop TP entirely;
    collective becomes the bf16 grad all-reduce + moment plumbing.
    Predicted: collective 9.0s -> ~0.3s, frac -> ~0.5."""
    arch.rule_overrides = {"heads": None, "kv_heads": None, "d_ff": None,
                           "seq": None}


def qac_butterfly(arch):
    """The k-merge all-gather moves k*S ints per query; a butterfly
    (XOR-pair ppermute) merge moves k*log2(S). Hypothesis: collective term
    drops ~4x (16 stripes -> 4 rounds); compute/memory unchanged."""
    arch.merge = "butterfly"


def gemma2_seq_parallel(arch):
    """gemma2 has 8 heads / 4 KV heads on a 16-way model axis -> the worst
    uneven-sharding case (104GiB of f32 head gathers per step in the HLO
    audit). Hypothesis: SP layout as for smollm. Predicted: collective
    9.0s -> ~1.0s, making the cell ~compute-bound (0.33s)."""
    arch.rule_overrides = {"heads": None, "kv_heads": None, "seq": "model"}


def qwen3_14b_sp(arch):
    """40 heads don't divide the 16-way model axis (uneven gathers), but 28GB
    of bf16 params rule out pure DP. Hypothesis: keep d_ff/vocab TP
    (17408/151936 divide cleanly), move attention to SP+KV-all-gather (seq
    over 'model'), drop head sharding. Predicted: collective 20.8s -> ~5s."""
    arch.rule_overrides = {"heads": None, "kv_heads": None, "seq": "model"}


def qwen3_14b_fsdp(arch):
    """SP still pays f32 FFN all-reduces (13.1s left). New hypothesis: go
    fully FSDP-DP — batch sharded over BOTH axes (256 = 16x16 exactly, 1
    seq/device), every weight sharded over 'data' on its contraction-free
    dim and all-gathered just-in-time (2x28GB bf16 per step), grads
    reduce-scattered. No activation collectives at all except the tiny CE
    reductions. Predicted: collective -> ~2s, frac -> ~0.5-0.8."""
    arch.rule_overrides = {
        "batch": ("data", "model"), "heads": "data", "kv_heads": "data",
        "d_ff": "data", "seq": None, "d_model": None,
    }


def qwen3_14b_fsdp_mb1(arch):
    """it2's 215GB of gathers = weights re-gathered per microbatch (x2) and
    per remat pass. Hypothesis: with FSDP the optimizer+param memory is
    already sharded, so microbatching is unnecessary — mb=1 halves the
    weight gathers. Predicted: collective 6.9s -> ~4s."""
    qwen3_14b_fsdp(arch)
    arch.train_microbatches = 1


def qwen3moe_mb1(arch):
    """30.6s collective: FSDP expert gathers are paid once per microbatch
    (mb=2) per pass. Hypothesis: mb=1 halves them (memory is already
    FSDP/ZeRO-sharded). Predicted: collective -> ~18s."""
    arch.train_microbatches = 1


def qwen3moe_mb1_bf16psum(arch):
    """On top of mb=1: the EP combine psum moves [32k,4096] f32 per layer
    x94. Hypothesis: bf16 psum halves those bytes with acceptable precision
    (sum of <=16 partials, magnitudes gate-weighted <=1).
    Predicted: another ~2-3s off."""
    arch.train_microbatches = 1
    arch.cfg = dataclasses.replace(arch.cfg, moe_psum_bf16=True)


def qwen3moe_kv_replicated(arch):
    """HLO audit: 188GB of f32[256,4,1024,128] gathers — kv_heads=4 sharded
    over the 16-way model axis is uneven (the gemma2 disease). Hypothesis:
    replicate kv projections (tiny: 4 heads) while q stays TP; removes the
    uneven gathers (~300GB with related kv entries).
    Predicted: collective 28.2s -> ~21s."""
    arch.train_microbatches = 1
    arch.rule_overrides = {"expert_ff": "data", "kv_heads": None}


def fm_sparse_rows(arch):
    """Dense AdamW reads+writes all 39M table rows every step (34x table
    bytes = 53GB of HBM traffic; the recsys-train memory term). Hypothesis:
    lazy sparse-row AdamW (optim/sparse_adam.py — sort+segment-sum dup rows,
    gather/update/scatter <=B*F rows) cuts the memory term ~40x; collective
    term also falls because the dense moment/param update no longer streams
    row-sharded tables through the data axis. Numerics validated exact vs
    dense Adam when every row is touched (tests/test_sparse_adam.py)."""
    arch.sparse_tables = True


ITERATIONS = {
    "smollm-360m:train_4k": [
        ("it1_seq_parallel_attention", smollm_seq_parallel),
        ("it2_pure_dp_zero1", smollm_pure_dp),
    ],
    "qwen3-14b:train_4k": [
        ("it1_seq_parallel_attention", qwen3_14b_sp),
        ("it2_full_fsdp", qwen3_14b_fsdp),
        ("it3_fsdp_no_microbatch", qwen3_14b_fsdp_mb1),
    ],
    "qwen3-moe-235b-a22b:train_4k": [
        ("it1_no_microbatch", qwen3moe_mb1),
        ("it2_mb1_bf16_psum", qwen3moe_mb1_bf16psum),
        ("it3_kv_replicated", qwen3moe_kv_replicated),
    ],
    "qwen2-moe-a2.7b:train_4k": [
        ("it1_pad_experts_64_EP", qwen2moe_pad_experts),
        ("it2_plus_seq_parallel", qwen2moe_pad_plus_sp),
        ("it3_dp_attention_ep_moe", qwen2moe_dp_attn_ep_moe),
    ],
    "fm:train_batch": [
        ("it1_lazy_sparse_rows", fm_sparse_rows),
    ],
    "qac-ebay:serve_bulk": [
        ("it1_butterfly_merge", qac_butterfly),
    ],
    "qac-ebay:serve_online": [
        ("it1_butterfly_merge", qac_butterfly),
    ],
    "gemma2-2b:train_4k": [
        ("it1_seq_parallel_attention", gemma2_seq_parallel),
        ("it2_pure_dp_zero1", gemma2_pure_dp),
    ],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    os.makedirs(PERF_DIR, exist_ok=True)
    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"

    for cell, iters in ITERATIONS.items():
        if args.cell and args.cell != cell:
            continue
        arch_id, shape = cell.split(":")
        base_path = os.path.join(RESULTS_DIR, mesh_name, f"{arch_id}__{shape}.json")
        base = json.load(open(base_path)) if os.path.exists(base_path) else None

        arch = get_arch(arch_id)
        saved = {f.name: getattr(arch, f.name)
                 for f in dataclasses.fields(arch)} if dataclasses.is_dataclass(arch) else None
        extra_attrs = {}
        for name, patch in iters:
            # restore pristine arch then apply this iteration's patch
            if saved:
                for kk, vv in saved.items():
                    setattr(arch, kk, vv)
            for kk in extra_attrs:
                delattr(arch, kk)
            extra_attrs = {}
            before_attrs = set(vars(arch)) if hasattr(arch, "__dict__") else set()
            patch(arch)
            extra_attrs = {kk: None for kk in
                           (set(vars(arch)) - before_attrs)} if hasattr(arch, "__dict__") else {}
            # qac merge knob routes through the lowerable via attribute
            if hasattr(arch, "merge") and arch_id == "qac-ebay":
                _patch_qac_merge(arch)
            print(f"[hillclimb] {cell} {name} ...", flush=True)
            rec = run_cell(arch_id, shape, args.multi_pod, PERF_DIR)
            rec["iteration"] = name
            rec["hypothesis"] = patch.__doc__.strip()
            if base and base.get("ok"):
                rec["before"] = {kk: base.get(kk) for kk in
                                 ("compute_s", "memory_s", "collective_s",
                                  "dominant", "roofline_frac")}
            out = os.path.join(PERF_DIR, f"{arch_id}__{shape}__{name}.json")
            with open(out, "w") as f:
                json.dump(rec, f, indent=1)
            if rec.get("ok"):
                b = rec.get("before", {})
                print(f"[hillclimb]   collective {b.get('collective_s'):.3} -> "
                      f"{rec['collective_s']:.3e}; dominant {rec['dominant']}; "
                      f"frac {rec.get('roofline_frac')}", flush=True)
            else:
                print(f"[hillclimb]   FAIL {rec.get('error', '')[:200]}", flush=True)
        # restore
        if saved:
            for kk, vv in saved.items():
                setattr(arch, kk, vv)


def _patch_qac_merge(arch):
    import functools
    from repro.serve import qac as qac_mod
    orig = arch.lowerable

    def lowerable(shape, mesh):
        low = orig(shape, mesh)
        from repro.configs.qac_common import QAC_SHAPES
        from repro.core.types import MAX_TERMS, MAX_TERM_CHARS
        k = arch.k

        def fn(striped, dictionary, pids, plen, schars, slen):
            return qac_mod.qac_serve_striped(striped, dictionary, pids, plen,
                                             schars, slen, k=k, mesh=mesh,
                                             merge="butterfly")

        return dataclasses.replace(low, fn=fn)

    arch.lowerable = lowerable


if __name__ == "__main__":
    main()
