import sys
sys.path.insert(0, "src")
import numpy as np
import jax
import jax.numpy as jnp
from repro.models.transformer import TransformerConfig, MoESettings, TransformerLM

for name, cfg in {
    "dense": TransformerConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
                               dtype=jnp.float32, param_dtype=jnp.float32),
    "gemma2ish": TransformerConfig(name="g", n_layers=4, d_model=64, n_heads=4,
                                   n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
                                   layer_pattern="local_global", window=8,
                                   post_norms=True, attn_softcap=50.0,
                                   final_softcap=30.0, embed_scale=True,
                                   act="geglu", dtype=jnp.float32,
                                   param_dtype=jnp.float32),
    "moe": TransformerConfig(name="m", n_layers=2, d_model=64, n_heads=4,
                             n_kv_heads=4, head_dim=16, d_ff=128, vocab=256,
                             qk_norm=True, dtype=jnp.float32, param_dtype=jnp.float32,
                             moe=MoESettings(n_experts=8, top_k=2, d_expert=32,
                                             shared_d_ff=64,
                                             capacity_factor=16.0)),
}.items():
    model = TransformerLM(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 256)
    logits, aux, _ = model.forward(params, toks)
    assert logits.shape == (2, 16, 256), logits.shape
    assert np.isfinite(np.asarray(logits)).all()
    loss = model.loss_fn(params, toks, toks, jnp.ones_like(toks))
    g = jax.grad(model.loss_fn)(params, toks, toks, jnp.ones_like(toks))
    gn = jax.tree_util.tree_reduce(lambda a, b: a + float(jnp.sum(b * b)), g, 0.0)
    # decode matches forward (teacher forcing)
    cache = model.init_cache(2, 16)
    outs = []
    for t in range(16):
        lg, cache = model.decode_step(params, cache, toks[:, t])
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec - logits)))
    print(f"{name}: loss={float(loss):.4f} aux={float(aux):.4f} gradnorm2={gn:.3e} decode_err={err:.2e}")
    assert err < 2e-3, err
print("LM OK")
