"""Quick end-to-end smoke of the QAC core on the paper's Table 1 example."""
import numpy as np
import jax.numpy as jnp

import sys
sys.path.insert(0, "src")

from repro.core import (
    build_qac_index, parse_queries, HostIndex,
    prefix_search_topk, conjunctive_multi, single_term_topk, INF_DOCID,
)
from repro.core.builder import build_corpus

# Table 1 corpus: scores chosen so docids match the paper's assignment
queries = [
    "bmw i3 sedan",      # docid 1
    "bmw i3 sportback",  # docid 2
    "audi q8 sedan",     # docid 3
    "bmw i3 sport",      # docid 4
    "bmw x1",            # docid 5
    "audi a3 sport",     # docid 6
    "bmw i8 sport",      # docid 7
    "bmw",               # docid 8
    "audi",              # docid 9
]
scores = [9 - i for i in range(9)]  # descending by listed order

qidx, kept, sc = build_qac_index(queries, scores)
print("terms:", qidx.dictionary.n_terms, "completions:", qidx.completions.n)

# paper example: "bmw i3 s" -> conjunctive results (docids 1,2,4) = 0,1,3 (0-based)
pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, ["bmw i3 s"])
tl, tr = qidx.dictionary.locate_prefix(suf, slen)
print("suffix range (1-based, half-open):", int(tl[0]), int(tr[0]))
res = conjunctive_multi(qidx.index, qidx.completions, pids[0], plen[0], tl[0], tr[0], 3)
print("conjunctive(bmw i3 s):", res, "(expect [0 1 3])")

res_p = prefix_search_topk(qidx.completions, qidx.rmq_docids, pids[0], plen[0], tl[0], tr[0], 3)
print("prefix(bmw i3 s):", res_p, "(expect [0 1 3])")

# paper example: single-term "s" -> top-3 should be docids 1,2,3 (0-based 0,1,2)... compute
pids2, plen2, pok2, suf2, slen2 = parse_queries(qidx.dictionary, ["s"])
tl2, tr2 = qidx.dictionary.locate_prefix(suf2, slen2)
res_s = single_term_topk(qidx.index, qidx.rmq_minimal, tl2[0], tr2[0], 3)
print("single(s):", res_s)

# oracle comparison
rows = np.zeros((9, 8), dtype=np.int32)
dictionary, rows, sc2, kept2 = build_corpus(queries, scores)
order = np.lexsort(tuple(rows[:, j] for j in range(rows.shape[1] - 1, -1, -1)) + (-sc2,))
d_of_row = np.empty(len(rows), dtype=np.int32)
d_of_row[order] = np.arange(len(rows))
host = HostIndex(rows, d_of_row, dictionary.n_terms)
print("oracle conj:", host.fwd_conjunctive([int(x) for x in np.asarray(pids[0]) if x], int(tl[0]), int(tr[0]), 3))
print("oracle single:", host.single_term_rmq(int(tl2[0]), int(tr2[0]), 3))
print("oracle heap:", host.heap_conjunctive([int(x) for x in np.asarray(pids[0]) if x], int(tl[0]), int(tr[0]), 3))
print("OK" if list(map(int, res)) == host.fwd_conjunctive([int(x) for x in np.asarray(pids[0]) if x], int(tl[0]), int(tr[0]), 3) + [INF_DOCID] * 0 else "MISMATCH")
