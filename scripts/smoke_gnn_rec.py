import sys
sys.path.insert(0, "src")
import numpy as np
import jax, jax.numpy as jnp
from repro.models import MACEConfig, MACEModel, GraphBatch, RecsysConfig, FMModel, DINModel, BSTModel, MINDModel, bce_loss
from repro.data.graphs import batch_molecules
from repro.data.recsys_data import recsys_batch

rng = np.random.default_rng(0)
# --- MACE energy+forces on molecules ---
cfg = MACEConfig(d_hidden=32, n_species=8)
model = MACEModel(cfg)
params = model.init_params(jax.random.PRNGKey(0))
pos, species, nmask, s, r, emask, gids = batch_molecules(rng, 4, 10, 24, 8)
batch = GraphBatch(jnp.asarray(pos), jnp.asarray(species), jnp.asarray(nmask),
                   jnp.asarray(s), jnp.asarray(r), jnp.asarray(emask),
                   jnp.asarray(gids), 4)
E = model.forward(params, batch)
print("energies:", np.asarray(E))
assert E.shape == (4,) and np.isfinite(np.asarray(E)).all()
# equivariance: random rotation leaves energies invariant
th = 0.7
R = np.array([[np.cos(th), -np.sin(th), 0], [np.sin(th), np.cos(th), 0], [0, 0, 1]])
import dataclasses
batch_r = dataclasses.replace(batch, positions=jnp.asarray(pos @ R.T))
E2 = model.forward(params, batch_r)
print("rot err:", float(jnp.max(jnp.abs(E - E2))))
assert float(jnp.max(jnp.abs(E - E2))) < 1e-3
loss = model.energy_force_loss(params, batch, jnp.zeros(4), force_targets=jnp.zeros_like(batch.positions))
g = jax.grad(lambda p: model.energy_force_loss(p, batch, jnp.zeros(4)))(params)
print("mace loss:", float(loss))

# --- recsys models ---
for kind, cls in [("fm", FMModel), ("din", DINModel), ("bst", BSTModel), ("mind", MINDModel)]:
    c = RecsysConfig(name=kind, kind=kind, embed_dim=16, n_sparse=8, field_vocab=1000,
                     item_vocab=5000, cate_vocab=50, seq_len=12, n_heads=4, n_interests=4)
    m = cls(c)
    p = m.init_params(jax.random.PRNGKey(1))
    feats, labels = recsys_batch(c, 32, rng)
    feats = {k: jnp.asarray(v) for k, v in feats.items()}
    logits = m.forward(p, feats)
    assert logits.shape == (32,) and np.isfinite(np.asarray(logits)).all(), kind
    l = bce_loss(logits, jnp.asarray(labels))
    g = jax.grad(lambda pp: bce_loss(m.forward(pp, feats), jnp.asarray(labels)))(p)
    print(f"{kind}: loss={float(l):.4f}")
    if kind == "mind":
        cand = jax.random.normal(jax.random.PRNGKey(2), (1000, 16))
        scores, idx = m.retrieve(p, feats, cand, k=10)
        assert scores.shape == (32, 10)
        print("mind retrieve ok")
print("GNN+RECSYS OK")
