"""Generate EXPERIMENTS.md from dry-run/hillclimb/benchmark artifacts."""
import glob
import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
RES = os.path.join(ROOT, "src", "repro", "launch", "dryrun_results")


def load(d):
    out = {}
    for f in sorted(glob.glob(os.path.join(RES, d, "*.json"))):
        r = json.load(open(f))
        out[(r["arch"], r["shape"])] = r
    return out


def fmt(x, digits=2):
    if x is None:
        return "-"
    if isinstance(x, float):
        return f"{x:.{digits}e}" if (abs(x) < 1e-2 or abs(x) > 1e4) else f"{x:.{digits}f}"
    return str(x)


def roofline_table(recs, title):
    lines = [f"### {title}", "",
             "| arch | shape | kind | dominant | compute s | memory s | "
             "collective s | coll GB/dev | peak GiB/dev | roofline frac | note |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape), r in sorted(recs.items()):
        if "skipped" in r:
            lines.append(f"| {arch} | {shape} | {r['kind']} | SKIP | - | - | - "
                         f"| - | - | - | {r['skipped'][:60]} |")
            continue
        if not r.get("ok"):
            lines.append(f"| {arch} | {shape} | {r['kind']} | FAIL | - | - | - "
                         f"| - | - | - | {r.get('error','')[:60]} |")
            continue
        peak = (r["bytes_per_device"]["peak"] or 0) / 2**30
        lines.append(
            f"| {arch} | {shape} | {r['kind']} | **{r['dominant']}** "
            f"| {fmt(r['compute_s'])} | {fmt(r['memory_s'])} "
            f"| {fmt(r['collective_s'])} | {fmt(r['collective_bytes']/1e9)} "
            f"| {peak:.2f} | {fmt(r.get('roofline_frac'), 3)} "
            f"| {r.get('note','')[:48]} |")
    return "\n".join(lines)


def perf_table():
    lines = ["| cell | iteration | hypothesis (abridged) | collective before -> after | frac before -> after | verdict |",
             "|---|---|---|---|---|---|"]
    for f in sorted(glob.glob(os.path.join(RES, "perf", "*__it*.json"))):
        r = json.load(open(f))
        if not r.get("ok"):
            continue
        b = r.get("before", {})
        hyp = " ".join(r.get("hypothesis", "").split())
        # verdict: confirmed if collective dropped >5%
        before_c = b.get("collective_s")
        after_c = r.get("collective_s")
        if before_c and after_c is not None:
            if after_c < before_c * 0.95:
                verdict = "confirmed"
            elif after_c <= before_c * 1.05:
                verdict = "refuted (no effect)"
            else:
                verdict = "refuted (worse)"
        else:
            verdict = "-"
        lines.append(
            f"| {r['arch']}:{r['shape']} | {r['iteration']} | {hyp[:180]} "
            f"| {fmt(before_c)} -> {fmt(after_c)} "
            f"| {fmt(b.get('roofline_frac'),3)} -> {fmt(r.get('roofline_frac'),3)} "
            f"| {verdict} |")
    return "\n".join(lines)


def main():
    base_sp = load("baseline_pod16x16")
    base_mp = load("baseline_pod2x16x16")
    opt_sp = load("pod16x16")
    opt_mp = load("pod2x16x16")
    sections = {
        "BASELINE_SP": roofline_table(base_sp, "Baseline, single pod 16x16 (256 chips)"),
        "BASELINE_MP": roofline_table(base_mp, "Baseline, multi-pod 2x16x16 (512 chips)"),
        "OPT_SP": roofline_table(opt_sp, "Optimized (shipped defaults), single pod 16x16"),
        "OPT_MP": roofline_table(opt_mp, "Optimized (shipped defaults), multi-pod 2x16x16"),
        "PERF": perf_table(),
    }
    tpl = open(os.path.join(ROOT, "EXPERIMENTS.template.md")).read()
    for k, v in sections.items():
        tpl = tpl.replace("{{" + k + "}}", v)
    open(os.path.join(ROOT, "EXPERIMENTS.md"), "w").write(tpl)
    print("EXPERIMENTS.md written")


if __name__ == "__main__":
    main()
