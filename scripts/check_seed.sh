#!/usr/bin/env bash
# Seed gate: catches jax import-drift and serving regressions before merge.
#   1. tier-1 test suite (must collect all modules — zero ImportErrors);
#   2. quick-mode serving benchmark (exercises the batch-native engines, the
#      routed frontend, the fused fallback, their parity asserts, and the
#      striped path end-to-end; writes the BENCH_qac.json snapshot).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== quick-mode serving benchmark =="
BENCH_QUICK=1 python -m benchmarks.bench_qac_serve

echo "bench json: $(pwd)/BENCH_qac.json"
echo "check_seed: OK"
