#!/usr/bin/env bash
# Seed gate: catches jax import-drift and serving regressions before merge.
#   1. kernel parity fast-fail: the codec round-trip/compressed-parity,
#      heap_topk and batched-engine suites first (bit-identity of every
#      kernel route — raw CSR and packed ef/bitpack — vs the vmap
#      references) so a broken kernel or codec fails in ~2 min instead of
#      after the whole tier-1 run;
#   2. online-runtime smoke: a short keystroke trace through
#      `launch/serve.py --online --check` (micro-batch scheduler + prefix/
#      session caches), asserting parity with naive per-request dispatch
#      and a nonzero cache hit rate;
#   3. cluster fault drill: a 2-replica cluster trace with one injected
#      kill mid-trace (`--cluster 2 --drill --check`), asserting every
#      served answer stays bit-identical to the uncached frontend oracle,
#      the death is detected, and re-routed traffic is nonzero;
#   4. tier-1 test suite (must collect all modules — zero ImportErrors);
#   5. quick-mode serving benchmark (exercises the batch-native engines, the
#      heap_topk route B-sweep, the routed frontend, the fused fallback +
#      its >=parity-vs-vmap acceptance assert, the online-runtime trace
#      sweep with its >=30% hit-rate / >=2x-vs-naive gates, and the striped
#      path end-to-end; writes the BENCH_qac.json snapshot);
#   6. quick-mode cluster saturation bench (admission-control SLA gate at
#      overload + kill-drill failover gate; merges into BENCH_qac.json);
#   7. freshness smoke: a mutation trace through `--freshness --check`
#      (delta tier + k-way merge + >=1 mid-trace rebuild-and-swap),
#      asserting time-indexed bit-parity of sampled answers vs from-scratch
#      rebuilds at their visible (generation, seq) versions, nonzero
#      delta-tier hits, and exactly-once cache invalidation per swap;
#   8. quick-mode freshness bench (apply/swap-stall latency, post-swap
#      hit-rate-recovery >= 0.5x gate, merged-vs-immutable <= 1.5x gate;
#      merges into BENCH_qac.json);
#   9. observability smoke: the online trace again with tracing + the
#      jit-variant auditor on (`--online --observe --check`), asserting
#      bit-parity with tracing enabled, every sampled request tree's
#      queue.wait + engine.service == its recorded e2e latency, and a
#      closed jit-variant space (zero post-freeze compiles) — plus
#      `scripts/obs_report.py --check` on the exported trace (e2e p99
#      rebuilt from child spans within 5% of the root-span p99);
#  10. bench regression report: `benchmarks.run --compare` in report-only
#      mode diffs this machine's quick-mode numbers against the committed
#      BENCH_qac.json trajectory (never fails the gate — host noise — but
#      makes an accidental order-of-magnitude regression visible in CI
#      logs; the enforcing `--compare` without report-only is for perf PRs).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== kernel parity: codecs + heap_topk + batched engines =="
python -m pytest -x -q tests/test_codecs.py tests/test_heap_topk.py \
    tests/test_batched_engines.py

echo "== online-runtime smoke: scheduler + prefix-cache parity =="
# short keystroke trace through the micro-batching runtime; --check asserts
# bit-identity vs naive one-request-per-dispatch serving and a nonzero
# cache hit rate (fails fast here instead of after the whole tier-1 run)
python -m repro.launch.serve --online --check --queries 3000 --sessions 64 \
    --slack-us 5000

echo "== cluster fault drill: 2 replicas + injected kill =="
# session-affinity cluster with a replica kill injected mid-trace; --check
# asserts bit-parity of every served row vs the uncached frontend oracle,
# a detected death + readmission, and nonzero re-routed traffic
python -m repro.launch.serve --online --cluster 2 --drill --check \
    --queries 800 --sessions 16 --keystroke-ms 5 --max-batch 8 \
    --slack-us 2000

echo "== tier-1: pytest =="
python -m pytest -x -q --ignore=tests/test_codecs.py \
    --ignore=tests/test_heap_topk.py \
    --ignore=tests/test_batched_engines.py

echo "== quick-mode serving benchmark (incl. heap_topk bench) =="
BENCH_QUICK=1 python -m benchmarks.bench_qac_serve

echo "== quick-mode cluster saturation + failover benchmark =="
BENCH_QUICK=1 python -m benchmarks.bench_qac_cluster

echo "== freshness smoke: delta tier + mid-trace swap parity =="
# live mutation trace with >= 1 rebuild-and-swap; --check asserts sampled
# answers are bit-identical to from-scratch builds at their own visible
# (generation, seq) versions, delta-tier hits are nonzero, and each swap
# invalidates both cache tiers exactly once
python -m repro.launch.serve --freshness --check --queries 2000 \
    --sessions 24 --mutations 18 --max-batch 8 --slack-us 2000 \
    --keystroke-ms 5

echo "== quick-mode freshness benchmark (apply/swap/recovery gates) =="
BENCH_QUICK=1 python -m benchmarks.bench_qac_freshness

echo "== observability smoke: tracing + jit audit + span-identity check =="
# the online trace with the obs stack live; --check asserts tracing
# bit-parity, the queue.wait + engine.service == e2e span identity on
# every sampled request, and zero post-freeze jit compiles; obs_report
# --check then rebuilds e2e p99 from the exported spans alone (5% tol)
OBS_TRACE="$(mktemp --suffix=.jsonl)"
python -m repro.launch.serve --online --observe --check --queries 3000 \
    --sessions 64 --slack-us 5000 --trace-sample 4 --trace-out "$OBS_TRACE"
python scripts/obs_report.py "$OBS_TRACE" --check
rm -f "$OBS_TRACE"

echo "== bench regression report vs committed BENCH_qac.json =="
# report-only: prints the per-metric diff + any would-be regressions
# without failing the seed gate (quick-mode numbers on a shared host are
# too noisy to block on; the enforcing mode is `--compare` without
# `--compare-report-only` on a quiet machine)
python -m benchmarks.run --quick --compare --compare-report-only \
    --only qac_obs

echo "bench json: $(pwd)/BENCH_qac.json"
echo "check_seed: OK"
