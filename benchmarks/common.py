"""Shared benchmark harness: corpus construction + timing utilities."""
from __future__ import annotations

import functools
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

QUICK = os.environ.get("BENCH_QUICK", "0") == "1"


def timer(fn, *args, repeats: int = 5, warmup: int = 1):
    """Median wall time of fn(*args) in seconds."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


@functools.lru_cache(maxsize=2)
def bench_corpus(n_queries: int = 0):
    """Shared synthetic AOL-like corpus + built index + host oracle."""
    from repro.text import SynthLogConfig, generate_query_log
    from repro.core import build_qac_index
    from repro.core.builder import build_corpus
    from repro.core.ref_engines import HostIndex

    n = n_queries or (3_000 if QUICK else 15_000)
    qs, sc = generate_query_log(SynthLogConfig(
        n_queries=n, vocab_size=max(n // 5, 500), mean_term_chars=7.0, seed=42))
    qidx, kept, scores = build_qac_index(qs, sc)
    dictionary, rows, sc2, kept2 = build_corpus(qs, sc)
    order = np.lexsort(tuple(rows[:, j] for j in range(rows.shape[1] - 1, -1, -1)) + (-sc2,))
    d_of_row = np.empty(len(rows), dtype=np.int32)
    d_of_row[order] = np.arange(len(rows), dtype=np.int32)
    host = HostIndex(rows, d_of_row, dictionary.n_terms)
    return qidx, kept, host, rows, d_of_row


def sample_eval_queries(kept, retain_pct: int, n_per_bucket: int = 50, seed=7):
    from repro.text import make_eval_queries
    rng = np.random.default_rng(seed)
    return make_eval_queries(list(kept), rng, n_per_bucket, retain_pct)


# every emit() lands here so runners can dump a machine-readable snapshot;
# keyed by benchmark name, value is us_per_call (see write_bench_json)
RESULTS: dict[str, float] = {}


def emit(name: str, us_per_call: float, derived: str = ""):
    RESULTS[name] = float(us_per_call)
    print(f"{name},{us_per_call:.3f},{derived}")


# regression-gate direction heuristics (ISSUE 10): which way is better for
# a BENCH_qac.json metric, decided from name tokens. Lower-better covers
# latencies, sizes and failure rates; higher-better covers throughput,
# hit/recovery rates and accuracy-style scores.
_LOWER_BETTER = ("_us", "_bpi", "ratio", "shed_rate", "stall", "_bytes",
                 "_ms")
_HIGHER_BETTER = ("qps", "hit_rate", "recovery", "mips", "agreement",
                  "coverage", "recall", "mrr")


def metric_direction(name: str) -> str:
    """"lower" | "higher" | "unknown" — which direction improves ``name``.

    Token match on the metric name (suffix conventions are stable across
    the bench modules); "unknown" metrics are reported but never gate.
    Higher-better tokens win ties: a name like ``decode_us_per_mips``
    reads as a throughput metric.
    """
    low = name.lower()
    if any(t in low for t in _HIGHER_BETTER):
        return "higher"
    if any(t in low for t in _LOWER_BETTER):
        return "lower"
    return "unknown"


def compare_results(current: dict, baseline: dict, *,
                    tolerance: float = 0.5) -> dict:
    """Diff a fresh bench run against the committed baseline.

    A metric REGRESSES when it moves in its bad direction by more than
    ``tolerance`` (relative: 0.5 = 50%, generous because these benches run
    on shared noisy hosts; the gate is for order-of-magnitude breakage
    like a kernel silently falling back to XLA, not for jitter). Returns
    ``{"rows": [...], "regressions": [names], "missing": [names]}`` where
    rows carry (name, base, cur, ratio, direction, status) and ``missing``
    lists baseline metrics the fresh run did not produce (only metrics
    present in BOTH are compared — a partial ``--only`` run gates only
    what it ran).
    """
    if tolerance < 0:
        raise ValueError(f"tolerance must be >= 0, got {tolerance}")
    rows, regressions = [], []
    for name in sorted(set(baseline) & set(current)):
        base, cur = float(baseline[name]), float(current[name])
        direction = metric_direction(name)
        ratio = cur / base if base else float("inf") if cur else 1.0
        if direction == "lower":
            bad = cur > base * (1.0 + tolerance)
        elif direction == "higher":
            bad = cur < base * (1.0 - tolerance)
        else:
            bad = False
        status = "REGRESSED" if bad else "ok"
        if bad:
            regressions.append(name)
        rows.append(dict(name=name, base=base, cur=cur, ratio=ratio,
                         direction=direction, status=status))
    missing = sorted(set(baseline) - set(current))
    return {"rows": rows, "regressions": regressions, "missing": missing}


def write_bench_json(path: str | None = None) -> str:
    """Merge all emitted results as {name: value} JSON at the repo root.

    The bench trajectory (BENCH_qac.json) is the machine-readable record the
    perf gate and future PRs diff against; every ``benchmarks.run`` /
    ``bench_qac_serve`` invocation MERGES its own entries over the existing
    file and keeps the rest (so ``--only`` runs don't clobber the other
    modules' numbers — including the online runtime's ``qac_online_*``
    latency/hit-rate keys, which capture end-to-end serving rather than
    per-engine us/q). The write goes through a tmp file + ``os.replace`` so
    a crash mid-dump can't leave a torn JSON behind for the next merge to
    silently discard.
    """
    import json

    if path is None:
        path = os.path.join(os.path.dirname(__file__), "..", "BENCH_qac.json")
    path = os.path.abspath(path)
    merged = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                merged = json.load(f)
        except (ValueError, OSError):
            merged = {}
    merged.update(RESULTS)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(merged, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
    print(f"# bench json: {path}", flush=True)
    return path
