"""Paper Table 4: inverted-index compression in bits per integer.

ISSUE 7 adds the device block format (``core.codecs.pack_postings``): the
``qac_postings_bpi_{raw,bitpack,ef}`` keys measure the layout the kernels
actually decode on-chip (per-128 block directory included), and the
``qac_postings_decode_*_mips`` keys its random-access decode bandwidth
(jit'd ``packed_lookup`` over the full stream, vs a raw int32 gather) —
the cost side of the compressed-fit routing trade.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .common import bench_corpus, emit, timer, QUICK, write_bench_json
from repro.core.codecs import (index_bpi, ef_encode, ef_decode, vbyte_encode,
                               vbyte_decode, pack_postings, packed_lookup,
                               unpack_postings)


def _decode_rate(pk, ptrs):
    """Random-access decode bandwidth in million ints/second."""
    fn = jax.jit(lambda p: packed_lookup(
        pk.words, pk.base, pk.meta, pk.wordoff, p,
        n_post=pk.n_post, ef=pk.has_ef))
    fn(ptrs).block_until_ready()
    t = timer(lambda: fn(ptrs).block_until_ready(), repeats=5)
    return ptrs.shape[0] / t / 1e6


def main():
    qidx, kept, host, rows, d_of_row = bench_corpus()
    lists = [np.asarray(host.plist(t), dtype=np.int64)
             for t in range(1, host.n_terms + 1)]
    lists = [l for l in lists if len(l)]
    if QUICK:
        lists = lists[:300]
    for method in ("ef", "pef", "vbyte", "bitpack", "raw32"):
        bpi = index_bpi(lists, method)
        emit(f"compress_{method}_bpi", bpi, f"n_lists={len(lists)}")
    # decode roundtrip sanity on a sample (correctness in the bench harness)
    for l in lists[:20]:
        assert (ef_decode(ef_encode(l)) == l).all()
        assert (vbyte_decode(vbyte_encode(l), len(l)) == l).all()

    # -- device block format (ISSUE 7): what the kernels decode on-chip -----
    postings = np.asarray(qidx.index.postings, dtype=np.int64)
    emit("qac_postings_bpi_raw", 32.0, f"n_post={len(postings)}")
    ptrs = jnp.asarray(np.arange(len(postings), dtype=np.int32))
    raw_dev = jnp.asarray(postings.astype(np.int32))
    g = jax.jit(lambda p: raw_dev[p])
    g(ptrs).block_until_ready()
    t_raw = timer(lambda: g(ptrs).block_until_ready(), repeats=5)
    emit("qac_postings_decode_raw_mips", len(postings) / t_raw / 1e6,
         "plain int32 gather baseline")
    for codec in ("bitpack", "ef"):
        pk = pack_postings(postings, codec)
        assert (unpack_postings(pk) == postings).all()
        bpi = pk.bits_per_int()
        emit(f"qac_postings_bpi_{codec}", bpi,
             f"ratio={32.0 / bpi:.2f}x,bytes={pk.nbytes()}")
        rate = _decode_rate(pk, ptrs)
        emit(f"qac_postings_decode_{codec}_mips", rate,
             f"raw_gather_mips={len(postings) / t_raw / 1e6:.1f},"
             f"n_post={len(postings)}")

    write_bench_json()


if __name__ == "__main__":
    main()
