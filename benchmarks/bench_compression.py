"""Paper Table 4: inverted-index compression in bits per integer."""
from __future__ import annotations

import numpy as np

from .common import bench_corpus, emit, QUICK
from repro.core.codecs import index_bpi, ef_encode, ef_decode, vbyte_encode, vbyte_decode


def main():
    qidx, kept, host, rows, d_of_row = bench_corpus()
    lists = [np.asarray(host.plist(t), dtype=np.int64)
             for t in range(1, host.n_terms + 1)]
    lists = [l for l in lists if len(l)]
    if QUICK:
        lists = lists[:300]
    for method in ("ef", "pef", "vbyte", "bitpack", "raw32"):
        bpi = index_bpi(lists, method)
        emit(f"compress_{method}_bpi", bpi, f"n_lists={len(lists)}")
    # decode roundtrip sanity on a sample (correctness in the bench harness)
    for l in lists[:20]:
        assert (ef_decode(ef_encode(l)) == l).all()
        assert (vbyte_decode(vbyte_encode(l), len(l)) == l).all()


if __name__ == "__main__":
    main()
