"""Paper Fig 6b: RMQ top-k timing by query-range size (number of terms /
suffix % controls the lexicographic range width), for both the vmap-of-scalar
reference and the batch-native engine (ISSUE 2)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .common import bench_corpus, timer, emit, QUICK
from repro.core.rmq import topk_in_range, topk_in_range_batch


def main():
    qidx, kept, host, rows, d_of_row = bench_corpus()
    N = qidx.completions.n
    rng = np.random.default_rng(3)
    B = 64 if QUICK else 256
    for width in (16, 256, 4096, N // 2):
        p = rng.integers(0, max(N - width, 1), B).astype(np.int32)
        q = np.minimum(p + width, N).astype(np.int32)
        # hoist host->device transfer out of the timed region: re-converting
        # inside the timed lambda polluted the Fig 6b numbers with PCIe time
        pj, qj = jnp.asarray(p), jnp.asarray(q)
        fn = jax.jit(jax.vmap(
            lambda a, b: topk_in_range(qidx.rmq_docids, a, b, 10)[0]))
        fn(pj, qj).block_until_ready()
        t = timer(lambda: fn(pj, qj).block_until_ready(),
                  repeats=3, warmup=0) / B
        emit(f"rmq_top10_width{width}", t * 1e6, f"batch={B}")
        fb = jax.jit(
            lambda a, b: topk_in_range_batch(qidx.rmq_docids, a, b, 10)[0])
        np.testing.assert_array_equal(np.asarray(fn(pj, qj)),
                                      np.asarray(fb(pj, qj)))
        tb = timer(lambda: fb(pj, qj).block_until_ready(),
                   repeats=3, warmup=0) / B
        emit(f"rmq_top10_batched_width{width}", tb * 1e6,
             f"batch={B},speedup={t/tb:.2f}x")


if __name__ == "__main__":
    main()
