"""Paper Fig 6b: RMQ top-k timing by query-range size (number of terms /
suffix % controls the lexicographic range width)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .common import bench_corpus, timer, emit, QUICK
from repro.core.rmq import topk_in_range


def main():
    qidx, kept, host, rows, d_of_row = bench_corpus()
    N = qidx.completions.n
    rng = np.random.default_rng(3)
    B = 64 if QUICK else 256
    for width in (16, 256, 4096, N // 2):
        p = rng.integers(0, max(N - width, 1), B).astype(np.int32)
        q = np.minimum(p + width, N).astype(np.int32)
        fn = jax.jit(jax.vmap(
            lambda a, b: topk_in_range(qidx.rmq_docids, a, b, 10)[0]))
        fn(jnp.asarray(p), jnp.asarray(q)).block_until_ready()
        t = timer(lambda: fn(jnp.asarray(p), jnp.asarray(q)).block_until_ready(),
                  repeats=3, warmup=0) / B
        emit(f"rmq_top10_width{width}", t * 1e6, f"batch={B}")


if __name__ == "__main__":
    main()
