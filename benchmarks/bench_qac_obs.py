"""Observability bench (ISSUE 10): tracing overhead + jit-audit gates.

Three acceptance gates over the obs stack (src/repro/obs/), enforced here
and emitted into BENCH_qac.json:

  * ``qac_obs_overhead_ratio`` — online p99 with request tracing at the
    production 1/16 sampling stride vs tracing disabled, same trace, same
    warm frontend, best-of-3 interleaved trials. Gate: <= 1.10. Tracing
    must be observability, not a tax — every instrumentation site is
    behind ``tracer is not None`` + ``want(idx)``, and span construction
    happens OUTSIDE the measured engine-wall windows.
  * bit-parity: the rows served with tracing on are bit-identical to the
    rows served with tracing off (sampling can never change answers).
  * the jit-variant auditor's negative control: a frontend with
    ``specialize_list_pad=True`` (the open-variant config the online stack
    forbids), warmed ONLY on single-term traffic and then frozen, must
    produce >= 1 flagged mid-trace compile when the full trace's
    multi-term requests arrive — a REAL compile caught in the act, and
    ``assert_closed()`` must raise on it. The same trace through the
    closed ``specialize_list_pad=False`` frontend records zero.
"""
from __future__ import annotations

import os
import sys

if "--quick" in sys.argv:               # before .common reads BENCH_QUICK
    os.environ["BENCH_QUICK"] = "1"

import numpy as np

from .common import bench_corpus, emit, QUICK, write_bench_json
from repro.obs import JitAuditError, JitAuditor, Tracer
from repro.serve.frontend import QACFrontend
from repro.serve.runtime import (QACOnlineRuntime, RuntimeConfig,
                                 prepare_requests)
from repro.text import KeystrokeTraceConfig, generate_keystroke_trace

OVERHEAD_CAP = 1.10          # traced p99 vs untraced p99, 1/16 sampling
SAMPLE_EVERY = 16            # the production stride (QACArch default)
TRIALS = 3                   # best-of-N interleaved, min-vs-min


def main():
    qidx, kept, host, rows, d_of_row = bench_corpus()
    n_sessions = 48 if QUICK else 96
    trace = generate_keystroke_trace(kept, KeystrokeTraceConfig(
        n_sessions=n_sessions, seed=33))
    reqs = prepare_requests(qidx, trace, k=10)
    cfg = RuntimeConfig(max_batch=64, slack_us=2_000.0)

    # -- overhead: traced vs untraced, shared warm frontend ------------------
    fe = QACFrontend(qidx, k=10, specialize_list_pad=False)
    tracer = Tracer(sample_every=SAMPLE_EVERY)
    rt_off = QACOnlineRuntime(fe, cfg)
    rt_on = QACOnlineRuntime(fe, cfg, tracer=tracer)
    # one warm pass compiles every jit variant the trace can form; the
    # frontend is shared, so both runtimes serve from the same warm cache
    rt_off.warmup(reqs)
    rt_off.run_trace(reqs)
    p99_off, p99_on = [], []
    rows_off = rows_on = None
    for _ in range(TRIALS):
        rt_off.reset()
        rows_off = rt_off.run_trace(reqs)
        p99_off.append(rt_off.telemetry.snapshot()["p99_us"])
        rt_on.reset()
        tracer.clear()
        rows_on = rt_on.run_trace(reqs)
        p99_on.append(rt_on.telemetry.snapshot()["p99_us"])
    ratio = min(p99_on) / max(min(p99_off), 1e-9)
    emit("qac_obs_p99_off_us", min(p99_off),
         f"n={len(reqs)},sessions={n_sessions}")
    emit("qac_obs_p99_on_us", min(p99_on),
         f"spans={len(tracer.spans)},sample_every={SAMPLE_EVERY}")
    emit("qac_obs_overhead_ratio", ratio,
         f"cap={OVERHEAD_CAP},trials={TRIALS}")
    assert tracer.spans, "traced replay recorded no spans"
    assert ratio <= OVERHEAD_CAP, \
        (f"tracing overhead {ratio:.3f}x exceeds {OVERHEAD_CAP}x cap "
         f"(p99 on={min(p99_on):.0f}us off={min(p99_off):.0f}us)")

    # -- bit-parity: sampling can never change answers -----------------------
    for i, (a, b) in enumerate(zip(rows_on, rows_off)):
        assert np.array_equal(a, b), \
            f"tracing changed answer at request {i} ({reqs[i].query!r})"

    # -- jit audit: closed config records zero post-freeze compiles ----------
    aud_closed = JitAuditor()
    fe_closed = QACFrontend(qidx, k=10, specialize_list_pad=False,
                            auditor=aud_closed)
    rt_c = QACOnlineRuntime(fe_closed, cfg)
    rt_c.warmup(reqs)
    rt_c.run_trace(reqs)
    aud_closed.freeze()
    rt_c.reset()
    rt_c.run_trace(reqs)
    aud_closed.assert_closed()
    assert aud_closed.compiles, "closed run compiled nothing at warmup"

    # -- negative control: the open-variant config MUST get flagged ----------
    # warm only on single-term traffic, freeze, then serve the full trace:
    # the multi-term class's per-bucket list_pad specialization mints its
    # variants mid-trace — a real compile on the serving path, caught live
    aud_open = JitAuditor()
    fe_open = QACFrontend(qidx, k=10, specialize_list_pad=True,
                          auditor=aud_open)
    rt_o = QACOnlineRuntime(fe_open, cfg)
    singles = [r for r in reqs if r.plen == 0]
    assert singles and len(singles) < len(reqs), \
        "negative control needs a mixed single/multi trace"
    rt_o.warmup(singles)
    rt_o.run_trace(singles)
    aud_open.freeze()
    rt_o.reset()
    rt_o.run_trace(reqs)
    viol = aud_open.violations
    assert len(viol) >= 1, \
        "open-variant frontend compiled nothing mid-trace — negative " \
        "control is not exercising the auditor"
    try:
        aud_open.assert_closed()
    except JitAuditError:
        pass
    else:
        raise AssertionError("assert_closed() accepted post-freeze compiles")
    emit("qac_obs_jit_violations_flagged", float(len(viol)),
         f"first_key={viol[0]['key']},closed_variants="
         f"{len(aud_closed.compiles)}")

    write_bench_json()


if __name__ == "__main__":
    main()
