"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints ``name,us_per_call,derived`` CSV lines (one block per module).
Mapping to the paper: dictionary=Table 3, compression=Table 4,
conjunctive=Table 5, effectiveness=Table 6, space=Table 7,
completions=Fig 6a, rmq=Fig 6b; qac_serve and roofline are this system's
additions (TPU serving plan + §Roofline reader). Every emit lands in
BENCH_qac.json at the repo root — the perf trajectory future PRs diff
against; the ``qac_single_engine_kernel_b{64,256,1024}`` keys from
qac_serve track the heap_topk on-chip kernel route (PR 3).
"""
from __future__ import annotations

import argparse
import importlib
import os
import sys
import time
import traceback

MODULES = [
    "bench_dictionary",
    "bench_compression",
    "bench_completions",
    "bench_rmq",
    "bench_conjunctive",
    "bench_effectiveness",
    "bench_space",
    "bench_qac_serve",
    "bench_qac_cluster",
    "bench_qac_freshness",
    "bench_roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    if args.quick:
        os.environ["BENCH_QUICK"] = "1"
    failures = 0
    for mod in MODULES:
        if args.only and args.only not in mod:
            continue
        print(f"# === {mod} ===", flush=True)
        t0 = time.time()
        try:
            m = importlib.import_module(f"benchmarks.{mod}")
            m.main()
            print(f"# {mod} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {mod} FAILED:\n{traceback.format_exc()}", flush=True)
    from benchmarks.common import RESULTS, write_bench_json

    if RESULTS:
        write_bench_json()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
