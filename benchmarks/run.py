"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints ``name,us_per_call,derived`` CSV lines (one block per module).
Mapping to the paper: dictionary=Table 3, compression=Table 4,
conjunctive=Table 5, effectiveness=Table 6, space=Table 7,
completions=Fig 6a, rmq=Fig 6b; qac_serve and roofline are this system's
additions (TPU serving plan + §Roofline reader). Every emit lands in
BENCH_qac.json at the repo root — the perf trajectory future PRs diff
against; the ``qac_single_engine_kernel_b{64,256,1024}`` keys from
qac_serve track the heap_topk on-chip kernel route (PR 3).
"""
from __future__ import annotations

import argparse
import importlib
import os
import sys
import time
import traceback

MODULES = [
    "bench_dictionary",
    "bench_compression",
    "bench_completions",
    "bench_rmq",
    "bench_conjunctive",
    "bench_effectiveness",
    "bench_space",
    "bench_qac_serve",
    "bench_qac_cluster",
    "bench_qac_freshness",
    "bench_qac_obs",
    "bench_roofline",
]


def _load_baseline() -> dict:
    import json

    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_qac.json")
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


def _print_compare(report: dict, tolerance: float) -> None:
    print(f"# === compare vs committed BENCH_qac.json "
          f"(tolerance {tolerance:.0%}) ===", flush=True)
    for row in report["rows"]:
        arrow = {"lower": "v", "higher": "^", "unknown": "?"}[
            row["direction"]]
        print(f"# {row['status']:>9}  {row['name']}: "
              f"{row['base']:.3f} -> {row['cur']:.3f} "
              f"(x{row['ratio']:.2f}, better={arrow})", flush=True)
    for name in report["missing"]:
        print(f"#   missing  {name}: in baseline, not produced by this run",
              flush=True)
    n_reg = len(report["regressions"])
    print(f"# compare: {len(report['rows'])} metrics, "
          f"{n_reg} regression(s)"
          + (f": {report['regressions']}" if n_reg else ""), flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--compare", action="store_true",
                    help="diff this run's results against the committed "
                         "BENCH_qac.json (loaded BEFORE the run, so the "
                         "merge-on-write cannot mask a regression) and "
                         "exit nonzero on any metric past tolerance")
    ap.add_argument("--compare-report-only", action="store_true",
                    help="with --compare: print the diff but never fail "
                         "the run (the default CI stage, where host noise "
                         "must not block merges)")
    ap.add_argument("--compare-tolerance", type=float, default=0.5,
                    help="relative move in the bad direction that counts "
                         "as a regression (default 0.5 = 50%%)")
    ap.add_argument("--inject-regression", default=None, metavar="NAME",
                    help="testing hook: after the run, overwrite metric "
                         "NAME with a synthetically regressed value so the "
                         "gate's failure path can be exercised end-to-end")
    args = ap.parse_args()
    if args.quick:
        os.environ["BENCH_QUICK"] = "1"
    baseline = _load_baseline() if args.compare else {}
    if args.compare and not baseline:
        print("# compare: no committed BENCH_qac.json to diff against",
              flush=True)
    failures = 0
    for mod in MODULES:
        if args.only and args.only not in mod:
            continue
        print(f"# === {mod} ===", flush=True)
        t0 = time.time()
        try:
            m = importlib.import_module(f"benchmarks.{mod}")
            m.main()
            print(f"# {mod} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {mod} FAILED:\n{traceback.format_exc()}", flush=True)
    from benchmarks.common import (RESULTS, compare_results, metric_direction,
                                   write_bench_json)

    if args.inject_regression:
        name = args.inject_regression
        base = baseline.get(name, RESULTS.get(name))
        if base is None:
            print(f"# inject-regression: {name} not in baseline or results",
                  flush=True)
            sys.exit(2)
        # move the metric far past any tolerance in its bad direction
        bad = (base * 10.0 if metric_direction(name) != "higher"
               else base / 10.0)
        RESULTS[name] = float(bad)
        print(f"# inject-regression: {name} {base:.3f} -> {bad:.3f}",
              flush=True)
    if args.compare and baseline:
        report = compare_results(RESULTS, baseline,
                                 tolerance=args.compare_tolerance)
        _print_compare(report, args.compare_tolerance)
        if report["regressions"] and not args.compare_report_only:
            failures += 1
    # the injected regression is synthetic — never write it into the
    # committed trajectory
    if RESULTS and not args.inject_regression:
        write_bench_json()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
