"""Paper Table 6: % better-scored results of conjunctive vs prefix search.

Effectiveness metric per the paper: |Sc(q) \\ Sp(q)| / |Sp(q)| x 100, where
scores are docids (lower docid = better score) and Sc always covers Sp.
"""
from __future__ import annotations

import numpy as np

from .common import bench_corpus, sample_eval_queries, emit, QUICK
from repro.core import parse_queries


def main():
    qidx, kept, host, rows, d_of_row = bench_corpus()
    k = 10
    for pct in ((25, 75) if QUICK else (0, 25, 50, 75)):
        buckets = sample_eval_queries(kept, pct, n_per_bucket=10 if QUICK else 24,
                                      seed=pct + 100)
        for d, queries in sorted(buckets.items()):
            if d > 7 or not queries:
                continue
            pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, queries)
            tl, tr = qidx.dictionary.locate_prefix(suf, slen)
            better, base, covered_c, covered_p = 0, 0, 0, 0
            for i in range(len(queries)):
                prefix = [int(x) for x in np.asarray(pids[i]) if x]
                lo, hi = int(tl[i]), int(tr[i])
                sc = host.brute_conjunctive(prefix, lo, hi, k)
                sp = host.brute_prefix_search(prefix, lo, hi, k)
                covered_c += bool(sc)
                covered_p += bool(sp)
                if sp:
                    better += len(set(sc) - set(sp))
                    base += len(sp)
            pct_better = 100.0 * better / max(base, 1)
            emit(f"effect_d{d}_{pct}pct", pct_better,
                 f"coverage_conj={covered_c};coverage_prefix={covered_p};n={len(queries)}")


if __name__ == "__main__":
    main()
