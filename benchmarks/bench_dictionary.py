"""Paper Table 3: front-coded dictionary space/time by bucket size.

Reports MiB, bytes-per-string, and per-string timings for Extract, Locate,
and LocatePrefix at 0/25/50/75% retained characters.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .common import bench_corpus, timer, emit, QUICK
from repro.core import FrontCodedStore
from repro.core.strings import encode_strings


def main():
    qidx, kept, host, rows, d_of_row = bench_corpus()
    terms = sorted({t for q in kept for t in q.split()})
    raw_bytes = sum(len(t) + 1 for t in terms)
    rng = np.random.default_rng(0)
    n_q = 200 if QUICK else 800
    sample = [terms[i] for i in rng.integers(0, len(terms), n_q)]

    for bucket in ([16] if QUICK else [4, 16, 64, 256]):
        fc = FrontCodedStore.build(terms, bucket_size=bucket, max_chars=24)
        mib = fc.encoded_bytes() / 2**20
        bps = fc.encoded_bytes() / len(terms)
        import jax
        ex_f = jax.jit(lambda i: fc.extract(i))
        loc_f = jax.jit(lambda c: fc.locate(c))
        lp_f = jax.jit(lambda c, l: fc.locate_prefix(c, l))
        ids = jnp.asarray(rng.integers(0, len(terms), n_q), jnp.int32)
        t_ex = timer(lambda: ex_f(ids).block_until_ready()) / n_q
        chars = jnp.asarray(encode_strings(sample, 24))
        t_loc = timer(lambda: loc_f(chars).block_until_ready()) / n_q
        emit(f"dict_fc_b{bucket}_extract", t_ex * 1e6,
             f"MiB={mib:.2f};bps={bps:.2f};raw_bps={raw_bytes/len(terms):.2f}")
        emit(f"dict_fc_b{bucket}_locate", t_loc * 1e6, "")
        for pct in (0, 25, 50, 75):
            pref = [t[: max(1, int(len(t) * pct / 100))] for t in sample]
            pc = jnp.asarray(encode_strings(pref, 24))
            pl = jnp.asarray([len(p) for p in pref], jnp.int32)
            t_lp = timer(lambda: [x.block_until_ready()
                                  for x in lp_f(pc, pl)]) / n_q
            emit(f"dict_fc_b{bucket}_locate_prefix_{pct}pct", t_lp * 1e6, "")


if __name__ == "__main__":
    main()
