"""Paper Fig 6a: LocatePrefix on the completions — columnar trie-descent vs
front-coded strings — by number of query terms."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .common import bench_corpus, sample_eval_queries, timer, emit, QUICK
from repro.core import parse_queries
from repro.core.fc import FrontCodedStore
from repro.core.strings import encode_strings


def main():
    qidx, kept, host, rows, d_of_row = bench_corpus()
    fc = FrontCodedStore.build(list(kept), bucket_size=16, max_chars=96)
    buckets = sample_eval_queries(kept, 50, n_per_bucket=20 if QUICK else 100)

    for d, queries in sorted(buckets.items()):
        if d > 7 or not queries:
            continue
        pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, queries)
        tl, tr = qidx.dictionary.locate_prefix(suf, slen)
        n = len(queries)

        trie_fn = jax.jit(jax.vmap(
            lambda a, b, c, dd: qidx.completions.locate_prefix(a, b, c, dd)))
        trie_fn(pids, plen, tl, tr)[0].block_until_ready()
        t_trie = timer(lambda: trie_fn(pids, plen, tl, tr)[0].block_until_ready(),
                       repeats=3, warmup=0) / n

        qchars = jnp.asarray(encode_strings(queries, 96))
        qlens = jnp.asarray([len(q) for q in queries], jnp.int32)
        fc_fn = jax.jit(lambda a, b: fc.locate_prefix(a, b))
        fc_fn(qchars, qlens)[0].block_until_ready()
        t_fc = timer(lambda: fc_fn(qchars, qlens)[0].block_until_ready(),
                     repeats=3, warmup=0) / n
        emit(f"completions_trie_d{d}", t_trie * 1e6, "")
        emit(f"completions_fc_d{d}", t_fc * 1e6, "")


if __name__ == "__main__":
    main()
