"""Beyond-paper headline: batched QAC serving throughput (the TPU plan).

Amortized us/query and QPS of the batched complete() at several batch sizes,
plus (ISSUE 1) a routed-vs-fused comparison: the class-routed frontend
(serve/frontend.py) partitions each batch by query class and dispatches each
sub-batch to only its engine, swept over class-skew mixes (100%/80%/50%
single-term — paper §3.3 notes single-term queries dominate production
traffic), and the docid-striped distributed path on a local 1x{S} stripes
loop — paper §1 reports 135k QPS @ 80 cores.
ISSUE 2 adds the batch-native vs vmap-of-scalar engine comparison (the
serving hot loops now issue one batched RMQ / conjunctive tile per step)
and dumps every number to BENCH_qac.json at the repo root.
ISSUE 3 adds the single-term engine B-sweep (64/256/1024, quick mode
included, so routed-frontend and kernel numbers stay comparable across
PRs), the ``qac_single_engine_kernel_b{B}`` keys tracking the heap_topk
route (the fused on-chip kernel on TPU; its one-dispatch XLA reference
off-TPU), and the fused-path acceptance gate: the batched fused engine
must be at least at parity with the vmap-of-scalar fused engine.
ISSUE 4 adds the online-serving sweep: a keystroke-session trace replayed
through the micro-batching runtime (serve/runtime.py), emitting the
``qac_online_p50/p95/p99/mean_us`` + ``qac_online_cache_hit_rate`` keys —
END-TO-END per-request latency under arrival dynamics — gated on parity
with naive per-request dispatch, >=30% hit rate, and >=2x mean speedup.
ISSUE 7 adds the compressed heap route:
``qac_single_engine_kernel_compressed_b256`` times the single-term engine
decoding ef-packed postings inside the heap route (gated at <=1.5x the raw
kernel key — the decode cost that buys the VMEM headroom), and the
``qac_kernel_corpus_scale*`` sweep demonstrates the payoff: a dense corpus
plus a VMEM ceiling where raw CSR does NOT fit but the compressed stream
does (>=3x compression), with the compressed route still beating the
engine's own vmap-of-scalar reference.
"""
from __future__ import annotations

import os
import sys

if "--quick" in sys.argv:               # before .common reads BENCH_QUICK
    os.environ["BENCH_QUICK"] = "1"

import numpy as np
import jax
import jax.numpy as jnp

from .common import (bench_corpus, sample_eval_queries, timer, emit, QUICK,
                     write_bench_json)
from repro.compat import default_use_kernel
from repro.core import parse_queries
from repro.core.striped import build_striped
from repro.serve.qac import (qac_serve_step, qac_serve_step_vmap,
                             qac_serve_striped, serve_single_term,
                             serve_single_term_vmap)
from repro.serve.frontend import QACFrontend

BATCHES = (64,) if QUICK else (64, 256, 1024)
# the single-term engine sweep runs at full width even in quick mode: the
# production-dominant class is the one whose trajectory the kernel PRs move
ENGINE_BATCHES = (64, 256, 1024)
MIXES = (100, 80, 50)  # % single-term traffic


def _class_mix_batch(kept, rng, B, pct_single):
    """B partial queries, pct_single% single-term (lone partial token)."""
    multis = [q for q in kept if len(q.split()) >= 2] or list(kept)
    out = []
    n_single = round(B * pct_single / 100)
    while len(out) < n_single:
        t = kept[rng.integers(0, len(kept))].split()[0]
        out.append(t[: rng.integers(1, len(t) + 1)])
    while len(out) < B:
        toks = multis[rng.integers(0, len(multis))].split()
        cut = rng.integers(1, len(toks[-1]) + 1)
        out.append(" ".join(toks[:-1] + [toks[-1][:cut]]))
    rng.shuffle(out)
    return out


def main():
    qidx, kept, host, rows, d_of_row = bench_corpus()
    buckets = sample_eval_queries(kept, 50, n_per_bucket=200)
    queries = [q for qs in buckets.values() for q in qs]

    # -- fused baseline on the organic eval mix (historical headline) --------
    for B in BATCHES:
        qs = (queries * (B // len(queries) + 1))[:B]
        pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, qs)
        fn = jax.jit(lambda a, b, c, d: qac_serve_step(qidx, a, b, c, d, k=10))
        fn(pids, plen, suf, slen).block_until_ready()
        t = timer(lambda: fn(pids, plen, suf, slen).block_until_ready(),
                  repeats=3, warmup=0)
        emit(f"qac_serve_batch{B}", t / B * 1e6, f"qps={B/t:.0f}")

    # -- routed vs fused over class-skew mixes (ISSUE 1 tentpole) ------------
    rng = np.random.default_rng(123)
    frontend = QACFrontend(qidx, k=10)
    fused = jax.jit(lambda a, b, c, d: qac_serve_step(qidx, a, b, c, d, k=10))
    for B in BATCHES:
        for mix in MIXES:
            qs = _class_mix_batch(kept, rng, B, mix)
            pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, qs)
            got = np.asarray(frontend.complete(pids, plen, suf, slen))
            want = np.asarray(fused(pids, plen, suf, slen))
            assert np.array_equal(got, want), \
                f"routed != fused at B={B} mix={mix}"
            t_fused = timer(
                lambda: fused(pids, plen, suf, slen).block_until_ready(),
                repeats=5, warmup=1)
            t_routed = timer(
                lambda: np.asarray(frontend.complete(pids, plen, suf, slen)),
                repeats=5, warmup=1)
            emit(f"qac_routed_b{B}_single{mix}", t_routed / B * 1e6,
                 f"fused_us={t_fused/B*1e6:.3f},speedup={t_fused/t_routed:.2f}x,"
                 f"qps={B/t_routed:.0f}")

    # -- batch-native vs vmap-of-scalar engines (ISSUE 2 tentpole) -----------
    # single-term is the production-dominant class (paper §3.3); B=256 is the
    # acceptance point: batched >= 1.3x over vmap on the XLA ref path (CPU).
    # ISSUE 3 sweeps B and adds the heap_topk route: the whole bounded-trip
    # engine in ONE dispatch — the fused Pallas kernel on TPU, its XLA
    # reference formulation elsewhere (kernel_route notes which ran).
    uk = default_use_kernel()
    kernel_route = "pallas" if uk else "xla_ref"
    kernel_t = {}
    single_inputs = {}
    for B in ENGINE_BATCHES:
        singles = []
        while len(singles) < B:
            t = kept[rng.integers(0, len(kept))].split()[0]
            singles.append(t[: rng.integers(1, len(t) + 1)])
        _, _, _, suf, slen = parse_queries(qidx.dictionary, singles)
        single_inputs[B] = (suf, slen)
        f_vmap = jax.jit(
            lambda c, d: serve_single_term_vmap(qidx, c, d, k=10)[0])
        # heap_kernel=False pins the PR-2 per-pop engine so this key keeps
        # its meaning on TPU too (where the default would auto-route to the
        # heap kernel and silently duplicate the kernel key)
        f_bat = jax.jit(lambda c, d: serve_single_term(
            qidx, c, d, k=10, heap_kernel=False)[0])
        f_kern = jax.jit(lambda c, d: serve_single_term(
            qidx, c, d, k=10, use_kernel=uk, heap_kernel=True)[0])
        want = np.asarray(f_vmap(suf, slen))
        np.testing.assert_array_equal(want, np.asarray(f_bat(suf, slen)))
        np.testing.assert_array_equal(want, np.asarray(f_kern(suf, slen)))
        t_v = timer(lambda: f_vmap(suf, slen).block_until_ready(), repeats=7)
        t_b = timer(lambda: f_bat(suf, slen).block_until_ready(), repeats=7)
        t_k = timer(lambda: f_kern(suf, slen).block_until_ready(), repeats=7)
        emit(f"qac_single_engine_vmap_b{B}", t_v / B * 1e6, f"qps={B/t_v:.0f}")
        emit(f"qac_single_engine_batched_b{B}", t_b / B * 1e6,
             f"qps={B/t_b:.0f},speedup={t_v/t_b:.2f}x")
        emit(f"qac_single_engine_kernel_b{B}", t_k / B * 1e6,
             f"qps={B/t_k:.0f},route={kernel_route},speedup={t_v/t_k:.2f}x")
        kernel_t[B] = t_k

    # -- compressed heap route (ISSUE 7 tentpole) ----------------------------
    # the same heap route decoding ef-packed postings in place of raw CSR:
    # parity is bit-exact by the packed_lookup contract; the acceptance gate
    # bounds the decode overhead at 1.5x the raw kernel key — the price paid
    # for fitting a 3x bigger corpus under the same VMEM ceiling
    B = 256
    suf, slen = single_inputs[B]
    f_raw = jax.jit(lambda c, d: serve_single_term(
        qidx, c, d, k=10, use_kernel=uk, heap_kernel=True)[0])
    f_pk = jax.jit(lambda c, d: serve_single_term(
        qidx, c, d, k=10, use_kernel=uk, heap_kernel=True,
        postings_codec="ef")[0])
    np.testing.assert_array_equal(np.asarray(f_raw(suf, slen)),
                                  np.asarray(f_pk(suf, slen)))
    # best-of-3 interleaved timings against a re-measured raw reading: the
    # gate is a RATIO of two ~us-scale routes, and on a loaded runner a
    # single mean reading of either side swings past the 1.5x margin
    t_pk, t_raw = np.inf, np.inf
    for _ in range(3):
        t_pk = min(t_pk, timer(
            lambda: f_pk(suf, slen).block_until_ready(), repeats=7))
        t_raw = min(t_raw, timer(
            lambda: f_raw(suf, slen).block_until_ready(), repeats=7))
    t_raw = min(t_raw, kernel_t[B])
    emit(f"qac_single_engine_kernel_compressed_b{B}", t_pk / B * 1e6,
         f"qps={B/t_pk:.0f},route={kernel_route},"
         f"vs_raw_kernel={t_pk/t_raw:.2f}x,"
         f"bpi={qidx.index.packed.bits_per_int():.2f}")
    assert t_pk <= 1.5 * t_raw, \
        (f"compressed heap route {t_pk/B*1e6:.1f} us/q exceeds 1.5x the raw "
         f"kernel route {t_raw/B*1e6:.1f} us/q at B={B}")

    # -- kernel-eligible corpus scale (ISSUE 7 payoff) -----------------------
    # the point of in-kernel decode: corpora whose raw CSR blows the VMEM
    # ceiling but whose packed stream fits. Sweep corpus size with a dense
    # vocabulary (long postings lists — where ef earns its keep), set the
    # ceiling between the raw and packed footprints, and show the compressed
    # heap route is (a) the only kernel-eligible one and (b) still faster
    # than the engine's own vmap-of-scalar reference at that scale.
    from repro.core import build_qac_index
    from repro.core.search import _heap_kernel_fits
    from repro.text import SynthLogConfig, generate_query_log

    sizes = (2_000, 6_000) if QUICK else (2_000, 6_000, 15_000)
    scale_rng = np.random.default_rng(77)
    last = None
    for n in sizes:
        qs2, sc2 = generate_query_log(SynthLogConfig(
            n_queries=n, vocab_size=max(n // 40, 200), mean_term_chars=5.0,
            seed=77))
        qidx2, kept2, _ = build_qac_index(qs2, sc2, postings_codec="ef")
        idx2, rm2 = qidx2.index, qidx2.rmq_minimal
        raw_bytes = 4 * int(idx2.postings.size)
        pk_bytes = idx2.packed.nbytes()
        ratio = raw_bytes / pk_bytes
        overhead = 4 * int(rm2.values.size + rm2.st_pos.size + rm2.ib.size
                           + idx2.offsets.size)
        ceiling = overhead + (raw_bytes + pk_bytes) // 2
        fit_raw = _heap_kernel_fits(idx2, rm2, max_bytes=ceiling)
        fit_pk = _heap_kernel_fits(idx2, rm2, packed=idx2.packed,
                                   max_bytes=ceiling)
        B2 = 256
        singles = []
        while len(singles) < B2:
            t = kept2[scale_rng.integers(0, len(kept2))].split()[0]
            singles.append(t[: scale_rng.integers(1, len(t) + 1)])
        _, _, _, suf2, slen2 = parse_queries(qidx2.dictionary, singles)
        f_ref = jax.jit(lambda c, d, q=qidx2: serve_single_term_vmap(
            q, c, d, k=10)[0])
        f_pk2 = jax.jit(lambda c, d, q=qidx2, mb=ceiling: serve_single_term(
            q, c, d, k=10, use_kernel=uk, heap_kernel=True,
            postings_codec="ef", heap_kernel_max_bytes=mb)[0])
        np.testing.assert_array_equal(np.asarray(f_ref(suf2, slen2)),
                                      np.asarray(f_pk2(suf2, slen2)))
        t_ref = timer(lambda: f_ref(suf2, slen2).block_until_ready(),
                      repeats=5)
        t_pk2 = timer(lambda: f_pk2(suf2, slen2).block_until_ready(),
                      repeats=5)
        emit(f"qac_kernel_corpus_scale_n{n}", t_pk2 / B2 * 1e6,
             f"ratio={ratio:.2f}x,fit_raw={fit_raw},fit_pk={fit_pk},"
             f"vmap_us={t_ref/B2*1e6:.3f},speedup={t_ref/t_pk2:.2f}x")
        last = (n, ratio, fit_raw, fit_pk, t_ref, t_pk2)
    n, ratio, fit_raw, fit_pk, t_ref, t_pk2 = last
    emit("qac_kernel_corpus_scale", ratio,
         f"largest_n={n},only_compressed_fits={fit_pk and not fit_raw},"
         f"vs_vmap={t_ref/t_pk2:.2f}x")
    assert ratio >= 3.0, \
        f"ef compression {ratio:.2f}x below the 3x floor at n={n}"
    assert fit_pk and not fit_raw, \
        (f"ceiling {ceiling} should admit only the packed stream "
         f"(raw={raw_bytes + overhead}, packed={pk_bytes + overhead})")
    assert t_pk2 <= t_ref, \
        (f"compressed heap route {t_pk2/B2*1e6:.1f} us/q slower than its "
         f"vmap reference {t_ref/B2*1e6:.1f} us/q at n={n}")

    # fused path, mixed traffic: batched vs vmap. ISSUE 3 acceptance: the
    # batched fused engine must not regress below the vmap reference again
    B = 256
    qs = (queries * (B // len(queries) + 1))[:B]
    pids, plen, pok, sufm, slenm = parse_queries(qidx.dictionary, qs)
    g_vmap = jax.jit(lambda a, b, c, d: qac_serve_step_vmap(
        qidx, a, b, c, d, k=10))
    g_bat = jax.jit(lambda a, b, c, d: qac_serve_step(qidx, a, b, c, d, k=10))
    np.testing.assert_array_equal(np.asarray(g_vmap(pids, plen, sufm, slenm)),
                                  np.asarray(g_bat(pids, plen, sufm, slenm)))
    # best-of-3 interleaved timings: on a loaded 1-CPU runner single mean
    # readings of these two ~ms-scale paths swing past the 10% gate margin
    t_v, t_b = np.inf, np.inf
    for _ in range(3):
        t_v = min(t_v, timer(
            lambda: g_vmap(pids, plen, sufm, slenm).block_until_ready(),
            repeats=5))
        t_b = min(t_b, timer(
            lambda: g_bat(pids, plen, sufm, slenm).block_until_ready(),
            repeats=5))
    emit(f"qac_fused_engine_vmap_b{B}", t_v / B * 1e6, f"qps={B/t_v:.0f}")
    emit(f"qac_fused_engine_batched_b{B}", t_b / B * 1e6,
         f"qps={B/t_b:.0f},speedup={t_v/t_b:.2f}x")
    # 10% margin absorbs timer noise on loaded runners; the regression this
    # guards (PR 2 measured 1.27x) clears it by a wide band either way
    assert t_b <= t_v * 1.10, \
        (f"fused-path regression: batched {t_b/B*1e6:.1f} us/q slower than "
         f"vmap {t_v/B*1e6:.1f} us/q at B={B}")

    # -- online serving runtime: keystroke-session trace (ISSUE 4 tentpole) --
    # End-to-end latency under arrival dynamics, not amortized us/q: replay a
    # keystroke-per-session trace through the deadline-aware micro-batching
    # runtime + prefix/session caches, vs naive one-request-per-dispatch
    # serving (== uncached per-request QACFrontend calls, which doubles as
    # the bit-identity reference). Acceptance: parity everywhere, cache hit
    # rate >= 30%, mean per-request latency >= 2x better than naive.
    from repro.serve.runtime import (QACOnlineRuntime, RuntimeConfig,
                                     prepare_requests, run_naive_trace)
    from repro.text import KeystrokeTraceConfig, generate_keystroke_trace

    n_sessions = 64 if QUICK else 128
    trace = generate_keystroke_trace(kept, KeystrokeTraceConfig(
        n_sessions=n_sessions, queries_per_session=1 if QUICK else 2,
        seed=31))
    reqs = prepare_requests(qidx, trace, k=10)
    # naive reference first: one-request-per-dispatch serving is both the
    # bit-identity oracle AND the service-cost yardstick that sizes the
    # scheduler's slack below — a deadline wait is only worth roughly one
    # dispatch it amortizes away, and a hard-coded budget goes stale
    # whenever the engines (or the runner's load) shift the B=1 cost.
    # complete() is pure, so sharing the (warm) frontend with the runtime
    # gives identical rows with no duplicate compiles
    fe = QACFrontend(qidx, k=10, specialize_list_pad=False)
    naive_rows, naive = run_naive_trace(fe, reqs)
    slack_us = float(np.clip(naive["mean_us"], 500.0, 5_000.0))
    rt = QACOnlineRuntime(fe, RuntimeConfig(max_batch=64, slack_us=slack_us))
    online_rows = rt.replay(reqs)
    snap = rt.telemetry.snapshot()
    for i, (g, w) in enumerate(zip(online_rows, naive_rows)):
        assert np.array_equal(g, w), \
            f"online runtime parity break at request {i} ({reqs[i].query!r})"
    assert snap["cache_hit_rate"] >= 0.30, \
        f"cache hit rate {snap['cache_hit_rate']:.2f} below the 30% floor"
    assert naive["mean_us"] >= 2 * snap["mean_us"], \
        (f"micro-batched mean {snap['mean_us']:.0f}us not 2x better than "
         f"naive {naive['mean_us']:.0f}us")
    emit("qac_online_p50_us", snap["p50_us"],
         f"sessions={n_sessions},n={snap['n_requests']}")
    emit("qac_online_p95_us", snap["p95_us"],
         f"batches={snap['n_batches']},mean_batch={snap['mean_batch_size']:.1f}")
    emit("qac_online_p99_us", snap["p99_us"],
         f"queue_peak={snap['queue_peak']}")
    emit("qac_online_mean_us", snap["mean_us"],
         f"naive_mean_us={naive['mean_us']:.1f},slack_us={slack_us:.0f},"
         f"speedup={naive['mean_us']/max(snap['mean_us'], 1e-9):.2f}x")
    emit("qac_online_cache_hit_rate", snap["cache_hit_rate"],
         ",".join(f"{p}={c}" for p, c in sorted(snap["paths"].items())))

    # -- striped distributed path (agreement check) --------------------------
    striped = build_striped(rows, d_of_row, qidx.dictionary.n_terms, 4)
    B = 64
    qs = (queries * (B // len(queries) + 1))[:B]
    pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, qs)
    got = qac_serve_striped(striped, qidx.dictionary, pids, plen, suf, slen, k=10)
    want = qac_serve_step(qidx, pids, plen, suf, slen, k=10)
    agree = float(np.mean(np.asarray(got) == np.asarray(want)))
    emit("qac_striped_agreement", agree * 100, "pct_identical_to_single_index")

    write_bench_json()


if __name__ == "__main__":
    main()
