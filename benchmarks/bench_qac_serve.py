"""Beyond-paper headline: batched QAC serving throughput (the TPU plan).

Amortized us/query and QPS of the batched complete() at several batch sizes,
plus the docid-striped distributed path on a local 1x{S} stripes loop —
paper §1 reports 135k QPS @ 80 cores; this is the single-host CPU figure for
the same algorithm vectorized.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .common import bench_corpus, sample_eval_queries, timer, emit, QUICK
from repro.core import parse_queries
from repro.core.striped import build_striped
from repro.serve.qac import qac_serve_step, qac_serve_striped


def main():
    qidx, kept, host, rows, d_of_row = bench_corpus()
    buckets = sample_eval_queries(kept, 50, n_per_bucket=200)
    queries = [q for qs in buckets.values() for q in qs]
    for B in ((64,) if QUICK else (64, 256, 1024)):
        qs = (queries * (B // len(queries) + 1))[:B]
        pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, qs)
        fn = jax.jit(lambda a, b, c, d: qac_serve_step(qidx, a, b, c, d, k=10))
        fn(pids, plen, suf, slen).block_until_ready()
        t = timer(lambda: fn(pids, plen, suf, slen).block_until_ready(),
                  repeats=3, warmup=0)
        emit(f"qac_serve_batch{B}", t / B * 1e6, f"qps={B/t:.0f}")

    striped = build_striped(rows, d_of_row, qidx.dictionary.n_terms, 4)
    B = 64
    qs = (queries * (B // len(queries) + 1))[:B]
    pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, qs)
    got = qac_serve_striped(striped, qidx.dictionary, pids, plen, suf, slen, k=10)
    want = qac_serve_step(qidx, pids, plen, suf, slen, k=10)
    agree = float(np.mean(np.asarray(got) == np.asarray(want)))
    emit("qac_striped_agreement", agree * 100, "pct_identical_to_single_index")


if __name__ == "__main__":
    main()
