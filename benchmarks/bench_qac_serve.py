"""Beyond-paper headline: batched QAC serving throughput (the TPU plan).

Amortized us/query and QPS of the batched complete() at several batch sizes,
plus (ISSUE 1) a routed-vs-fused comparison: the class-routed frontend
(serve/frontend.py) partitions each batch by query class and dispatches each
sub-batch to only its engine, swept over class-skew mixes (100%/80%/50%
single-term — paper §3.3 notes single-term queries dominate production
traffic), and the docid-striped distributed path on a local 1x{S} stripes
loop — paper §1 reports 135k QPS @ 80 cores.
ISSUE 2 adds the batch-native vs vmap-of-scalar engine comparison (the
serving hot loops now issue one batched RMQ / conjunctive tile per step)
and dumps every number to BENCH_qac.json at the repo root.
ISSUE 3 adds the single-term engine B-sweep (64/256/1024, quick mode
included, so routed-frontend and kernel numbers stay comparable across
PRs), the ``qac_single_engine_kernel_b{B}`` keys tracking the heap_topk
route (the fused on-chip kernel on TPU; its one-dispatch XLA reference
off-TPU), and the fused-path acceptance gate: the batched fused engine
must be at least at parity with the vmap-of-scalar fused engine.
ISSUE 4 adds the online-serving sweep: a keystroke-session trace replayed
through the micro-batching runtime (serve/runtime.py), emitting the
``qac_online_p50/p95/p99/mean_us`` + ``qac_online_cache_hit_rate`` keys —
END-TO-END per-request latency under arrival dynamics — gated on parity
with naive per-request dispatch, >=30% hit rate, and >=2x mean speedup.
"""
from __future__ import annotations

import os
import sys

if "--quick" in sys.argv:               # before .common reads BENCH_QUICK
    os.environ["BENCH_QUICK"] = "1"

import numpy as np
import jax
import jax.numpy as jnp

from .common import (bench_corpus, sample_eval_queries, timer, emit, QUICK,
                     write_bench_json)
from repro.compat import default_use_kernel
from repro.core import parse_queries
from repro.core.striped import build_striped
from repro.serve.qac import (qac_serve_step, qac_serve_step_vmap,
                             qac_serve_striped, serve_single_term,
                             serve_single_term_vmap)
from repro.serve.frontend import QACFrontend

BATCHES = (64,) if QUICK else (64, 256, 1024)
# the single-term engine sweep runs at full width even in quick mode: the
# production-dominant class is the one whose trajectory the kernel PRs move
ENGINE_BATCHES = (64, 256, 1024)
MIXES = (100, 80, 50)  # % single-term traffic


def _class_mix_batch(kept, rng, B, pct_single):
    """B partial queries, pct_single% single-term (lone partial token)."""
    multis = [q for q in kept if len(q.split()) >= 2] or list(kept)
    out = []
    n_single = round(B * pct_single / 100)
    while len(out) < n_single:
        t = kept[rng.integers(0, len(kept))].split()[0]
        out.append(t[: rng.integers(1, len(t) + 1)])
    while len(out) < B:
        toks = multis[rng.integers(0, len(multis))].split()
        cut = rng.integers(1, len(toks[-1]) + 1)
        out.append(" ".join(toks[:-1] + [toks[-1][:cut]]))
    rng.shuffle(out)
    return out


def main():
    qidx, kept, host, rows, d_of_row = bench_corpus()
    buckets = sample_eval_queries(kept, 50, n_per_bucket=200)
    queries = [q for qs in buckets.values() for q in qs]

    # -- fused baseline on the organic eval mix (historical headline) --------
    for B in BATCHES:
        qs = (queries * (B // len(queries) + 1))[:B]
        pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, qs)
        fn = jax.jit(lambda a, b, c, d: qac_serve_step(qidx, a, b, c, d, k=10))
        fn(pids, plen, suf, slen).block_until_ready()
        t = timer(lambda: fn(pids, plen, suf, slen).block_until_ready(),
                  repeats=3, warmup=0)
        emit(f"qac_serve_batch{B}", t / B * 1e6, f"qps={B/t:.0f}")

    # -- routed vs fused over class-skew mixes (ISSUE 1 tentpole) ------------
    rng = np.random.default_rng(123)
    frontend = QACFrontend(qidx, k=10)
    fused = jax.jit(lambda a, b, c, d: qac_serve_step(qidx, a, b, c, d, k=10))
    for B in BATCHES:
        for mix in MIXES:
            qs = _class_mix_batch(kept, rng, B, mix)
            pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, qs)
            got = np.asarray(frontend.complete(pids, plen, suf, slen))
            want = np.asarray(fused(pids, plen, suf, slen))
            assert np.array_equal(got, want), \
                f"routed != fused at B={B} mix={mix}"
            t_fused = timer(
                lambda: fused(pids, plen, suf, slen).block_until_ready(),
                repeats=5, warmup=1)
            t_routed = timer(
                lambda: np.asarray(frontend.complete(pids, plen, suf, slen)),
                repeats=5, warmup=1)
            emit(f"qac_routed_b{B}_single{mix}", t_routed / B * 1e6,
                 f"fused_us={t_fused/B*1e6:.3f},speedup={t_fused/t_routed:.2f}x,"
                 f"qps={B/t_routed:.0f}")

    # -- batch-native vs vmap-of-scalar engines (ISSUE 2 tentpole) -----------
    # single-term is the production-dominant class (paper §3.3); B=256 is the
    # acceptance point: batched >= 1.3x over vmap on the XLA ref path (CPU).
    # ISSUE 3 sweeps B and adds the heap_topk route: the whole bounded-trip
    # engine in ONE dispatch — the fused Pallas kernel on TPU, its XLA
    # reference formulation elsewhere (kernel_route notes which ran).
    uk = default_use_kernel()
    kernel_route = "pallas" if uk else "xla_ref"
    for B in ENGINE_BATCHES:
        singles = []
        while len(singles) < B:
            t = kept[rng.integers(0, len(kept))].split()[0]
            singles.append(t[: rng.integers(1, len(t) + 1)])
        _, _, _, suf, slen = parse_queries(qidx.dictionary, singles)
        f_vmap = jax.jit(
            lambda c, d: serve_single_term_vmap(qidx, c, d, k=10)[0])
        # heap_kernel=False pins the PR-2 per-pop engine so this key keeps
        # its meaning on TPU too (where the default would auto-route to the
        # heap kernel and silently duplicate the kernel key)
        f_bat = jax.jit(lambda c, d: serve_single_term(
            qidx, c, d, k=10, heap_kernel=False)[0])
        f_kern = jax.jit(lambda c, d: serve_single_term(
            qidx, c, d, k=10, use_kernel=uk, heap_kernel=True)[0])
        want = np.asarray(f_vmap(suf, slen))
        np.testing.assert_array_equal(want, np.asarray(f_bat(suf, slen)))
        np.testing.assert_array_equal(want, np.asarray(f_kern(suf, slen)))
        t_v = timer(lambda: f_vmap(suf, slen).block_until_ready(), repeats=7)
        t_b = timer(lambda: f_bat(suf, slen).block_until_ready(), repeats=7)
        t_k = timer(lambda: f_kern(suf, slen).block_until_ready(), repeats=7)
        emit(f"qac_single_engine_vmap_b{B}", t_v / B * 1e6, f"qps={B/t_v:.0f}")
        emit(f"qac_single_engine_batched_b{B}", t_b / B * 1e6,
             f"qps={B/t_b:.0f},speedup={t_v/t_b:.2f}x")
        emit(f"qac_single_engine_kernel_b{B}", t_k / B * 1e6,
             f"qps={B/t_k:.0f},route={kernel_route},speedup={t_v/t_k:.2f}x")

    # fused path, mixed traffic: batched vs vmap. ISSUE 3 acceptance: the
    # batched fused engine must not regress below the vmap reference again
    B = 256
    qs = (queries * (B // len(queries) + 1))[:B]
    pids, plen, pok, sufm, slenm = parse_queries(qidx.dictionary, qs)
    g_vmap = jax.jit(lambda a, b, c, d: qac_serve_step_vmap(
        qidx, a, b, c, d, k=10))
    g_bat = jax.jit(lambda a, b, c, d: qac_serve_step(qidx, a, b, c, d, k=10))
    np.testing.assert_array_equal(np.asarray(g_vmap(pids, plen, sufm, slenm)),
                                  np.asarray(g_bat(pids, plen, sufm, slenm)))
    t_v = timer(lambda: g_vmap(pids, plen, sufm, slenm).block_until_ready(),
                repeats=5)
    t_b = timer(lambda: g_bat(pids, plen, sufm, slenm).block_until_ready(),
                repeats=5)
    emit(f"qac_fused_engine_vmap_b{B}", t_v / B * 1e6, f"qps={B/t_v:.0f}")
    emit(f"qac_fused_engine_batched_b{B}", t_b / B * 1e6,
         f"qps={B/t_b:.0f},speedup={t_v/t_b:.2f}x")
    # 10% margin absorbs timer noise on loaded runners; the regression this
    # guards (PR 2 measured 1.27x) clears it by a wide band either way
    assert t_b <= t_v * 1.10, \
        (f"fused-path regression: batched {t_b/B*1e6:.1f} us/q slower than "
         f"vmap {t_v/B*1e6:.1f} us/q at B={B}")

    # -- online serving runtime: keystroke-session trace (ISSUE 4 tentpole) --
    # End-to-end latency under arrival dynamics, not amortized us/q: replay a
    # keystroke-per-session trace through the deadline-aware micro-batching
    # runtime + prefix/session caches, vs naive one-request-per-dispatch
    # serving (== uncached per-request QACFrontend calls, which doubles as
    # the bit-identity reference). Acceptance: parity everywhere, cache hit
    # rate >= 30%, mean per-request latency >= 2x better than naive.
    from repro.serve.runtime import (QACOnlineRuntime, RuntimeConfig,
                                     prepare_requests, run_naive_trace)
    from repro.text import KeystrokeTraceConfig, generate_keystroke_trace

    n_sessions = 64 if QUICK else 128
    trace = generate_keystroke_trace(kept, KeystrokeTraceConfig(
        n_sessions=n_sessions, queries_per_session=1 if QUICK else 2,
        seed=31))
    reqs = prepare_requests(qidx, trace, k=10)
    # slack sized to the host-CPU engine (~ms service): big enough to form
    # real micro-batches, small enough that a miss's deadline wait doesn't
    # dwarf the per-dispatch cost it amortizes
    rt = QACOnlineRuntime(
        QACFrontend(qidx, k=10, specialize_list_pad=False),
        RuntimeConfig(max_batch=64, slack_us=5_000.0))
    online_rows = rt.replay(reqs)
    snap = rt.telemetry.snapshot()
    # same (warm) frontend: complete() is pure — identical reference rows,
    # no duplicate compiles; run_naive_trace's own warm loop still covers
    # the B=1 shapes before any timing
    naive_rows, naive = run_naive_trace(rt.fe, reqs)
    for i, (g, w) in enumerate(zip(online_rows, naive_rows)):
        assert np.array_equal(g, w), \
            f"online runtime parity break at request {i} ({reqs[i].query!r})"
    assert snap["cache_hit_rate"] >= 0.30, \
        f"cache hit rate {snap['cache_hit_rate']:.2f} below the 30% floor"
    assert naive["mean_us"] >= 2 * snap["mean_us"], \
        (f"micro-batched mean {snap['mean_us']:.0f}us not 2x better than "
         f"naive {naive['mean_us']:.0f}us")
    emit("qac_online_p50_us", snap["p50_us"],
         f"sessions={n_sessions},n={snap['n_requests']}")
    emit("qac_online_p95_us", snap["p95_us"],
         f"batches={snap['n_batches']},mean_batch={snap['mean_batch_size']:.1f}")
    emit("qac_online_p99_us", snap["p99_us"],
         f"queue_peak={snap['queue_peak']}")
    emit("qac_online_mean_us", snap["mean_us"],
         f"naive_mean_us={naive['mean_us']:.1f},"
         f"speedup={naive['mean_us']/max(snap['mean_us'], 1e-9):.2f}x")
    emit("qac_online_cache_hit_rate", snap["cache_hit_rate"],
         ",".join(f"{p}={c}" for p, c in sorted(snap["paths"].items())))

    # -- striped distributed path (agreement check) --------------------------
    striped = build_striped(rows, d_of_row, qidx.dictionary.n_terms, 4)
    B = 64
    qs = (queries * (B // len(queries) + 1))[:B]
    pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, qs)
    got = qac_serve_striped(striped, qidx.dictionary, pids, plen, suf, slen, k=10)
    want = qac_serve_step(qidx, pids, plen, suf, slen, k=10)
    agree = float(np.mean(np.asarray(got) == np.asarray(want)))
    emit("qac_striped_agreement", agree * 100, "pct_identical_to_single_index")

    write_bench_json()


if __name__ == "__main__":
    main()
