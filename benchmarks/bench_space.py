"""Paper Table 7: space usage of the Fwd / FC / Heap solution variants.

Component accounting (bytes + bytes-per-completion):
  Fwd  = dictionary + completions(trie/columnar) + RMQ(docids) + inverted
         index + forward index + RMQ(minimal)
  FC   = Fwd - forward index + front-coded completions (extraction source)
  Heap = FC - RMQ(minimal)
Both the in-memory TPU layout (int32 arrays) and the paper-style compressed
encodings (EF postings, FC strings) are reported.
"""
from __future__ import annotations

import numpy as np

from .common import bench_corpus, emit
from repro.core.fc import FrontCodedStore
from repro.core.codecs import index_bpi


def main():
    qidx, kept, host, rows, d_of_row = bench_corpus()
    N = qidx.completions.n
    raw = sum(len(q) + 1 for q in kept)

    d_bytes = qidx.dictionary.space_bytes()
    comp_bytes = qidx.completions.space_bytes()
    fwd_bytes = qidx.completions.fwd_space_bytes()
    inv_bytes = qidx.index.space_bytes()
    rmq_doc = qidx.rmq_docids.space_bytes() + qidx.rmq_docids.values.nbytes
    rmq_min = qidx.rmq_minimal.space_bytes() + qidx.rmq_minimal.values.nbytes
    fc_comp = FrontCodedStore.build(list(kept), bucket_size=16, max_chars=96)

    from repro.core.ref_engines import HybIndex
    hyb_bytes = HybIndex(host, c=1e-2).space_bytes()
    fwd_total = d_bytes + comp_bytes + rmq_doc + inv_bytes + fwd_bytes + rmq_min
    fc_total = d_bytes + comp_bytes + rmq_doc + inv_bytes + fc_comp.encoded_bytes() + rmq_min
    heap_total = d_bytes + comp_bytes + rmq_doc + inv_bytes + fc_comp.encoded_bytes()

    # paper-style compressed postings (EF) vs raw int32
    lists = [np.asarray(host.plist(t)) for t in range(1, host.n_terms + 1)]
    bpi_ef = index_bpi(lists, "ef")
    bpi_raw = 32.0
    inv_ef_bytes = int(inv_bytes * bpi_ef / bpi_raw)

    emit("space_fwd_bpc", fwd_total / N,
         f"MiB={fwd_total/2**20:.2f};raw_MiB={raw/2**20:.2f}")
    emit("space_fc_bpc", fc_total / N, f"MiB={fc_total/2**20:.2f}")
    emit("space_heap_bpc", heap_total / N, f"MiB={heap_total/2**20:.2f}")
    hyb_total = heap_total - inv_bytes + hyb_bytes
    emit("space_hyb_bpc", hyb_total / N, f"MiB={hyb_total/2**20:.2f}")
    emit("space_fwd_ef_bpc", (fwd_total - inv_bytes + inv_ef_bytes) / N,
         f"EF_postings;MiB={(fwd_total - inv_bytes + inv_ef_bytes)/2**20:.2f}")
    for name, b in [("dictionary", d_bytes), ("completions", comp_bytes),
                    ("rmq_docids", rmq_doc), ("inverted", inv_bytes),
                    ("forward", fwd_bytes), ("rmq_minimal", rmq_min),
                    ("fc_completions", fc_comp.encoded_bytes())]:
        emit(f"space_component_{name}", b / N, f"MiB={b/2**20:.2f}")


if __name__ == "__main__":
    main()
