"""Cluster saturation + failover bench (ISSUE 8 tentpole).

Open-loop offered-QPS sweep over the multi-replica serving cluster
(serve/cluster.py): the SAME keystroke request set replayed at increasing
arrival pressure (text.synth target_qps rescales the time axis only), per
replica count. Emits:

  * ``qac_cluster_max_qps_sla50_r{R}`` — the saturation point: the highest
    offered QPS where interactive p99 stays inside the 50 ms SLA with at
    most 2% shed, for R = 1 and 2 replicas.
  * ``qac_cluster_shed_rate`` — measured shed rate at 2x the saturation
    QPS with admission control on: the overload the controller absorbs.
  * ``qac_cluster_failover_p99_us`` — re-routed-request p99 under a
    kill-mid-trace drill (detection + failover latency included).

Acceptance gates, enforced here:
  * at 2x saturation the admission controller keeps interactive p99 within
    the SLA with a NONZERO shed/degrade rate, while the unbounded-queue
    baseline (thresholds off) blows the SLA on the same trace;
  * the kill drill re-routes traffic (rerouted > 0) and every served
    answer stays bit-identical to the uncached frontend oracle
    (check_cluster_parity) — failover loses caches, never correctness.
"""
from __future__ import annotations

import os
import sys

if "--quick" in sys.argv:               # before .common reads BENCH_QUICK
    os.environ["BENCH_QUICK"] = "1"

import numpy as np

from .common import bench_corpus, emit, timer, QUICK, write_bench_json
from repro.core import parse_queries
from repro.runtime.fault import FaultInjector, ReplicaFault
from repro.serve.cluster import (ClusterConfig, QACServingCluster,
                                 assign_sla, check_cluster_parity)
from repro.serve.frontend import QACFrontend
from repro.serve.runtime import (QACOnlineRuntime, RuntimeConfig,
                                 prepare_requests)
from repro.text import KeystrokeTraceConfig, generate_keystroke_trace

SLA_US = 50_000.0           # the paper-motivated interactive deadline
SHED_CAP = 0.02             # "serving" means rejecting at most 2%
REPLICA_COUNTS = (1, 2)
LADDER_GROWTH = 1.6
MAX_LADDER_STEPS = 12


def _cluster_cfg(R: int, *, admission: bool = True) -> ClusterConfig:
    if not admission:
        # the unbounded baseline: no pressure ladder, effectively no bound
        return ClusterConfig(n_replicas=R, max_queue=1 << 20,
                             degrade_pressure_us=1e15,
                             shed_bulk_pressure_us=1e15,
                             shed_pressure_us=1e15)
    # the ladder sits well inside the SLA: admitted wait stays under
    # 0.6*SLA, leaving batching slack + service + estimator error as margin
    return ClusterConfig(n_replicas=R, max_queue=4096,
                         degrade_pressure_us=0.3 * SLA_US,
                         shed_bulk_pressure_us=0.45 * SLA_US,
                         shed_pressure_us=0.6 * SLA_US,
                         degraded_k=4)


def _run_point(qidx, kept, fe, rt_cfg, base_cfg, R, qps, *,
               admission=True, injector=None):
    trace = generate_keystroke_trace(
        kept, KeystrokeTraceConfig(**base_cfg, target_qps=qps))
    reqs = prepare_requests(qidx, trace, k=10)
    sla = assign_sla(reqs, bulk_fraction=0.25)
    cluster = QACServingCluster(
        qidx, _cluster_cfg(R, admission=admission), rt_cfg,
        frontends=[fe] * R, injector=injector)
    res = cluster.run_trace(reqs, sla)
    return cluster, reqs, res, cluster.telemetry.snapshot()


def main():
    qidx, kept, host, rows, d_of_row = bench_corpus()
    fe = QACFrontend(qidx, k=10, specialize_list_pad=False)
    rt_cfg = RuntimeConfig(max_batch=64, slack_us=2_000.0)
    # the trace must carry total service work of SEVERAL x the SLA, or an
    # unbounded queue can never accumulate an SLA-violating backlog and
    # "saturation" is unmeasurable — size sessions accordingly
    base_cfg = dict(n_sessions=64 if QUICK else 96,
                    queries_per_session=1 if QUICK else 2, seed=51)

    # one warmup compiles every pow2 (engine, bucket, k) variant the sweep
    # can form — the frontend's pow2 bucketing closes the space, so every
    # later point (any replica count, any QPS) runs jit-warm. The k=4 pass
    # covers the DEGRADED tier: admission clamps k to degraded_k under
    # pressure, and an unwarmed k-bucket would bill XLA compiles to the
    # virtual clock right when the cluster is already overloaded,
    # snowballing fake pressure
    base_trace = generate_keystroke_trace(kept, KeystrokeTraceConfig(**base_cfg))
    probe = prepare_requests(qidx, base_trace, k=10)
    QACOnlineRuntime(fe, rt_cfg).warmup(probe)
    QACOnlineRuntime(fe, rt_cfg).warmup(
        prepare_requests(qidx, base_trace, k=_cluster_cfg(1).degraded_k))
    n_reqs = len(probe)

    # calibrate the ladder start from the real engine cost: one warm
    # batch-16 dispatch -> per-request service -> rough per-replica
    # capacity; the ladder then brackets saturation wherever it truly is
    sample = probe[:16]
    args = (np.stack([r.pids for r in sample]),
            np.asarray([r.plen for r in sample], np.int32),
            np.stack([r.suf for r in sample]),
            np.asarray([r.slen for r in sample], np.int32))
    t16 = timer(lambda: np.asarray(fe.complete(*args, k=10)), repeats=5)
    cap_qps = 16.0 / t16
    print(f"# calibration: {t16/16*1e6:.0f} us/req at B=16 "
          f"-> ~{cap_qps:.0f} QPS/replica ceiling, trace n={n_reqs}")

    max_qps = {}
    for R in REPLICA_COUNTS:
        qps = max(cap_qps * R / 8.0, 20.0)
        best = None
        best_snap = None
        for _ in range(MAX_LADDER_STEPS):
            # best-of-2: one slow wall-clock dispatch (this is a shared
            # box) becomes real virtual backlog and can fake a saturation
            # point; a load the cluster serves cleanly in EITHER attempt
            # is below saturation
            for attempt in range(2):
                _, _, _, s = _run_point(qidx, kept, fe, rt_cfg, base_cfg,
                                        R, qps)
                ok = (s["interactive_p99_us"] <= SLA_US
                      and s["shed_rate"] <= SHED_CAP)
                if ok:
                    break
            print(f"#   r{R} offered={qps:7.0f} qps: interactive_p99="
                  f"{s['interactive_p99_us']/1e3:7.1f}ms "
                  f"shed={s['shed_rate']:.3f} "
                  f"degrade={s['degrade_rate']:.3f} {'OK' if ok else 'SAT'}")
            if not ok:
                break
            best, best_snap = qps, s
            qps *= LADDER_GROWTH
        assert best is not None, \
            f"r{R}: even the lowest offered load missed the SLA"
        max_qps[R] = best
        emit(f"qac_cluster_max_qps_sla50_r{R}", best,
             f"interactive_p99_us={best_snap['interactive_p99_us']:.0f},"
             f"shed={best_snap['shed_rate']:.4f},n={n_reqs}")

    # -- overload: admission control vs the unbounded baseline ---------------
    # Start at 2x the measured saturation and escalate until the UNBOUNDED
    # baseline demonstrably violates the SLA on this box (saturation
    # measured under admission control is an earlier, service-quality
    # knee — the baseline's raw-capacity knee can sit higher), then hold
    # the admission-controlled cluster to the SLA at that same load.
    R = 2
    over_qps = 2.0 * max_qps[R]
    for _ in range(4):
        _, _, _, s_off = _run_point(qidx, kept, fe, rt_cfg, base_cfg,
                                    R, over_qps, admission=False)
        if s_off["interactive_p99_us"] > SLA_US:
            break
        over_qps *= LADDER_GROWTH
    assert s_off["interactive_p99_us"] > SLA_US, \
        (f"unbounded baseline still met the SLA at {over_qps:.0f} qps "
         f"(p99={s_off['interactive_p99_us']/1e3:.1f}ms) — no overload found")
    cl, reqs, res, s_on = _run_point(qidx, kept, fe, rt_cfg, base_cfg,
                                     R, over_qps)
    emit("qac_cluster_shed_rate", s_on["shed_rate"],
         f"offered_qps={over_qps:.0f},degrade_rate={s_on['degrade_rate']:.3f},"
         f"interactive_p99_us={s_on['interactive_p99_us']:.0f},"
         f"baseline_p99_us={s_off['interactive_p99_us']:.0f}")
    emit("qac_cluster_overload_p99_us", s_on["interactive_p99_us"],
         f"baseline={s_off['interactive_p99_us']:.0f},"
         f"sheds={s_on['shed']}")
    n_ok = check_cluster_parity(fe, reqs, res)
    assert n_ok == s_on["served"], "parity checked fewer rows than served"
    assert s_on["interactive_p99_us"] <= SLA_US, \
        (f"admission control missed the SLA at {over_qps:.0f} qps: "
         f"p99={s_on['interactive_p99_us']/1e3:.1f}ms > {SLA_US/1e3:.0f}ms")
    assert s_on["shed_rate"] + s_on["degrade_rate"] > 0, \
        "overload produced no shed/degrade — the controller never engaged"

    # -- kill drill at a comfortable load ------------------------------------
    drill_qps = 0.5 * max_qps[R]
    trace = generate_keystroke_trace(
        kept, KeystrokeTraceConfig(**base_cfg, target_qps=drill_qps))
    t_mid = sorted(t for t, _, _ in trace)[len(trace) // 2]
    inj = FaultInjector([], replica_faults=[
        ReplicaFault(0, t_mid, t_mid + 300_000.0)])
    drill_cfg = ClusterConfig(n_replicas=R, max_queue=4096,
                              degrade_pressure_us=1e15,
                              shed_bulk_pressure_us=1e15,
                              shed_pressure_us=1e15,
                              heartbeat_timeout_us=100_000.0)
    reqs_d = prepare_requests(qidx, trace, k=10)
    cl_d = QACServingCluster(qidx, drill_cfg, rt_cfg, frontends=[fe] * R,
                             injector=inj)
    res_d = cl_d.run_trace(reqs_d)
    s_d = cl_d.telemetry.snapshot()
    served_d = sum(r.status == "ok" for r in res_d)
    assert check_cluster_parity(fe, reqs_d, res_d) == served_d
    assert s_d["rerouted"] > 0, "kill drill produced no re-routed traffic"
    assert s_d["deaths"], "kill drill death went undetected"
    emit("qac_cluster_failover_p99_us", s_d["failover_p99_us"],
         f"rerouted={s_d['rerouted']},deaths={len(s_d['deaths'])},"
         f"readmits={len(s_d['readmissions'])},served={served_d},"
         f"offered_qps={drill_qps:.0f}")

    write_bench_json()


if __name__ == "__main__":
    main()
