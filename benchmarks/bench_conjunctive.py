"""Paper Table 5: conjunctive-search engine timings by query length and
suffix percentage.

Engines (per DESIGN.md §2): the paper's own algorithms run host-side
(Heap = Fig 3, Fwd = Fig 5, FC = Fig 5 + front-coded extraction) as the CPU
baselines, and the TPU-batched JAX Fwd path (jax_fwd) is the production
engine — reported as amortized us/query at batch 256.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .common import bench_corpus, sample_eval_queries, timer, emit, QUICK
from repro.core import parse_queries, conjunctive_multi, single_term_topk
from repro.core.fc import FrontCodedStore


def main():
    from repro.core.ref_engines import HybIndex
    qidx, kept, host, rows, d_of_row = bench_corpus()
    k = 10
    fc_store = FrontCodedStore.build(list(kept), bucket_size=16, max_chars=96)
    hyb = HybIndex(host, c=1e-2)   # paper's best c ~ 1e-4 of a 10M log

    # host-side FC extraction for the FC engine
    import bisect
    lex_sorted = list(kept)

    def fc_extract_terms(docid):
        # docid -> lex id -> decode string -> term ids via host dict
        return [int(t) for t in host.fwd[docid] if t]

    pcts = (25, 75) if QUICK else (0, 25, 50, 75)
    for pct in pcts:
        buckets = sample_eval_queries(kept, pct, n_per_bucket=10 if QUICK else 24)
        for d, queries in sorted(buckets.items()):
            if d > 7 or not queries:
                continue
            pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, queries)
            tl, tr = qidx.dictionary.locate_prefix(suf, slen)
            tl_h, tr_h = np.asarray(tl), np.asarray(tr)
            prefixes = [[int(x) for x in np.asarray(pids[i]) if x]
                        for i in range(len(queries))]

            def run_host(engine, cap=None):
                m = cap or len(queries)
                for i in range(m):
                    engine(prefixes[i], int(tl_h[i]), int(tr_h[i]), k)

            n = len(queries)
            # Heap with a 1-char suffix walks thousands of python-heap lists;
            # subsample it (the paper's point is exactly that it is slow there)
            n_heap = min(n, 6 if pct == 0 else 16)
            t_heap = timer(lambda: run_host(host.heap_conjunctive, n_heap),
                           repeats=2) / n_heap
            t_fwd = timer(run_host, host.fwd_conjunctive, repeats=3) / n

            def fc_engine(prefix, lo, hi, kk):
                return host.fwd_conjunctive(prefix, lo, hi, kk,
                                            extract=fc_extract_terms)

            t_fc = timer(lambda: run_host(fc_engine), repeats=3) / n
            n_hyb = min(n, 6 if pct == 0 else 16)
            t_hyb = timer(lambda: run_host(hyb.conjunctive, n_hyb),
                          repeats=2) / n_hyb

            # JAX batched path (jit once per shape, amortized)
            B = len(queries)
            fn = jax.jit(jax.vmap(
                lambda a, b, c_, d_: jnp.where(
                    b > 0,
                    conjunctive_multi(qidx.index, qidx.completions, a, b, c_, d_, k),
                    single_term_topk(qidx.index, qidx.rmq_minimal, c_, d_, k))))
            fn(pids, plen, tl, tr)[0].block_until_ready()
            t_jax = timer(lambda: fn(pids, plen, tl, tr).block_until_ready(),
                          repeats=3, warmup=0) / n
            emit(f"conj_heap_d{d}_{pct}pct", t_heap * 1e6, "")
            emit(f"conj_hyb_d{d}_{pct}pct", t_hyb * 1e6, "")
            emit(f"conj_fwd_d{d}_{pct}pct", t_fwd * 1e6, "")
            emit(f"conj_fc_d{d}_{pct}pct", t_fc * 1e6, "")
            emit(f"conj_jaxfwd_d{d}_{pct}pct", t_jax * 1e6, f"batch={B}")


if __name__ == "__main__":
    main()
