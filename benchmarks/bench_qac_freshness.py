"""Freshness-tier bench (ISSUE 9): live-update cost + post-swap recovery.

Three measurements over the generational serving layer
(serve/freshness.py), emitted into BENCH_qac.json:

  * ``qac_freshness_apply_p99_us`` — p99 wall time of a single live insert
    into the delta tier (tokenize + dictionary lookup + shadow detection +
    append-only postings), over a mixed stream of new inserts and trend
    raises with no swaps. This is the "trending query becomes suggestible"
    latency — the number the offline-rebuild world cannot have.
  * ``qac_freshness_swap_stall_p99_us`` — p99 of the swap STALL (drain +
    absorb + install) across a mutation-trace replay with at least one
    mid-trace rebuild-and-swap. The rebuild itself runs "in background"
    and is reported (not gated) as derived info.
  * ``qac_freshness_hit_rate_recovery`` — post-swap cache hit rate over
    pre-swap hit rate, from the runtime's per-generation telemetry. A swap
    flushes both cache tiers exactly once; keystroke locality must re-warm
    them within the same trace.

Acceptance gates, enforced here:
  * every sampled answer of the swap trace is bit-identical to a
    from-scratch build at its visible (generation, seq) version
    (``GenerationalQAC.check_parity``), the trace swaps >= 1 time, each
    swap invalidates each cache tier exactly once, and the delta tier
    serves a nonzero number of answers;
  * hit-rate recovery >= 0.5;
  * the merged single-term path at B=256 (parse + main engine + per-row
    delta merge, keys ``qac_freshness_merged_single_b256_us`` /
    ``qac_freshness_immutable_single_b256_us``) stays <= 1.5x the
    immutable-only path (parse + main engine) on the same batch.
"""
from __future__ import annotations

import os
import sys

if "--quick" in sys.argv:               # before .common reads BENCH_QUICK
    os.environ["BENCH_QUICK"] = "1"

import numpy as np

from .common import bench_corpus, emit, timer, QUICK, write_bench_json
from repro.obs.metrics import percentiles
from repro.serve.freshness import FreshnessConfig, GenerationalQAC
from repro.serve.runtime import RuntimeConfig
from repro.text import (KeystrokeTraceConfig, MutationTraceConfig,
                        generate_mutation_trace)

MERGE_OVERHEAD_CAP = 1.5     # merged single-term path vs immutable, B=256
RECOVERY_FLOOR = 0.5         # post-swap hit rate vs pre-swap


def _base_scores(kept):
    # deterministic frequency-like scores for the canonical corpus (the
    # bench corpus helper returns kept strings; scores only shape trend
    # targets here)
    rng = np.random.default_rng(13)
    return rng.zipf(1.3, size=len(kept)).astype(np.float64)


def main():
    qidx, kept, host, rows, d_of_row = bench_corpus()
    kept = list(kept)
    scores = _base_scores(kept)
    rt_cfg = RuntimeConfig(max_batch=64, slack_us=2_000.0)

    # -- apply latency: mixed insert/trend stream, no swaps ------------------
    n_apply = 200 if QUICK else 500
    cap = max(2 * n_apply, 4096)
    gq = GenerationalQAC(kept, scores, rt_cfg=rt_cfg, cfg=FreshnessConfig(
        k=10, delta_capacity=cap, swap_threshold=cap))
    rng = np.random.default_rng(7)
    vocab = sorted({t for q in kept for t in q.split()})
    for i in range(n_apply):
        if i % 3 == 0:      # trend raise on an existing completion
            q = kept[int(rng.integers(0, len(kept)))]
            gq.insert(q, float(scores.max()) + i + 1.0, t_us=float(i))
        else:               # new completion from recombined vocab
            toks = [vocab[int(j)] for j in
                    rng.integers(0, len(vocab), size=int(rng.integers(1, 4)))]
            gq.insert(" ".join(toks), float(np.median(scores)) + 1.0,
                      t_us=float(i))
    ap = percentiles([a["wall_us"] for a in gq.apply_log], (50, 99))
    outcomes = gq.snapshot()["mutation_outcomes"]
    emit("qac_freshness_apply_p99_us", ap["p99_us"],
         f"p50={ap['p50_us']:.0f},n={n_apply},"
         f"outcomes={'/'.join(f'{k}:{v}' for k, v in sorted(outcomes.items()))}")

    # -- merged vs immutable single-term path at B=256 -----------------------
    # the delta above is warm (hundreds of live entries) — exactly the
    # state the merge must stay cheap in
    B = 256
    rng2 = np.random.default_rng(11)
    singles = []
    for qi in rng2.integers(0, len(kept), B):
        t0 = kept[qi].split()[0]
        singles.append(t0[: max(1, int(rng2.integers(1, len(t0) + 1)))])
    g = gq.history[gq.rt.generation]

    def immutable():
        from repro.serve.freshness import parse_and_prepare
        reqs = parse_and_prepare(g.qidx, [(0.0, 0, q) for q in singles], k=10)
        return np.asarray(g.frontend.complete(
            np.stack([r.pids for r in reqs]),
            np.asarray([r.plen for r in reqs], np.int32),
            np.stack([r.suf for r in reqs]),
            np.asarray([r.slen for r in reqs], np.int32), k=10))

    def merged():
        return gq.complete_batch(singles, k=10)

    t_imm = timer(immutable, repeats=5, warmup=2) / B * 1e6
    t_mrg = timer(merged, repeats=5, warmup=2) / B * 1e6
    emit("qac_freshness_immutable_single_b256_us", t_imm,
         f"delta_n={g.delta.n}")
    emit("qac_freshness_merged_single_b256_us", t_mrg,
         f"overhead={t_mrg / t_imm:.2f}x,cap={MERGE_OVERHEAD_CAP}x")
    assert t_mrg <= MERGE_OVERHEAD_CAP * t_imm, \
        (f"merged single-term path {t_mrg:.1f}us/q exceeds "
         f"{MERGE_OVERHEAD_CAP}x immutable {t_imm:.1f}us/q at B={B}")

    # -- swap trace: stall + hit-rate recovery + time-indexed parity ---------
    # small max_batch keeps each new generation's jit-variant warm sweep
    # (part of rebuild_wall_us) to a few buckets per engine class — the
    # recovery/stall numbers don't depend on batch shaping
    n_mut = 16
    swap_thr = max(2, n_mut // 2)       # one swap near mid-trace
    rt_small = RuntimeConfig(max_batch=8, slack_us=2_000.0)
    gq2 = GenerationalQAC(kept, scores, rt_cfg=rt_small, cfg=FreshnessConfig(
        k=10, delta_capacity=4096, swap_threshold=swap_thr))
    events = generate_mutation_trace(kept, scores, MutationTraceConfig(
        keystrokes=KeystrokeTraceConfig(
            n_sessions=24 if QUICK else 48, mean_keystroke_ms=5.0, seed=51),
        n_mutations=n_mut, follower_sessions=8, seed=3))
    results = gq2.replay(events)
    s = gq2.snapshot()
    rts = s["runtime"]
    assert s["n_swaps"] >= 1, "swap trace produced no generation swap"
    for key, inv in rts["invalidations"].items():
        assert inv["count"] == 1, \
            f"swap {key} invalidated caches {inv['count']} times"
    assert len(rts["invalidations"]) == s["n_swaps"], \
        "each swap must invalidate the cache tiers exactly once"
    assert s["delta_hit_answers"] > 0, "no answer used the delta tier"
    n_par = gq2.check_parity(results,
                             sample_every=max(1, len(results) // 150))
    stalls = [sw["swap_stall_us"] for sw in gq2.swap_log]
    rebuilds = [sw["rebuild_wall_us"] for sw in gq2.swap_log]
    emit("qac_freshness_swap_stall_p99_us",
         percentiles(stalls, (99,))["p99_us"],
         f"swaps={s['n_swaps']},rebuild_p50_ms="
         f"{percentiles(rebuilds, (50,))['p50_us']/1e3:.0f},parity_n={n_par}")

    def hit_rate(paths: dict) -> float:
        n = sum(paths.values())
        return (paths.get("hit_exact", 0) + paths.get("hit_session", 0)) / max(n, 1)

    per_gen = rts["per_generation"]
    pre = hit_rate(per_gen.get(0, {}))
    post_paths = {}
    for g_id, paths in per_gen.items():
        if g_id == 0:
            continue
        for p, c in paths.items():
            post_paths[p] = post_paths.get(p, 0) + c
    post = hit_rate(post_paths)
    recovery = post / max(pre, 1e-9)
    emit("qac_freshness_hit_rate_recovery", recovery,
         f"pre={pre:.3f},post={post:.3f},floor={RECOVERY_FLOOR}")
    assert pre > 0, "pre-swap trace produced no cache hits to recover from"
    assert recovery >= RECOVERY_FLOOR, \
        (f"post-swap hit rate {post:.3f} recovered only {recovery:.2f}x of "
         f"pre-swap {pre:.3f} (floor {RECOVERY_FLOOR})")

    write_bench_json()


if __name__ == "__main__":
    main()
