"""§Roofline reader: summarize dry-run records into the roofline table."""
from __future__ import annotations

import json
import os

from .common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "src", "repro",
                       "launch", "dryrun_results")


def load_records():
    recs = []
    for mesh in ("pod16x16", "pod2x16x16"):
        d = os.path.join(RESULTS, mesh)
        if not os.path.isdir(d):
            continue
        for f in sorted(os.listdir(d)):
            if f.endswith(".json"):
                with open(os.path.join(d, f)) as fh:
                    recs.append(json.load(fh))
    return recs


def main():
    recs = load_records()
    if not recs:
        print("# no dry-run results yet — run python -m repro.launch.dryrun")
        return
    for r in recs:
        if not r.get("ok") or "skipped" in r:
            continue
        name = f"roofline_{r['mesh']}_{r['arch']}_{r['shape']}"
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        emit(name, bound * 1e6,
             f"dom={r['dominant']};compute_s={r['compute_s']:.3e};"
             f"memory_s={r['memory_s']:.3e};collective_s={r['collective_s']:.3e};"
             f"frac={r.get('roofline_frac')}")


if __name__ == "__main__":
    main()
