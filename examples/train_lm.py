"""Train a (reduced) smollm for a few hundred steps with the full stack:
data pipeline, jit'd train step, checkpointing, fault drill.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.argv = [sys.argv[0], "--arch", "smollm-360m",
            "--steps", sys.argv[sys.argv.index("--steps") + 1]
            if "--steps" in sys.argv else "200",
            "--ckpt-dir", "/tmp/repro_lm_ckpt", "--drill"]

from repro.launch.train import main

main()
