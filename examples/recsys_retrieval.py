"""MIND multi-interest retrieval end-to-end: train briefly on synthetic
behavior logs, then retrieve top-k from 100k candidates.

  PYTHONPATH=src python examples/recsys_retrieval.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.configs.recsys_common import MODEL_CLS
from repro.data.recsys_data import recsys_batch
from repro.models.recsys import bce_loss
from repro.optim.adamw import AdamWConfig
from repro.train.steps import init_train_state, make_recsys_train_step

arch = get_arch("mind")
cfg = arch.smoke_cfg
model = MODEL_CLS[cfg.kind](cfg)
params = model.init_params(jax.random.PRNGKey(0))
state = init_train_state(params)
step = jax.jit(make_recsys_train_step(model, AdamWConfig(lr=1e-3, total_steps=100)))
rng = np.random.default_rng(0)
for i in range(100):
    feats, labels = recsys_batch(cfg, 128, rng)
    batch = {"feats": {k: jnp.asarray(v) for k, v in feats.items()},
             "labels": jnp.asarray(labels)}
    state, metrics = step(state, batch)
    if i % 25 == 0:
        print(f"step {i} loss {float(metrics['loss']):.4f}")

feats, _ = recsys_batch(cfg, 8, rng)
feats = {k: jnp.asarray(v) for k, v in feats.items()}
cand = jax.random.normal(jax.random.PRNGKey(1), (100_000, cfg.embed_dim))
scores, idx = model.retrieve(state.params, feats, cand, k=10)
print("retrieved top-10 per user:", np.asarray(idx)[:2])
print("scores:", np.round(np.asarray(scores)[:2], 3))
