"""Quickstart: build a QAC index from a scored query log and complete queries.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax.numpy as jnp

from repro.core import (build_qac_index, parse_queries, INF_DOCID,
                        prefix_search_topk, conjunctive_multi)
from repro.serve.qac import qac_serve_step
from repro.core.strings import decode_string

# the paper's Table 1 example corpus, scores descending by listed order
log = ["audi", "audi a3 sport", "audi q8 sedan", "bmw", "bmw x1",
       "bmw i3 sedan", "bmw i3 sport", "bmw i3 sportback", "bmw i8 sport"]
scores = [9, 6, 3, 8, 5, 1, 4, 2, 7]  # higher = better
scores = [10 - s for s in scores]      # docid order of the paper

qidx, kept, _ = build_qac_index(log, scores)


def show(query: str):
    pids, plen, ok, suf, slen = parse_queries(qidx.dictionary, [query])
    docids = np.asarray(qac_serve_step(qidx, pids, plen, suf, slen, k=3))[0]
    out = []
    for d in docids:
        if d == INF_DOCID:
            break
        terms, n = qidx.completions.extract(jnp.int32(int(d)))
        chars = qidx.dictionary.extract(terms[: int(n)])
        out.append(" ".join(decode_string(np.asarray(c)) for c in np.asarray(chars)))
    print(f"{query!r:18s} -> {out}")


print("conjunctive-search completions (paper Fig 1b):")
show("bmw i3 s")     # prefix-search also finds these
show("sport")        # single-term: prefix-search finds nothing better
show("i3")           # no completion STARTS with i3 — conjunctive still answers
show("bmw sport i8") # out-of-order terms
