"""End-to-end serving driver (the paper's kind of system is a serving one):
build a ~20k-completion index, replay a keystroke stream in batches, report
throughput + effectiveness vs prefix-search.

  PYTHONPATH=src python examples/qac_serving.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time
import numpy as np
import jax
import jax.numpy as jnp

from repro.text import SynthLogConfig, generate_query_log
from repro.core import build_qac_index, parse_queries, INF_DOCID
from repro.serve.qac import qac_serve_step

qs, sc = generate_query_log(SynthLogConfig(n_queries=20_000, seed=1))
qidx, kept, _ = build_qac_index(qs, sc)
print(f"index: {qidx.completions.n} completions, {qidx.dictionary.n_terms} terms")

# keystroke replay: every prefix of 64 random queries, batched
rng = np.random.default_rng(0)
stream = []
for qi in rng.integers(0, len(kept), 64):
    q = kept[qi]
    for cut in range(1, len(q) + 1):
        if not q[:cut].endswith(" "):
            stream.append(q[:cut])
B = 256
fn = jax.jit(lambda a, b, c, d: qac_serve_step(qidx, a, b, c, d, k=10))
total, t_total, answered = 0, 0.0, 0
for i in range(0, len(stream) - B, B):
    batch = stream[i : i + B]
    pids, plen, ok, suf, slen = parse_queries(qidx.dictionary, batch)
    t0 = time.time()
    out = fn(pids, plen, suf, slen).block_until_ready()
    t_total += time.time() - t0
    total += B
    answered += int((np.asarray(out)[:, 0] != INF_DOCID).sum())
print(f"served {total} keystrokes in {t_total:.2f}s "
      f"({total/t_total:.0f} QPS host-CPU, batch {B}); "
      f"coverage {100*answered/total:.1f}%")
