"""End-to-end serving driver (the paper's kind of system is a serving one):
build a ~20k-completion index, then serve keystroke traffic two ways —

  part 1: offline batch replay of a keystroke stream (throughput view);
  part 2 (ISSUE 4): the ONLINE runtime — timestamped requests from
    concurrent typing sessions flow through the deadline-aware
    micro-batching scheduler + prefix/session caches, and per-request
    latency (p50/p99) is compared against naive one-request-per-dispatch
    serving with bit-identical results;
  part 3 (ISSUE 8): the CLUSTER — two runtime replicas behind a
    session-affinity dispatcher take the same trace at overload with
    admission control (SLA-class degrade/shed), then again with a replica
    KILLED mid-trace: the death is detected, its traffic re-routed, and
    every served answer stays bit-identical to the uncached oracle;
  part 4 (ISSUE 9): the LIVE index — keystroke traffic interleaved with
    corpus mutations (trending score bumps + newly observed completions)
    flows through the freshness tier: the delta index absorbs inserts in
    microseconds, answers are exact k-way merges of both tiers, a
    mid-trace rebuild-and-swap installs the next generation (caches
    invalidate exactly once), and sampled answers are verified
    bit-identical to from-scratch rebuilds at their visible versions;
  part 5 (ISSUE 10): OBSERVABILITY — part 2's trace replayed with request
    tracing on (1/4 sampling): still bit-identical, and the spans alone
    reconstruct where the latency went — a per-stage budget table, the
    slowest sampled request's waterfall, and the multi-window SLO
    burn-rate summary over the 50 ms interactive objective.

  PYTHONPATH=src python examples/qac_serving.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time
import numpy as np
import jax
import jax.numpy as jnp

from repro.text import SynthLogConfig, generate_query_log
from repro.core import build_qac_index, parse_queries, INF_DOCID
from repro.serve.qac import qac_serve_step

qs, sc = generate_query_log(SynthLogConfig(n_queries=20_000, seed=1))
qidx, kept, kept_sc = build_qac_index(qs, sc)
print(f"index: {qidx.completions.n} completions, {qidx.dictionary.n_terms} terms")

# keystroke replay: every prefix of 64 random queries, batched
rng = np.random.default_rng(0)
stream = []
for qi in rng.integers(0, len(kept), 64):
    q = kept[qi]
    for cut in range(1, len(q) + 1):
        if not q[:cut].endswith(" "):
            stream.append(q[:cut])
B = 256
fn = jax.jit(lambda a, b, c, d: qac_serve_step(qidx, a, b, c, d, k=10))
total, t_total, answered = 0, 0.0, 0
for i in range(0, len(stream) - B, B):
    batch = stream[i : i + B]
    pids, plen, ok, suf, slen = parse_queries(qidx.dictionary, batch)
    t0 = time.time()
    out = fn(pids, plen, suf, slen).block_until_ready()
    t_total += time.time() - t0
    total += B
    answered += int((np.asarray(out)[:, 0] != INF_DOCID).sum())
print(f"served {total} keystrokes in {t_total:.2f}s "
      f"({total/t_total:.0f} QPS host-CPU, batch {B}); "
      f"coverage {100*answered/total:.1f}%")

# -- part 2: the online runtime (ISSUE 4) ------------------------------------
# Requests now ARRIVE one at a time: 48 concurrent sessions type Zipf-popular
# queries keystroke by keystroke (Poisson inter-arrival, occasional
# backspaces). The runtime forms deadline-bounded micro-batches over
# QACFrontend's pow2 buckets and serves repeated/extended prefixes from the
# exact-prefix LRU + the session filter-first fast path — bit-identical to
# dispatching every request alone, at a fraction of the latency.
from repro.text import KeystrokeTraceConfig, generate_keystroke_trace
from repro.serve.frontend import QACFrontend
from repro.serve.runtime import (QACOnlineRuntime, RuntimeConfig,
                                 prepare_requests, run_naive_trace)

trace = generate_keystroke_trace(kept, KeystrokeTraceConfig(
    n_sessions=48, mean_keystroke_ms=120.0, seed=2))
reqs = prepare_requests(qidx, trace, k=10)
print(f"\nonline: {len(reqs)} timestamped keystroke requests, 48 sessions")
rt = QACOnlineRuntime(QACFrontend(qidx, k=10, specialize_list_pad=False),
                      RuntimeConfig(max_batch=64, slack_us=20_000.0))
rows = rt.replay(reqs)      # warm variants + warm pass + reset + measured
s = rt.telemetry.snapshot()
print(f"online: p50={s['p50_us']:.0f}us p95={s['p95_us']:.0f}us "
      f"p99={s['p99_us']:.0f}us  hit_rate={s['cache_hit_rate']:.2f} "
      f"(exact={s['paths'].get('hit_exact', 0)}, "
      f"session={s['paths'].get('hit_session', 0)}); "
      f"{s['n_batches']} engine batches, mean size "
      f"{s['mean_batch_size']:.1f}")
naive_rows, naive = run_naive_trace(rt.fe, reqs)  # complete() is pure
assert all(np.array_equal(g, w) for g, w in zip(rows, naive_rows))
print(f"online: bit-identical to per-request dispatch; mean latency "
      f"{s['mean_us']:.0f}us vs naive {naive['mean_us']:.0f}us "
      f"({naive['mean_us']/max(s['mean_us'], 1e-9):.1f}x)")

# -- part 3: overload + failover on the cluster (ISSUE 8) --------------------
# Two replicas behind a rendezvous-hash session-affinity dispatcher. First,
# the SAME request set compressed onto a 10x denser time axis (target_qps)
# with the admission ladder armed: 75% of sessions are `interactive` (SLA
# traffic, degraded to a smaller k before ever being shed), 25% `bulk`
# (scrapers — first to lose multi-term service, first shed). Then a fault
# drill: replica 0 is killed mid-trace; the heartbeat registry detects the
# death, in-flight + queued work re-routes to the survivor (whose caches
# never saw those sessions — answers must still be bit-identical), and the
# replica is re-admitted once its fault window closes.
from repro.runtime.fault import FaultInjector, ReplicaFault
from repro.serve.cluster import (ClusterConfig, QACServingCluster,
                                 assign_sla, check_cluster_parity)

sla = assign_sla(reqs, bulk_fraction=0.25)
base_qps = len(reqs) / (max(r.t_us for r in reqs) / 1e6)
hot = generate_keystroke_trace(kept, KeystrokeTraceConfig(
    n_sessions=48, mean_keystroke_ms=120.0, seed=2,
    target_qps=10.0 * base_qps))
hot_reqs = prepare_requests(qidx, hot, k=10)
cl = QACServingCluster(
    qidx,
    ClusterConfig(n_replicas=2, degrade_pressure_us=15_000.0,
                  shed_bulk_pressure_us=22_500.0, shed_pressure_us=30_000.0,
                  degraded_k=4),
    RuntimeConfig(max_batch=64, slack_us=2_000.0),
    frontends=[rt.fe, rt.fe])           # complete() is pure: share the warm fe
res = cl.replay(hot_reqs, assign_sla(hot_reqs, bulk_fraction=0.25))
cs = cl.telemetry.snapshot()
print(f"\ncluster: 2 replicas at {10*base_qps:.0f} qps offered — "
      f"served={cs['served']} shed_rate={cs['shed_rate']:.2f} "
      f"degrade_rate={cs['degrade_rate']:.2f}; interactive "
      f"p99={cs['interactive_p99_us']/1e3:.1f}ms, bulk "
      f"p99={cs['bulk_p99_us']/1e3:.1f}ms, sheds={dict(cs['shed'])}")
n_ok = check_cluster_parity(rt.fe, hot_reqs, res)
print(f"cluster: all {n_ok} served rows bit-identical to the uncached oracle")

t_mid = sorted(r.t_us for r in reqs)[len(reqs) // 2]
inj = FaultInjector([], replica_faults=[
    ReplicaFault(0, t_mid, t_mid + 500_000.0)])   # killed for 500 ms
cl_d = QACServingCluster(
    qidx,
    ClusterConfig(n_replicas=2, degrade_pressure_us=1e12,
                  shed_bulk_pressure_us=1e12, shed_pressure_us=1e12,
                  heartbeat_timeout_us=100_000.0),
    RuntimeConfig(max_batch=64, slack_us=2_000.0),
    frontends=[rt.fe, rt.fe], injector=inj)
res_d = cl_d.replay(reqs, sla)
ds = cl_d.telemetry.snapshot()
served_d = sum(r.status == "ok" for r in res_d)
assert check_cluster_parity(rt.fe, reqs, res_d) == served_d
assert ds["rerouted"] > 0 and ds["deaths"]
print(f"drill: replica 0 killed at t={t_mid/1e3:.0f}ms — detected at "
      f"t={ds['deaths'][0][0]/1e3:.0f}ms, {ds['rerouted']} requests "
      f"re-routed (failover p99={ds['failover_p99_us']/1e3:.1f}ms), "
      f"{len(ds['readmissions'])} readmission(s); all {served_d} served "
      f"answers bit-identical through the failover")

# -- part 4: the live index (ISSUE 9) ----------------------------------------
# The corpus now MUTATES mid-trace: trending completions spike, new ones
# appear. A smaller sub-corpus keeps the example's rebuilds snappy; the
# trace interleaves keystroke traffic with mutations and follower sessions
# that type the mutated queries — so a correct delta tier must show up in
# the answers, not just in the counters.
from repro.serve.freshness import FreshnessConfig, GenerationalQAC
from repro.text import MutationTraceConfig, generate_mutation_trace
from repro.text import KeystrokeTraceConfig

sub, sub_sc = kept[:3000], list(kept_sc[:3000])
gq = GenerationalQAC(sub, sub_sc,
                     cfg=FreshnessConfig(k=10, delta_capacity=4096,
                                         swap_threshold=8),
                     rt_cfg=RuntimeConfig(max_batch=64, slack_us=2_000.0))
mut_events = generate_mutation_trace(sub, sub_sc, MutationTraceConfig(
    keystrokes=KeystrokeTraceConfig(n_sessions=24, mean_keystroke_ms=5.0,
                                    seed=2),
    n_mutations=20, follower_sessions=8, seed=2))
fresh = gq.replay(mut_events)
fs = gq.snapshot()
print(f"\nlive index: {sum(e.kind != 'request' for e in mut_events)} "
      f"mutations over {len(fresh)} answers — outcomes "
      f"{fs['mutation_outcomes']}, apply p99 "
      f"{fs['apply_p99_us']:.0f}us; {fs['n_swaps']} generation swap(s), "
      f"stall p99 {fs['swap_stall_p99_us']/1e3:.1f}ms (rebuilds "
      f"{[f'{u/1e6:.1f}s' for u in fs['rebuild_wall_us']]} in background)")
print(f"live index: {fs['delta_hit_answers']} answers carried delta-tier "
      f"completions; invalidations {fs['runtime']['invalidations']}")
n_checked = gq.check_parity(fresh, sample_every=max(1, len(fresh) // 100))
print(f"live index: {n_checked} sampled answers bit-identical to "
      f"from-scratch rebuilds at their visible (generation, seq) versions")

# -- part 5: observability (ISSUE 10) ----------------------------------------
# Part 2's trace again, now with the obs stack live: a Tracer samples 1/4
# of requests into span trees on the same virtual clock the scheduler runs
# on (root `request` = [arrival, completion]; children queue.wait +
# engine.service or the cache.* hit). Tracing is passive — answers stay
# bit-identical — yet the spans alone tell the whole latency story.
from repro.obs import SLOMonitor, Tracer
from repro.obs.tracing import request_trees
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from scripts.obs_report import print_stage_table, print_waterfall, stage_table

tracer = Tracer(sample_every=4)
rt_obs = QACOnlineRuntime(rt.fe,                # warm since part 2
                          RuntimeConfig(max_batch=64, slack_us=20_000.0),
                          tracer=tracer)
rows_obs = rt_obs.run_trace(reqs)
assert all(np.array_equal(g, w) for g, w in zip(rows_obs, rows))
trees = request_trees(tracer.spans)
print(f"\nobserve: {len(tracer.spans)} spans over {len(trees)} sampled "
      f"requests (1/4 sampling); answers bit-identical with tracing on")
print("observe: per-stage latency budget (sampled requests)")
print_stage_table(stage_table(trees))
root, kids = max(trees.values(), key=lambda t: t[0]["dur_us"])
print("observe: slowest sampled request waterfall")
print_waterfall(root, kids)

slo = SLOMonitor(target_us=50_000.0, objective=0.999)
for idx, done in sorted(rt_obs.done_t_us.items(), key=lambda kv: kv[1]):
    slo.observe(done, done - reqs[idx].t_us)
ev = slo.evaluate()
worst = max((a["long_burn"] or 0.0) for a in ev["alerts"])
print(f"observe: SLO 50ms @ 99.9% — compliance "
      f"{ev['compliance']:.4f} over {ev['n_requests']} requests, "
      f"worst long-window burn {worst:.2f}x budget, "
      f"{'FIRING' if ev['firing'] else 'no alert firing'}")
