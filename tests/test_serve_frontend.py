"""Routing parity: the class-routed frontend must equal the fused serve path
element-for-element — including INF_DOCID padding, empty-suffix-range
queries, odd batch sizes, and the bounded-engine fallback."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import build_qac_index, parse_queries, INF_DOCID
from repro.serve import qac_serve_step, QACFrontend, route_classes
from repro.text import SynthLogConfig, generate_query_log


@pytest.fixture(scope="module")
def built():
    qs, sc = generate_query_log(SynthLogConfig(n_queries=600, vocab_size=150,
                                               mean_term_chars=4.0, seed=5))
    qidx, kept, _ = build_qac_index(qs, sc)
    return qidx, kept


def _mixed_batch(kept, rng, B, pct_single, pct_garbage=0):
    """Random partial queries: pct_single% single-term, pct_garbage% with a
    suffix matching no term (empty [term_lo, term_hi) range)."""
    multis = [q for q in kept if len(q.split()) >= 2] or kept
    out = []
    for _ in range(B):
        r = rng.integers(0, 100)
        if r < pct_garbage:
            out.append("zzzzzzqx" if rng.integers(0, 2) else
                       kept[rng.integers(0, len(kept))].split()[0] + " zzzzzzqx")
        elif r < pct_garbage + pct_single:
            t = kept[rng.integers(0, len(kept))].split()[0]
            out.append(t[: rng.integers(1, len(t) + 1)])
        else:
            toks = multis[rng.integers(0, len(multis))].split()
            cut = rng.integers(1, len(toks[-1]) + 1)
            out.append(" ".join(toks[:-1] + [toks[-1][:cut]]))
    return out


def _check_parity(qidx, batch, fe, k=10):
    pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, batch)
    got = fe.complete(pids, plen, suf, slen, k=k)
    want = qac_serve_step(qidx, pids, plen, suf, slen, k=k)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    return np.asarray(got)


def test_routed_equals_fused_mixed_batches(built):
    qidx, kept = built
    fe = QACFrontend(qidx, k=10)
    rng = np.random.default_rng(0)
    for B, pct in [(32, 50), (64, 80), (48, 20), (17, 50), (5, 60)]:
        _check_parity(qidx, _mixed_batch(kept, rng, B, pct), fe)


def test_routed_packed_codec_parity(built):
    """Explicit postings_codec routes BOTH engines through the compressed
    kernels (interpret off-TPU) — still bit-identical to the fused step."""
    qidx, kept = built
    fe = QACFrontend(qidx, k=10, use_kernel=True, interpret=True,
                     heap_kernel=True, postings_codec="ef")
    rng = np.random.default_rng(21)
    _check_parity(qidx, _mixed_batch(kept, rng, 24, 50, pct_garbage=15), fe)


def test_routed_single_class_batches(built):
    """Batches that exercise only one engine (the other is never dispatched)."""
    qidx, kept = built
    fe = QACFrontend(qidx, k=10)
    rng = np.random.default_rng(1)
    _check_parity(qidx, _mixed_batch(kept, rng, 32, 100), fe)
    assert fe.stats["multi_queries"] == 0
    _check_parity(qidx, _mixed_batch(kept, rng, 32, 0), fe)
    _check_parity(qidx, _mixed_batch(kept, rng, 1, 100), fe)
    _check_parity(qidx, _mixed_batch(kept, rng, 1, 0), fe)


def test_routed_empty_suffix_range_pads_inf(built):
    """Unmatched suffixes must yield all-INF rows, same as the fused path."""
    qidx, kept = built
    fe = QACFrontend(qidx, k=10)
    rng = np.random.default_rng(2)
    got = _check_parity(qidx, _mixed_batch(kept, rng, 40, 40, pct_garbage=30), fe)
    assert (got == INF_DOCID).any(axis=1).any(), "expected some INF padding"
    # a pure-garbage batch: every row all-INF on both paths
    got = _check_parity(qidx, ["zzzzzzqx", "qzzzzzy zzzzzzqx"] * 4, fe)
    assert (got == INF_DOCID).all()


def test_routed_bounded_engine_fallback_is_exact(built):
    """With a starvation trip budget the done-flag must trigger the full
    2k-trip fallback and results must still match the fused path exactly."""
    qidx, kept = built
    fe = QACFrontend(qidx, k=10, trips=1)
    rng = np.random.default_rng(3)
    _check_parity(qidx, _mixed_batch(kept, rng, 32, 100), fe)
    assert fe.stats["single_fallbacks"] >= 1


def test_routed_jit_cache_reuse(built):
    """Same class shapes on repeat calls must not grow the jit cache."""
    qidx, kept = built
    fe = QACFrontend(qidx, k=10)
    rng = np.random.default_rng(4)
    batch = _mixed_batch(kept, rng, 32, 50)
    _check_parity(qidx, batch, fe)
    n_entries = len(fe._cache)
    for _ in range(3):
        _check_parity(qidx, batch, fe)
    assert len(fe._cache) == n_entries
    # a different mix with the same bucketed class sizes also reuses the cache
    plen = np.asarray(parse_queries(qidx.dictionary, batch)[1])
    other = _mixed_batch(kept, rng, int((plen == 0).sum()), 100) + \
        _mixed_batch(kept, rng, int((plen > 0).sum()), 0)
    _check_parity(qidx, other, fe)
    assert len(fe._cache) == n_entries


def test_per_request_k_matches_scalar_calls(built):
    """ISSUE 4 satellite: a per-request k array must give each row exactly
    its scalar-k result in columns [0, k_i) and INF beyond — the engines'
    top-k is prefix-stable — while the jit cache only ever sees the pow2
    k-buckets (plus the exact default k), never the raw tail ks."""
    qidx, kept = built
    fe = QACFrontend(qidx, k=10)
    rng = np.random.default_rng(7)
    batch = _mixed_batch(kept, rng, 24, 50, pct_garbage=10)
    pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, batch)
    karr = rng.choice([3, 10, 21, 64], size=24)
    karr[:4] = [3, 10, 21, 64]          # every bucket present
    out = fe.complete(pids, plen, suf, slen, k=karr)
    assert out.shape == (24, int(karr.max()))
    # cache keys snapshot BEFORE the scalar reference calls add their own
    ks_in_cache = {key[2] for key in fe._cache}
    assert ks_in_cache <= {10, 4, 32, 64}, ks_in_cache
    pids_n, plen_n = np.asarray(pids), np.asarray(plen)
    suf_n, slen_n = np.asarray(suf), np.asarray(slen)
    for i, ki in enumerate(karr):
        want = np.asarray(fe.complete(pids_n[i:i + 1], plen_n[i:i + 1],
                                      suf_n[i:i + 1], slen_n[i:i + 1],
                                      k=int(ki)))[0]
        np.testing.assert_array_equal(out[i, :ki], want,
                                      err_msg=f"row {i} k={ki}")
        assert (out[i, ki:] == INF_DOCID).all()


def test_uniform_k_array_collapses_to_scalar_path(built):
    qidx, kept = built
    fe = QACFrontend(qidx, k=10)
    rng = np.random.default_rng(8)
    batch = _mixed_batch(kept, rng, 16, 50)
    pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, batch)
    want = np.asarray(fe.complete(pids, plen, suf, slen, k=10))
    got = np.asarray(fe.complete(pids, plen, suf, slen,
                                 k=np.full(16, 10, np.int32)))
    np.testing.assert_array_equal(got, want)
    assert {key[2] for key in fe._cache} == {10}
    # a uniform TAIL k must still take the bucketed path (k=21 -> 32), or
    # every distinct uniform k would mint a raw jit variant of its own
    got21 = np.asarray(fe.complete(pids, plen, suf, slen,
                                   k=np.full(16, 21, np.int32)))
    assert got21.shape == (16, 21)
    assert {key[2] for key in fe._cache} == {10, 32}


def test_route_classes_partition(built):
    qidx, kept = built
    rng = np.random.default_rng(6)
    batch = _mixed_batch(kept, rng, 30, 50)
    pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, batch)
    single_rows, multi_rows = route_classes(plen)
    merged = np.sort(np.concatenate([single_rows, multi_rows]))
    np.testing.assert_array_equal(merged, np.arange(len(batch)))
    assert (np.asarray(plen)[single_rows] == 0).all()
    assert (np.asarray(plen)[multi_rows] > 0).all()
