import os
import sys

# smoke tests and benches must see 1 device; only launch/dryrun.py sets 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
