"""Training-stack semantics: optimizer, schedules, microbatching, RoPE,
decode/forward parity, MoE capacity behavior."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim.adamw import AdamWConfig, init_opt_state, adamw_update, cosine_lr
from repro.train.steps import _accumulate_grads
from repro.models.layers import apply_rope
from repro.configs import get_arch


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                      total_steps=400, clip_norm=0)
    w = jnp.asarray([5.0, -3.0])
    target = jnp.asarray([1.0, 2.0])
    st = init_opt_state(w)
    loss = lambda w_: jnp.sum((w_ - target) ** 2)
    for _ in range(400):
        g = jax.grad(loss)(w)
        w, st, m = adamw_update(cfg, w, g, st)
    assert float(loss(w)) < 1e-3


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(cosine_lr(cfg, jnp.int32(s))) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert abs(max(lrs) - 1.0) < 0.11          # reaches ~peak after warmup
    assert abs(lrs[-1] - 0.1) < 1e-3           # decays to min_lr_frac
    assert all(a >= b - 1e-6 for a, b in zip(lrs[2:], lrs[3:]))  # monotone decay


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=10, clip_norm=1.0)
    w = jnp.zeros((4,))
    st = init_opt_state(w)
    g = jnp.full((4,), 1e6)
    w2, st, m = adamw_update(cfg, w, g, st)
    assert float(m["grad_norm"]) > 1e5          # raw norm reported
    assert np.isfinite(np.asarray(w2)).all()
    assert np.abs(np.asarray(w2)).max() < 1.0   # clipped step


def test_microbatch_accumulation_matches_full_batch():
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(16, 3)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3,)), jnp.float32)

    def loss_fn(w_, batch):
        return jnp.mean((batch["x"] @ w_ - batch["y"]) ** 2)

    batch = {"x": X, "y": y}
    l1, g1 = _accumulate_grads(loss_fn, w, batch, 1)
    l4, g4 = _accumulate_grads(loss_fn, w, batch, 4)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g4), rtol=1e-5)


def test_rope_relative_position_property():
    """<RoPE(q, m), RoPE(k, n)> depends only on m - n."""
    rng = np.random.default_rng(1)
    D = 32
    q = jnp.asarray(rng.normal(size=(D,)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(D,)), jnp.float32)

    def dot(m, n):
        qm = apply_rope(q[None], jnp.asarray([m]), 10000.0)[0]
        kn = apply_rope(k[None], jnp.asarray([n]), 10000.0)[0]
        return float(qm @ kn)

    np.testing.assert_allclose(dot(3, 7), dot(13, 17), rtol=1e-4)
    np.testing.assert_allclose(dot(0, 5), dot(100, 105), rtol=1e-4)
    assert abs(dot(0, 5) - dot(0, 9)) > 1e-6   # but it does depend on the gap


@pytest.mark.parametrize("arch_id", ["smollm-360m", "gemma2-2b"])
def test_decode_matches_teacher_forcing(arch_id):
    """KV-cache decode logits == full-forward logits, token by token."""
    model = get_arch(arch_id).smoke_model()
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, model.cfg.vocab)
    full, _, _ = model.forward(params, toks)
    cache = model.init_cache(B, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t])
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_tokens_and_aux_balances():
    from repro.models.transformer import TransformerConfig, MoESettings, TransformerLM
    import dataclasses
    base = get_arch("qwen3-moe-235b-a22b").smoke_cfg
    tight = dataclasses.replace(base, moe=dataclasses.replace(
        base.moe, capacity_factor=0.25))
    m_tight = TransformerLM(tight)
    m_loose = TransformerLM(base)
    params = m_tight.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, base.vocab)
    lt, aux_t, _ = m_tight.forward(params, toks)
    ll, aux_l, _ = m_loose.forward(params, toks)
    assert np.isfinite(np.asarray(lt)).all()
    assert float(aux_t) > 0
    # tight capacity must actually change the output (tokens dropped)
    assert float(jnp.max(jnp.abs(lt - ll))) > 1e-6


def test_expert_padding_is_semantically_inert():
    """pad_experts_to only adds dead experts — outputs must be identical."""
    from repro.models.transformer import TransformerLM
    import dataclasses
    base = get_arch("qwen3-moe-235b-a22b").smoke_cfg     # 8 experts
    padded_cfg = dataclasses.replace(base, moe=dataclasses.replace(
        base.moe, pad_experts_to=12))
    m0 = TransformerLM(base)
    m1 = TransformerLM(padded_cfg)
    p0 = m0.init_params(jax.random.PRNGKey(0))
    p1 = jax.tree_util.tree_map(lambda x: x, p0)
    # grow expert arrays with garbage rows — they must never be selected
    for k in ("we_gate", "we_up", "we_down"):
        w = p0["layers"][k]
        pad = jnp.ones((w.shape[0], w.shape[1], 4) + w.shape[3:], w.dtype)
        p1["layers"][k] = jnp.concatenate([w, pad], axis=2)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, base.vocab)
    l0, _, _ = m0.forward(p0, toks)
    l1, _, _ = m1.forward(p1, toks)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), rtol=1e-5,
                               atol=1e-5)
