"""On-chip single-term top-k kernel vs the vmap per-query reference (ISSUE 3).

The heap_topk kernel runs the WHOLE bounded-trip single-term engine in one
Pallas launch (heap state in VMEM scratch, in-kernel RMQ + iterator
gathers). Both the kernel (interpret mode off-TPU) and the ref.py XLA
fallback must be bit-identical — ``out`` AND ``done`` — to vmap-ing
``single_term_topk_bounded``, across empty/inverted term ranges,
duplicate-docid trip starvation, and every trip budget.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.core import build_qac_index, parse_queries, INF_DOCID
from repro.core.search import (single_term_topk_bounded,
                               single_term_topk_bounded_batch)
from repro.kernels.heap_topk.ops import heap_topk
from repro.kernels.heap_topk.ref import heap_topk_ref
from repro.text import SynthLogConfig, generate_query_log


@pytest.fixture(scope="module")
def built():
    # small vocab => heavy term co-occurrence => duplicate docids across the
    # lists of a suffix range (the dedup/trip-starvation stressor)
    qs, sc = generate_query_log(SynthLogConfig(n_queries=500, vocab_size=80,
                                               mean_term_chars=4.0, seed=9))
    qidx, kept, _ = build_qac_index(qs, sc)
    return qidx, kept


def _ranges(qidx, kept, rng, B, pct_garbage=25):
    """Term ranges of B random partial tokens + garbage (empty-range) lanes."""
    out = []
    for _ in range(B):
        if rng.integers(0, 100) < pct_garbage:
            out.append("zzzzzzqx")
        else:
            t = kept[rng.integers(0, len(kept))].split()[0]
            out.append(t[: rng.integers(1, len(t) + 1)])
    _, _, _, suf, slen = parse_queries(qidx.dictionary, out)
    tl, th = qidx.dictionary.locate_prefix(suf, slen)
    return jnp.asarray(tl), jnp.asarray(th)


def _want(qidx, tl, th, k, trips):
    return jax.vmap(lambda a, b: single_term_topk_bounded(
        qidx.index, qidx.rmq_minimal, a, b, k, trips))(tl, th)


def _got(qidx, tl, th, k, trips, **kw):
    """ops.heap_topk + the caller-side bad/full-budget done conditions
    (exactly what ``single_term_topk_bounded_batch`` layers on top)."""
    rm, idx = qidx.rmq_minimal, qidx.index
    t = min(trips, 2 * k)
    out, done = heap_topk(rm.values, rm.st_pos, rm.ib, idx.offsets,
                          idx.postings, tl, th, k=k, trips=t, n=rm.n,
                          n_terms=idx.n_terms, **kw)
    bad = np.asarray(tl) >= np.asarray(th)
    out = np.where(bad[:, None], INF_DOCID, np.asarray(out))
    done = np.asarray(done) | bad | (t >= 2 * k)
    return out, done


@pytest.mark.parametrize("trips", [1, 3, 12, 20])
def test_ref_matches_vmap(built, trips):
    """Starvation budgets included: duplicate runs burn pops, so small
    ``trips`` must reproduce the partial out AND the done flags."""
    qidx, kept = built
    tl, th = _ranges(qidx, kept, np.random.default_rng(trips), 48)
    wo, wd = _want(qidx, tl, th, 10, trips)
    go, gd = _got(qidx, tl, th, 10, trips, use_kernel=False)
    np.testing.assert_array_equal(go, np.asarray(wo))
    np.testing.assert_array_equal(gd, np.asarray(wd))
    if trips == 1:
        assert not gd.all(), "starvation budget should trip lanes"


@pytest.mark.parametrize("trips,k", [(1, 10), (3, 10), (12, 10), (20, 10),
                                     (7, 5)])
def test_kernel_matches_vmap(built, trips, k):
    qidx, kept = built
    tl, th = _ranges(qidx, kept, np.random.default_rng(100 + trips), 48)
    wo, wd = _want(qidx, tl, th, k, trips)
    go, gd = _got(qidx, tl, th, k, trips, use_kernel=True, interpret=True)
    np.testing.assert_array_equal(go, np.asarray(wo))
    np.testing.assert_array_equal(gd, np.asarray(wd))


def test_kernel_lane_padding(built):
    """B not a multiple of the kernel's lane tile: pad lanes are dead."""
    qidx, kept = built
    for B in (5, 130):
        tl, th = _ranges(qidx, kept, np.random.default_rng(B), B)
        wo, wd = _want(qidx, tl, th, 10, 12)
        go, gd = _got(qidx, tl, th, 10, 12, use_kernel=True, interpret=True)
        np.testing.assert_array_equal(go, np.asarray(wo))
        np.testing.assert_array_equal(gd, np.asarray(wd))


def test_all_inverted_ranges(built):
    """Every lane empty/inverted: INF rows, done immediately."""
    qidx, _ = built
    B = 16
    tl = jnp.asarray(np.arange(B, dtype=np.int32) + 5)
    th = jnp.asarray(np.arange(B, dtype=np.int32))       # th < tl everywhere
    for kw in (dict(use_kernel=False),
               dict(use_kernel=True, interpret=True)):
        go, gd = _got(qidx, tl, th, 10, 12, **kw)
        assert (go == INF_DOCID).all()
        assert gd.all()


def test_engine_heap_kernel_route(built):
    """single_term_topk_bounded_batch(heap_kernel=True) == the default
    XLA route — the kernel-routing seam used on TPU, under interpret."""
    qidx, kept = built
    tl, th = _ranges(qidx, kept, np.random.default_rng(77), 32)
    wo, wd = single_term_topk_bounded_batch(qidx.index, qidx.rmq_minimal,
                                            tl, th, 10, 12)
    go, gd = single_term_topk_bounded_batch(qidx.index, qidx.rmq_minimal,
                                            tl, th, 10, 12, use_kernel=True,
                                            heap_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(go), np.asarray(wo))
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))


def test_engine_per_pop_route(built):
    """heap_kernel=False forces the per-pop batched-RMQ kernel route (what
    a VMEM-oversized corpus takes on TPU) — still bit-identical."""
    qidx, kept = built
    tl, th = _ranges(qidx, kept, np.random.default_rng(78), 32)
    wo, wd = single_term_topk_bounded_batch(qidx.index, qidx.rmq_minimal,
                                            tl, th, 10, 12)
    go, gd = single_term_topk_bounded_batch(qidx.index, qidx.rmq_minimal,
                                            tl, th, 10, 12, use_kernel=True,
                                            heap_kernel=False, interpret=True)
    np.testing.assert_array_equal(np.asarray(go), np.asarray(wo))
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))


# ---------------------------------------------------------- packed (ISSUE 7)
@pytest.mark.parametrize("trips", [1, 3, 12, 20])
def test_packed_ref_matches_vmap(built, trips):
    """ref.py with the compressed stream: same decode transcription as the
    kernel, bit-identical to the raw vmap reference."""
    qidx, kept = built
    assert qidx.index.packed is not None
    tl, th = _ranges(qidx, kept, np.random.default_rng(200 + trips), 48)
    wo, wd = _want(qidx, tl, th, 10, trips)
    go, gd = _got(qidx, tl, th, 10, trips, use_kernel=False,
                  packed=qidx.index.packed)
    np.testing.assert_array_equal(go, np.asarray(wo))
    np.testing.assert_array_equal(gd, np.asarray(wd))


@pytest.mark.parametrize("codec", ["ef", "bitpack"])
def test_packed_kernel_matches_vmap(built, codec):
    """Pallas kernel (interpret) decoding ef/bitpack blocks in VMEM."""
    from repro.core.codecs import pack_postings

    qidx, kept = built
    pk = (qidx.index.packed if codec == "ef"
          else pack_postings(np.asarray(qidx.index.postings), codec))
    tl, th = _ranges(qidx, kept, np.random.default_rng(300), 48)
    for trips in (3, 12):
        wo, wd = _want(qidx, tl, th, 10, trips)
        go, gd = _got(qidx, tl, th, 10, trips, use_kernel=True,
                      interpret=True, packed=pk)
        np.testing.assert_array_equal(go, np.asarray(wo))
        np.testing.assert_array_equal(gd, np.asarray(wd))


def test_engine_packed_codec_route(built):
    """single_term_topk_bounded_batch(postings_codec=...) — the explicit
    compressed heap route AND the auto route where only compressed fits —
    bit-identical to the default XLA route."""
    from repro.core.search import _heap_kernel_fits

    qidx, kept = built
    idx, rm = qidx.index, qidx.rmq_minimal
    tl, th = _ranges(qidx, kept, np.random.default_rng(79), 32)
    wo, wd = single_term_topk_bounded_batch(idx, rm, tl, th, 10, 12)
    for codec in ("ef", "auto", "raw"):
        go, gd = single_term_topk_bounded_batch(
            idx, rm, tl, th, 10, 12, use_kernel=True, heap_kernel=True,
            interpret=True, postings_codec=codec)
        np.testing.assert_array_equal(np.asarray(go), np.asarray(wo))
        np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))
    # a ceiling between the packed and raw footprints: auto must still route
    # to the heap kernel (via the compressed stream), not per-pop
    squeeze = _heap_kernel_fits(idx, rm, packed=idx.packed, max_bytes=0)
    assert not squeeze
    mb = (idx.packed.nbytes()
          + 4 * (rm.values.size + rm.st_pos.size + rm.ib.size
                 + idx.offsets.size))
    assert _heap_kernel_fits(idx, rm, packed=idx.packed, max_bytes=mb)
    assert not _heap_kernel_fits(idx, rm, max_bytes=mb)
    go, gd = single_term_topk_bounded_batch(
        idx, rm, tl, th, 10, 12, use_kernel=True, interpret=True,
        postings_codec="auto", heap_kernel_max_bytes=mb)
    np.testing.assert_array_equal(np.asarray(go), np.asarray(wo))
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))


def test_engine_explicit_codec_requires_match(built):
    qidx, _ = built
    with pytest.raises(ValueError):
        single_term_topk_bounded_batch(
            qidx.index, qidx.rmq_minimal, jnp.asarray([1]), jnp.asarray([2]),
            10, 12, use_kernel=True, postings_codec="bitpack")


@given(st.integers(0, 2**31 - 2), st.sampled_from([1, 4, 9, 12, 17, 20]))
@settings(max_examples=15, deadline=None)
def test_heap_topk_property(built, seed, trips):
    """Random term ranges (valid, empty, inverted, out-of-bounds) x random
    trip budgets: ref AND Pallas kernel bit-identical to the vmap
    reference (sampled trip values keep the interpret-mode compile count
    bounded)."""
    qidx, _ = built
    V = qidx.index.n_terms
    rng = np.random.default_rng(seed % 2**32)
    B = 16
    tl = jnp.asarray(rng.integers(-2, V + 3, B).astype(np.int32))
    th = jnp.asarray((np.asarray(tl)
                      + rng.integers(-4, V, B)).astype(np.int32))
    wo, wd = _want(qidx, tl, th, 10, trips)
    go, gd = _got(qidx, tl, th, 10, trips, use_kernel=False)
    np.testing.assert_array_equal(go, np.asarray(wo))
    np.testing.assert_array_equal(gd, np.asarray(wd))
    ko, kd = _got(qidx, tl, th, 10, trips, use_kernel=True, interpret=True)
    np.testing.assert_array_equal(ko, np.asarray(wo))
    np.testing.assert_array_equal(kd, np.asarray(wd))
    po, pd = _got(qidx, tl, th, 10, trips, use_kernel=False,
                  packed=qidx.index.packed)
    np.testing.assert_array_equal(po, np.asarray(wo))
    np.testing.assert_array_equal(pd, np.asarray(wd))
