"""Online runtime parity (ISSUE 4): ANY arrival interleaving / batch-formation
schedule must yield results bit-identical to direct per-request
``QACFrontend`` calls — across the exact-LRU hit path, the session
filter-first fast path (prefix extension AND term-completion-by-space), the
trivial reject path, session backtracking (deleted characters), mixed
per-request k, and every scheduler trigger (full bucket, deadline, drain).

Scheduling can never change WHAT a request answers (each lane is computed
independently and caches only ever replay complete match sets), so these
tests drive the scheduler through pathological configs — max_batch=1, zero
slack, tiny/disabled caches — and still demand bit-identity.
"""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import build_qac_index, parse_queries, INF_DOCID
from repro.serve import QACFrontend
from repro.serve.runtime import (QACOnlineRuntime, RuntimeConfig,
                                 prepare_requests, run_naive_trace)
from repro.text import (SynthLogConfig, generate_query_log,
                        KeystrokeTraceConfig, generate_keystroke_trace)


@pytest.fixture(scope="module")
def built():
    qs, sc = generate_query_log(SynthLogConfig(n_queries=600, vocab_size=150,
                                               mean_term_chars=4.0, seed=5))
    qidx, kept, _ = build_qac_index(qs, sc)
    # one shared frontend: the jit cache stays warm across tests, and using
    # the same instance for runtime and reference is sound (complete() is a
    # pure function of its inputs)
    fe = QACFrontend(qidx, k=10, specialize_list_pad=False)
    return qidx, kept, fe


def _direct_rows(fe, reqs):
    """The reference: every request dispatched alone, straight through the
    frontend, at its own k."""
    return [np.asarray(fe.complete(
        r.pids[None], np.asarray([r.plen], np.int32), r.suf[None],
        np.asarray([r.slen], np.int32), k=r.k))[0] for r in reqs]


def _assert_parity(fe, reqs, got):
    want = _direct_rows(fe, reqs)
    assert len(got) == len(reqs)
    for i, (g, w) in enumerate(zip(got, want)):
        np.testing.assert_array_equal(
            g, w, err_msg=f"request {i}: {reqs[i].query!r}")


def _keystrokes(queries, session0=0, t0=0.0, gap=1000.0):
    """Explicit keystroke events: every prefix of every query, one session
    per query, fixed inter-arrival gap (deterministic schedules)."""
    events, t = [], t0
    for s, q in enumerate(queries):
        for n in range(1, len(q) + 1):
            t += gap
            events.append((t, session0 + s, q[:n]))
    return sorted(events)


# NOTE on determinism: parity is schedule-independent (every path computes
# or replays the exact per-request answer), but HIT COUNTS are not — a slow
# engine dispatch (e.g. a jit compile on a loaded runner) can push results
# past a duplicate's arrival, turning a would-be hit into a miss. Tests
# that assert hit counts therefore use the synchronous config
# (max_batch=1, slack=0): every miss is served inside submit(), before the
# next arrival is processed, so cache contents — and hence hit counts —
# are a pure function of the trace.
_SYNC = dict(max_batch=1, slack_us=0.0)


# --------------------------------------------------------------- fast paths
def test_synthetic_trace_parity_and_hits(built):
    qidx, kept, fe = built
    trace = generate_keystroke_trace(kept, KeystrokeTraceConfig(
        n_sessions=12, mean_keystroke_ms=5.0, session_spread_ms=20.0,
        seed=3))
    reqs = prepare_requests(qidx, trace, k=10)
    rt = QACOnlineRuntime(fe, RuntimeConfig(max_batch=8, slack_us=2_000.0))
    got = rt.run_trace(reqs)
    _assert_parity(fe, reqs, got)
    s = rt.telemetry.snapshot()
    assert s["paths"]["miss"] > 0              # the first arrival always is
    assert s["n_requests"] == len(reqs) == sum(s["paths"].values())
    assert max(s["batch_hist"]) <= 8
    # hit counts: deterministic under the synchronous schedule
    rt2 = QACOnlineRuntime(fe, RuntimeConfig(**_SYNC))
    got2 = rt2.run_trace(reqs)
    for g, g2 in zip(got, got2):
        np.testing.assert_array_equal(g, g2)
    s2 = rt2.telemetry.snapshot()
    assert s2["paths"]["hit_exact"] > 0 and s2["paths"]["hit_session"] > 0


def test_session_filter_path_is_exact(built):
    """A session typing one long multi-term query end to end: once a prefix
    has < k matches the whole tail must be served by host-side filtering of
    the session's complete set — including across the space that promotes
    the suffix into a prefix term — bit-identical to the engine."""
    qidx, kept, fe = built
    target = max((q for q in kept if len(q.split()) >= 2), key=len)
    reqs = prepare_requests(qidx, _keystrokes([target + " "]), k=64)
    rt = QACOnlineRuntime(fe, RuntimeConfig(**_SYNC))
    got = rt.run_trace(reqs)
    _assert_parity(fe, reqs, got)
    # k=64 on a ~600-completion corpus: deep prefixes are complete (<k
    # matches), so the filter path must have fired
    assert rt.telemetry.paths["hit_session"] >= 1


def test_backtracking_hits_the_exact_cache(built):
    """Deleting characters GROWS the match set — the session filter must
    refuse it, and the re-typed shorter prefixes must come back verbatim
    from the exact LRU populated on the way in."""
    qidx, kept, fe = built
    q = max((s for s in kept if len(s.split()) == 1), key=len)
    strokes = [q[:n] for n in range(1, len(q) + 1)]          # type it out
    strokes += [q[:n] for n in range(len(q) - 1, 0, -1)]     # delete it all
    events = [(1000.0 * i, 7, s) for i, s in enumerate(strokes)]
    reqs = prepare_requests(qidx, events, k=10)
    rt = QACOnlineRuntime(fe, RuntimeConfig(**_SYNC))
    got = rt.run_trace(reqs)
    _assert_parity(fe, reqs, got)
    # every backtracked prefix was served earlier in the same session
    assert rt.telemetry.paths["hit_exact"] >= len(q) - 1


def test_trivial_reject_path(built):
    """Unknown-term and empty-suffix-range requests short-circuit to all-INF
    without an engine dispatch — exactly what the engines return."""
    qidx, kept, fe = built
    base = kept[0].split()[0]
    events = _keystrokes(["zzzzzzqx", base + " zzzzzzqx", "qzzzzzy zz"],
                         gap=500.0)
    reqs = prepare_requests(qidx, events, k=10)
    rt = QACOnlineRuntime(fe, RuntimeConfig(max_batch=4, slack_us=100.0))
    got = rt.run_trace(reqs)
    _assert_parity(fe, reqs, got)
    assert rt.telemetry.paths["trivial"] > 0
    assert all((g == INF_DOCID).all() for g, r in zip(got, reqs)
               if "zzz" in r.query.split()[-1])


def test_truncated_multi_scan_never_poisons_session_cache(built):
    """``conjunctive_multi`` stops scanning its driver list after
    tile * max_tiles docids, so an INF-padded row is NOT always the
    complete match set — the session store must refuse to derive a filter
    set from a possibly-truncated scan (``_scan_exact``), or a later
    keystroke could answer from a poisoned set and break parity. Force
    truncation with a tiny scan budget and check both the guard and
    end-to-end parity."""
    qidx, kept, _ = built
    fe2 = QACFrontend(qidx, k=10, tile=8, max_tiles=1,
                      specialize_list_pad=False)
    rt = QACOnlineRuntime(fe2, RuntimeConfig(**_SYNC))
    long_term = int(np.argmax(fe2._list_lens))
    assert int(fe2._list_lens[long_term]) > 8
    fake = prepare_requests(qidx, [(0.0, 0, kept[0])], k=10)[0]
    fake.pids = np.asarray([long_term] + [0] * (fake.pids.size - 1), np.int32)
    fake.plen = 1
    assert not rt._scan_exact(fake)          # long driver => unprovable
    assert rt._scan_exact(prepare_requests(
        qidx, [(0.0, 0, kept[0].split()[0])], k=10)[0])  # single-term: exact
    # the sharpest shape: a single-term prefix (exact engine -> complete
    # session set) followed by the space that promotes a LONG-listed term
    # into the prefix — the new request's own driver scan truncates, so
    # _reusable must refuse the filter path and reproduce the engine's
    # truncated answer verbatim
    lens = np.asarray(fe2._list_lens)
    long_toks = [q.split()[0] for q in kept if len(q.split()) >= 2
                 and lens[np.clip(qidx.dictionary.id_of(q.split()[0]), 0,
                                  len(lens) - 1)] > 8]
    assert long_toks, "corpus lost its long posting lists?"
    promoted = [t + " " for t in long_toks[:3]]
    # k=64 so the single-term stage is COMPLETE (< k matches -> a session
    # set forms) while 'tok ' matches every docid of the long list — more
    # than the 8 the engine scans. Without the _reusable exactness guard
    # the filter path answers correctly where the engine truncates, which
    # is exactly the parity break this test must catch.
    rt64 = QACOnlineRuntime(fe2, RuntimeConfig(**_SYNC))
    reqs = prepare_requests(qidx, _keystrokes(promoted), k=64)
    got = rt64.run_trace(reqs)
    _assert_parity(fe2, reqs, got)
    # end-to-end: sessions typing multi-term queries under the truncating
    # frontend must still match its own direct per-request answers
    multis = [q for q in kept if len(q.split()) >= 2][:6]
    reqs = prepare_requests(qidx, _keystrokes(multis), k=10)
    got = rt.run_trace(reqs)
    _assert_parity(fe2, reqs, got)


# ---------------------------------------------------------------- scheduler
def test_full_bucket_and_drain_triggers(built):
    """A burst arriving faster than the deadline forces full-bucket
    dispatches; the tail drains. Caches off so every request queues."""
    qidx, kept, fe = built
    queries = [kept[i % len(kept)] for i in range(11)]
    events = [(float(i), i, q) for i, q in enumerate(queries)]  # 1us apart
    reqs = prepare_requests(qidx, events, k=10)
    rt = QACOnlineRuntime(fe, RuntimeConfig(
        max_batch=4, slack_us=1e9, cache_entries=0, session_entries=0))
    got = rt.run_trace(reqs)
    _assert_parity(fe, reqs, got)
    s = rt.telemetry.snapshot()
    assert s["paths"].get("miss", 0) == len(reqs)
    assert s["triggers"].get("full", 0) >= 2
    assert s["triggers"].get("drain", 0) >= 1
    assert s["batch_hist"].get(4, 0) >= 2


def test_tick_fires_deadlines_without_new_arrivals(built):
    """Live mode: a queued request whose deadline passes during a traffic
    lull must be dispatched by tick(now), not wait for the next submit."""
    qidx, kept, fe = built
    reqs = prepare_requests(qidx, [(0.0, 0, kept[10])], k=10)
    rt = QACOnlineRuntime(fe, RuntimeConfig(
        max_batch=64, slack_us=1_000.0, cache_entries=0, session_entries=0))
    rt.submit(reqs[0])
    assert len(rt.queue) == 1
    rt.tick(500.0)                    # before the deadline: still queued
    assert len(rt.queue) == 1
    rt.tick(2_000.0)                  # past it: dispatched
    assert not rt.queue and rt.telemetry.paths["miss"] == 1


def test_one_request_per_dispatch_matches_naive(built):
    """max_batch=1 + caches off degenerates to the naive baseline."""
    qidx, kept, fe = built
    events = _keystrokes([kept[3], kept[40]], gap=2_000.0)
    reqs = prepare_requests(qidx, events, k=10)
    rt = QACOnlineRuntime(fe, RuntimeConfig(
        max_batch=1, slack_us=0.0, cache_entries=0, session_entries=0))
    got = rt.run_trace(reqs)
    naive_rows, stats = run_naive_trace(fe, reqs, warm=False)
    for g, w in zip(got, naive_rows):
        np.testing.assert_array_equal(g, w)
    assert rt.telemetry.snapshot()["mean_batch_size"] == 1.0
    assert stats["n_requests"] == len(reqs)


def test_mixed_per_request_k(built):
    """Heterogeneous k in one trace: batches dispatch through the
    frontend's per-k path, caches key on (prefix, k)."""
    qidx, kept, fe = built
    events = _keystrokes([kept[5], kept[17], kept[31]], gap=300.0)
    ks = np.asarray([(3, 10, 33)[i % 3] for i in range(len(events))])
    reqs = prepare_requests(qidx, events, k=ks)
    rt = QACOnlineRuntime(fe, RuntimeConfig(max_batch=8, slack_us=1_000.0))
    got = rt.run_trace(reqs)
    _assert_parity(fe, reqs, got)
    for r, g in zip(reqs, got):
        assert g.shape == (r.k,)


# ------------------------------------------------- randomized interleavings
def _random_schedule_example(built, draw_int, draw_float, draw_from):
    """One randomized trace + scheduler config, checked for parity. The
    draw_* hooks are either hypothesis draws or a seeded numpy rng, so the
    property gets shrinkable exploration where hypothesis is installed and
    a deterministic seeded sweep everywhere else."""
    qidx, kept, fe = built
    n_sessions = draw_int(1, 5)
    pool = kept[:: max(1, len(kept) // 40)]      # small pool => collisions
    events = []
    for s in range(n_sessions):
        target = draw_from(pool)
        t = draw_float(0.0, 2e4)
        pos = draw_int(1, len(target))
        for _ in range(draw_int(1, 7)):
            events.append((t, s, target[:pos]))
            t += draw_float(1.0, 3e4)
            pos = max(1, min(len(target),
                             pos + draw_from([1, 1, 1, 2, -1, -2])))
    events.sort(key=lambda e: e[0])
    cfg = RuntimeConfig(
        max_batch=draw_from([1, 2, 5, 8]),
        slack_us=draw_from([0.0, 500.0, 1e5]),
        cache_entries=draw_from([0, 3, 1 << 10]),
        session_entries=draw_from([0, 2, 1 << 10]))
    reqs = prepare_requests(qidx, events, k=draw_from([3, 10, 33]))
    got = QACOnlineRuntime(fe, cfg).run_trace(reqs)
    _assert_parity(fe, reqs, got)


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_runtime_parity_any_interleaving(built, data):
    """Random sessions, random prefix walks (forward AND backward), random
    arrival gaps, random scheduler/cache configs — bit-identical to direct
    per-request frontend calls, always."""
    _random_schedule_example(
        built,
        lambda a, b: data.draw(st.integers(a, b)),
        lambda a, b: data.draw(st.floats(a, b)),
        lambda xs: data.draw(st.sampled_from(xs)))


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5])
def test_runtime_parity_seeded_schedules(built, seed):
    """The same property as the hypothesis test, driven by a seeded rng so
    it runs (deterministically) even where hypothesis is not installed."""
    rng = np.random.default_rng(1234 + seed)
    _random_schedule_example(
        built,
        lambda a, b: int(rng.integers(a, b + 1)),
        lambda a, b: float(rng.uniform(a, b)),
        lambda xs: xs[int(rng.integers(0, len(xs)))])


# -------------------------------------------------- telemetry (ISSUE 8)
def test_telemetry_percentiles_pinned_to_numpy():
    """snapshot()'s quantile math is np.percentile, verbatim — no
    hand-rolled interpolation allowed to drift."""
    from repro.serve.runtime import RuntimeTelemetry
    t = RuntimeTelemetry()
    lats = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0, 535.0, 89.0, 79.0]
    for x in lats:
        t.record("miss", x)
    s = t.snapshot()
    for p in (50, 95, 99):
        assert s[f"p{p}_us"] == float(np.percentile(lats, p))
    assert s["mean_us"] == pytest.approx(np.mean(lats))
    assert s["max_us"] == max(lats)
    assert s["deadline_violations"] == 0
    assert s["max_queue_depth"] == 0


def test_telemetry_deadline_violations_and_queue_gauge(built):
    """Two same-instant arrivals under max_batch=1/slack=0: the second
    dispatch starts at server_free > its deadline — exactly one violation
    per such pile-up. Then a held queue pins the max-depth gauge."""
    qidx, kept, fe = built
    words = sorted({q.split()[0] for q in kept})[:6]
    reqs = prepare_requests(qidx, [(0.0, s, w) for s, w in enumerate(words)],
                            k=10)
    rt = QACOnlineRuntime(fe, RuntimeConfig(**_SYNC))
    rt.run_trace(reqs)
    s = rt.telemetry.snapshot()
    # first dispatch starts exactly at its deadline (t=0): not a violation;
    # every later one starts behind the busy server: violation
    assert s["deadline_violations"] == len(reqs) - 1
    # huge slack + batch: all requests sit queued until drain
    rt2 = QACOnlineRuntime(fe, RuntimeConfig(max_batch=64, slack_us=1e9))
    rt2.run_trace(reqs)
    s2 = rt2.telemetry.snapshot()
    assert s2["max_queue_depth"] == len(reqs)
    assert s2["max_queue_depth"] == s2["queue_peak"]   # back-compat alias
    assert s2["deadline_violations"] == 0              # drain fires in time


# ---------------------------------------------- open-loop traces (ISSUE 8)
def test_trace_target_qps_rescales_and_is_deterministic(built):
    qidx, kept, fe = built
    base_cfg = KeystrokeTraceConfig(n_sessions=8, mean_keystroke_ms=50.0,
                                    seed=13)
    base = generate_keystroke_trace(kept, base_cfg)
    for qps in (50.0, 400.0):
        cfg = KeystrokeTraceConfig(n_sessions=8, mean_keystroke_ms=50.0,
                                   seed=13, target_qps=qps)
        tr = generate_keystroke_trace(kept, cfg)
        tr2 = generate_keystroke_trace(kept, cfg)
        assert tr == tr2                        # seeded-deterministic
        # same REQUEST SET, rescaled time axis
        assert [(s, q) for _, s, q in tr] == [(s, q) for _, s, q in base]
        span_s = (tr[-1][0] - tr[0][0]) / 1e6
        assert (len(tr) - 1) / span_s == pytest.approx(qps, rel=1e-6)
        assert tr[0][0] == 0.0
        # ordering preserved -> still a valid runtime trace
        assert all(a[0] <= b[0] for a, b in zip(tr, tr[1:]))
    with pytest.raises(ValueError):
        generate_keystroke_trace(kept, KeystrokeTraceConfig(
            n_sessions=2, seed=13, target_qps=-1.0))
