"""Observability stack (ISSUE 10): metrics/tracing/jit-audit/SLO units,
the one-percentile-implementation contract, span-tree invariants under
arbitrary schedules (hypothesis), and the bench regression gate's diff
logic. The end-to-end acceptance (overhead cap, negative jit-audit
control) lives in benchmarks/bench_qac_obs.py; here we pin the contracts
every layer relies on."""
import json
import os
import sys

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import build_qac_index
from repro.obs import (JitAuditError, JitAuditor, MetricsRegistry, ObsConfig,
                       SLOMonitor, Tracer)
from repro.obs.metrics import Histogram, fmt, percentiles
from repro.obs.tracing import load_jsonl, request_trees, span_children
from repro.serve import QACFrontend
from repro.serve.runtime import (QACOnlineRuntime, RuntimeConfig,
                                 prepare_requests)
from repro.text import (KeystrokeTraceConfig, SynthLogConfig,
                        generate_keystroke_trace, generate_query_log)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import compare_results, metric_direction  # noqa: E402


@pytest.fixture(scope="module")
def built():
    qs, sc = generate_query_log(SynthLogConfig(n_queries=500, vocab_size=120,
                                               mean_term_chars=4.0, seed=9))
    qidx, kept, _ = build_qac_index(qs, sc)
    fe = QACFrontend(qidx, k=10, specialize_list_pad=False)
    return qidx, kept, fe


# ------------------------------------------------------------- percentiles
def test_percentiles_pinned_to_numpy():
    """THE percentile implementation (every serving snapshot routes here)
    is np.percentile, verbatim."""
    vals = [3.0, 1.0, 4.0, 1.0, 5.0, 926.0, 5.0, 3.0, 589.0]
    p = percentiles(vals, (50, 95, 99), mean=True, vmax=True)
    for q in (50, 95, 99):
        assert p[f"p{q}_us"] == float(np.percentile(vals, q))
    assert p["mean_us"] == pytest.approx(np.mean(vals))
    assert p["max_us"] == max(vals)


def test_percentiles_empty_is_none_not_nan():
    """Empty latency lists -> explicit None per key (the snapshot contract
    ISSUE 10 fixes): no NaN, no fake 0.0, no crash."""
    p = percentiles([], (50, 99), mean=True, vmax=True)
    assert p == {"p50_us": None, "p99_us": None,
                 "mean_us": None, "max_us": None}
    assert percentiles([], suffix="_ms") == {
        "p50_ms": None, "p95_ms": None, "p99_ms": None}


def test_fmt_renders_none_as_na():
    assert fmt(None) == "n/a"
    assert fmt(1234.0, 1e3, 2, "ms") == "1.23ms"
    assert fmt(50.0) == "50"


def test_empty_runtime_telemetry_snapshot():
    """RuntimeTelemetry on zero requests: None percentiles, no crash."""
    from repro.serve.runtime import RuntimeTelemetry
    s = RuntimeTelemetry().snapshot()
    assert s["n_requests"] == 0
    assert s["p50_us"] is None and s["p99_us"] is None
    assert s["mean_us"] is None and s["max_us"] is None
    assert s["mean_batch_size"] is None
    json.dumps(s)                        # schema stays JSON-serializable


def test_empty_cluster_telemetry_snapshot():
    from repro.serve.cluster import ClusterTelemetry
    s = ClusterTelemetry().snapshot()
    assert s["interactive_p99_us"] is None
    assert s["shed_rate"] == 0.0
    json.dumps(s)


# ---------------------------------------------------------------- registry
def test_histogram_reservoir():
    h = Histogram(capacity=4)
    for x in (5.0, 1.0, 3.0):
        h.observe(x)
    s = h.snapshot()
    assert s["n"] == 3 and "truncated" not in s
    assert s["p50"] == float(np.percentile([5.0, 1.0, 3.0], 50))
    assert s["max"] == 5.0
    for x in range(10):
        h.observe(float(x))
    s = h.snapshot()
    assert s["n"] == 13 and s["truncated"]   # count/max stay exact
    assert s["max"] == 9.0


def test_metrics_registry_schema():
    reg = MetricsRegistry()
    reg.counter("requests", 3)
    reg.counter("requests")
    reg.gauge("queue_depth", 7.0)
    reg.observe("lat", 10.0)
    reg.observe("lat", 20.0)
    reg.register_collector("rt", lambda: {"x": 1})
    with pytest.raises(TypeError):
        reg.register_collector("bad", 42)
    s = reg.snapshot()
    assert s["counters"] == {"requests": 4}
    assert s["gauges"] == {"queue_depth": 7.0}
    assert s["histograms"]["lat"]["n"] == 2
    assert s["collectors"]["rt"] == {"x": 1}
    # re-register replaces (the freshness layer re-registers per reset)
    reg.register_collector("rt", lambda: {"x": 2})
    assert reg.snapshot()["collectors"]["rt"] == {"x": 2}


# ------------------------------------------------------------------ tracer
def test_tracer_sampling_and_roundtrip(tmp_path):
    tr = Tracer(sample_every=4)
    assert [i for i in range(8) if tr.want(i)] == [0, 4]
    root = tr.span("request", 0.0, 100.0, req=0, path="miss")
    tr.span("queue.wait", 0.0, 60.0, cat="queue", req=0, parent=root)
    tr.span("engine.service", 60.0, 40.0, cat="engine", req=0, parent=root)
    tr.instant("jit.compile", 5.0, cat="jit", key="k")
    p = tr.to_jsonl(str(tmp_path / "t.jsonl"))
    spans, instants = load_jsonl(p)
    assert len(spans) == 3 and len(instants) == 1
    trees = request_trees(spans)
    r, kids = trees[0]
    assert r["attrs"]["path"] == "miss" and len(kids) == 2
    assert sum(c["dur_us"] for c in kids) == r["dur_us"]
    # chrome export is well-formed trace-event JSON
    cp = tr.to_chrome(str(tmp_path / "t.json"))
    with open(cp) as f:
        ev = json.load(f)["traceEvents"]
    assert {e["ph"] for e in ev} == {"X", "i"}


def test_tracer_capacity_and_clear():
    tr = Tracer(capacity=2)
    ids = [tr.span("s", 0.0, 1.0) for _ in range(4)]
    assert ids[2] is None and tr.dropped == 2
    seen = set(ids[:2])
    tr.clear()
    assert tr.spans == [] and tr.dropped == 0
    nid = tr.span("s", 0.0, 1.0)
    assert nid not in seen            # ids advance across clears
    with pytest.raises(ValueError):
        Tracer(sample_every=0)


# --------------------------------------------------------------- jit audit
def test_jit_auditor_freeze_and_violations():
    aud = JitAuditor()
    f = aud.wrap(("single", 8, 10, 0), lambda x: x + 1)
    assert f(1) == 2 and f(2) == 3
    assert len(aud.compiles) == 1     # only the first call records
    aud.freeze()
    aud.assert_closed()               # nothing post-freeze yet
    g = aud.wrap(("multi", 8, 10, 16), lambda x: x * 2, label="intersect")
    assert g(3) == 6
    assert len(aud.violations) == 1
    assert aud.violations[0]["label"] == "intersect"
    with pytest.raises(JitAuditError):
        aud.assert_closed()
    snap = aud.snapshot()
    assert snap["n_variants"] == 2 and snap["n_violations"] == 1
    json.dumps(snap)


def test_jit_auditor_strict_raises_on_the_spot():
    aud = JitAuditor(strict=True)
    aud.freeze()
    f = aud.wrap("k", lambda: 0)
    with pytest.raises(JitAuditError):
        f()


def test_jit_auditor_compile_instants_land_in_trace():
    tr = Tracer()
    aud = JitAuditor(tracer=tr)
    aud.wrap("k", lambda: 0)()
    assert [e["name"] for e in tr.instants] == ["jit.compile"]


# --------------------------------------------------------------------- SLO
def test_slo_burn_rate_math():
    """Burn = violation fraction / error budget, exactly."""
    slo = SLOMonitor(target_us=100.0, objective=0.9,
                     windows=((1_000.0, 100.0, 2.0),))
    for i in range(10):               # 10 samples, 3 violations
        slo.observe(float(i * 10), 500.0 if i in (2, 5, 9) else 50.0)
    assert slo.burn_rate(1_000.0) == pytest.approx((3 / 10) / 0.1)
    ev = slo.evaluate()
    assert ev["n_requests"] == 10 and ev["n_violations"] == 3
    assert ev["compliance"] == pytest.approx(0.7)
    a = ev["alerts"][0]
    assert a["long_burn"] == pytest.approx(3.0)
    # short window (trailing 100us ending at t=90): samples t in [-10, 90]
    # -> all 10; the pair fires only when BOTH exceed the threshold
    assert a["firing"] == (a["long_burn"] >= 2.0 and a["short_burn"] >= 2.0)
    assert a["firing"]


def test_slo_multi_window_needs_both():
    """A burst inside the short window alone must NOT fire (the long
    window proves the burn is sustained)."""
    slo = SLOMonitor(target_us=100.0, objective=0.9,
                     windows=((10_000.0, 100.0, 3.0),))
    for i in range(100):
        slo.observe(float(i * 100), 50.0)   # 10ms of clean traffic
    for i in range(3):                      # then a 3-violation burst
        slo.observe(10_000.0 + i, 500.0)
    ev = slo.evaluate()
    a = ev["alerts"][0]
    assert a["short_burn"] >= 3.0           # short window: all bad
    assert a["long_burn"] < 3.0             # long window: diluted
    assert not a["firing"]


def test_slo_empty_and_validation():
    slo = SLOMonitor()
    assert slo.burn_rate(1e6) is None
    assert slo.evaluate()["compliance"] is None
    with pytest.raises(ValueError):
        SLOMonitor(objective=1.0)
    with pytest.raises(ValueError):
        SLOMonitor(windows=((100.0, 200.0, 1.0),))   # short > long
    with pytest.raises(ValueError):
        ObsConfig(trace_sample_every=0)


# --------------------------------------------------------- regression gate
def test_metric_direction_heuristics():
    assert metric_direction("qac_online_p99_us") == "lower"
    assert metric_direction("qac_postings_bpi") == "lower"
    assert metric_direction("qac_obs_overhead_ratio") == "lower"
    assert metric_direction("qac_cluster_shed_rate_burst") == "lower"
    assert metric_direction("qac_cluster_interactive_qps") == "higher"
    assert metric_direction("qac_online_cache_hit_rate") == "higher"
    assert metric_direction("qac_freshness_hit_rate_recovery") == "higher"
    assert metric_direction("some_novel_score") == "unknown"


def test_compare_results_gates_both_directions():
    base = {"a_p99_us": 100.0, "b_hit_rate": 0.8, "c_novel": 1.0,
            "gone_us": 5.0}
    # within tolerance: no regressions
    rep = compare_results({"a_p99_us": 140.0, "b_hit_rate": 0.75,
                           "c_novel": 99.0}, base, tolerance=0.5)
    assert rep["regressions"] == []
    assert rep["missing"] == ["gone_us"]
    # lower-better metric moving up past tolerance regresses
    rep = compare_results({"a_p99_us": 151.0}, base, tolerance=0.5)
    assert rep["regressions"] == ["a_p99_us"]
    # higher-better metric moving down past tolerance regresses
    rep = compare_results({"b_hit_rate": 0.3}, base, tolerance=0.5)
    assert rep["regressions"] == ["b_hit_rate"]
    # unknown-direction metrics are reported but never gate
    rep = compare_results({"c_novel": 1e9}, base, tolerance=0.5)
    assert rep["regressions"] == []
    assert [r["status"] for r in rep["rows"]] == ["ok"]
    with pytest.raises(ValueError):
        compare_results({}, {}, tolerance=-0.1)


# --------------------------------------- span-tree invariants (hypothesis)
def _traced_run(built, n_sessions, seed, sample_every, max_batch, slack_us):
    qidx, kept, fe = built
    trace = generate_keystroke_trace(kept, KeystrokeTraceConfig(
        n_sessions=n_sessions, mean_keystroke_ms=5.0, session_spread_ms=20.0,
        seed=seed))
    reqs = prepare_requests(qidx, trace, k=10)
    cfg = RuntimeConfig(max_batch=max_batch, slack_us=slack_us)
    tr = Tracer(sample_every=sample_every)
    rt = QACOnlineRuntime(fe, cfg, tracer=tr)
    got = rt.run_trace(reqs)
    rt_off = QACOnlineRuntime(fe, cfg)
    want = rt_off.run_trace(reqs)
    return reqs, rt, tr, got, want


def _assert_span_invariants(reqs, rt, tr, got, want):
    # 1. tracing never changes answers: bit parity with the untraced run
    for i, (g, w) in enumerate(zip(got, want)):
        np.testing.assert_array_equal(
            g, w, err_msg=f"tracing changed request {i}")
    trees = request_trees(tr.spans)
    sampled = [r for r in reqs if tr.want(r.idx)]
    assert set(trees) == {r.idx for r in sampled}
    kids_by_parent = span_children(tr.spans)
    for r in sampled:
        root, kids = trees[r.idx]
        # 2. the root covers [arrival, completion] on the virtual clock
        assert root["t0_us"] == r.t_us
        lat = rt.done_t_us[r.idx] - r.t_us
        assert root["dur_us"] == pytest.approx(lat, abs=1e-6)
        # 3. children nest inside the root and partition its interval:
        #    child-sum == e2e latency EXACTLY (same clock arithmetic)
        assert kids, f"request {r.idx} root span has no children"
        t0, t1 = root["t0_us"], root["t0_us"] + root["dur_us"]
        for c in kids:
            assert c["t0_us"] >= t0 - 1e-9
            assert c["t0_us"] + c["dur_us"] <= t1 + 1e-9
            assert kids_by_parent.get(c["id"], []) == []   # depth <= 2
        assert sum(c["dur_us"] for c in kids) == \
            pytest.approx(root["dur_us"], abs=1e-6)
        names = sorted(c["name"] for c in kids)
        if root["attrs"]["path"] == "miss":
            assert names == ["engine.service", "queue.wait"]
        else:
            assert names == [f"cache.{root['attrs']['path']}"]


@pytest.mark.parametrize("seed,sample_every,max_batch,slack_us", [
    (0, 1, 8, 2_000.0), (1, 3, 1, 0.0), (2, 16, 64, 500.0),
])
def test_span_tree_invariants_seeded(built, seed, sample_every, max_batch,
                                     slack_us):
    _assert_span_invariants(
        *_traced_run(built, 10, seed, sample_every, max_batch, slack_us))


# hypothesis is fine with module-scoped fixtures (its health check only
# rejects function scope, which would be silently reused across examples)
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), sample_every=st.integers(1, 17),
       max_batch=st.sampled_from([1, 4, 8, 32]),
       slack_us=st.floats(0.0, 5_000.0))
def test_span_tree_invariants_hypothesis(built, seed, sample_every,
                                         max_batch, slack_us):
    _assert_span_invariants(*_traced_run(
        built, 6, seed, sample_every, max_batch, slack_us))


def test_obs_config_factories():
    cfg = ObsConfig(trace_sample_every=4, slo_target_us=10_000.0)
    tr = cfg.tracer()
    assert tr.sample_every == 4
    aud = cfg.auditor(tracer=tr)
    assert aud.tracer is tr
    assert cfg.slo_monitor().target_us == 10_000.0
    assert isinstance(cfg.registry(), MetricsRegistry)
