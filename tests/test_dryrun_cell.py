"""Dry-run regression: one cell lowers+compiles on the 512-device mesh in a
subprocess (XLA device-count flags must precede jax init)."""
import json
import os
import subprocess
import sys

ROOT = "/root/repo" if os.path.exists("/root/repo/pyproject.toml") else os.path.join(os.path.dirname(__file__), "..")


def test_dryrun_single_cell_compiles():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "fm",
         "--shape", "serve_p99", "--single-pod-only"],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=420,
    )
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    rec = json.load(open(os.path.join(
        ROOT, "src", "repro", "launch", "dryrun_results", "pod16x16",
        "fm__serve_p99.json")))
    assert rec["ok"] and rec["n_chips"] == 256
    assert rec["collective_bytes"] > 0
