"""Codec round-trips + compressed-postings parity (ISSUE 7 satellites).

Every codec is checked two ways: deterministic edge-case sweeps (always run,
CI tier-1) and hypothesis property tests (run when hypothesis is installed,
skip otherwise — see tests/_hyp.py). The block-format tests pin the
compressed-on-chip contract: ``packed_lookup(ptr) == postings[ptr]`` for
every in-bounds pointer, under jit, for both codecs.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.core.codecs import (
    BitReader, BitWriter, PACK_BLOCK, PackedPostings, bitpack_bits,
    ef_decode, ef_encode, pack_postings, packed_lookup, pef_bits,
    unpack_postings, vbyte_decode, vbyte_encode,
)


def _sorted_values(rng, n, universe):
    return np.sort(rng.integers(0, universe, size=n).astype(np.int64))


def _csr_like(rng, n_lists, max_len, universe):
    """Concatenated ascending lists — ascending only WITHIN each list."""
    parts = [np.sort(rng.choice(universe, size=rng.integers(1, max_len),
                                replace=False))
             for _ in range(n_lists)]
    return np.concatenate(parts).astype(np.int64)


# ---------------------------------------------------------------- bit I/O
def test_bit_io_roundtrip_mixed():
    rng = np.random.default_rng(0)
    bw = BitWriter()
    fields = []
    for _ in range(200):
        nb = int(rng.integers(0, 48))
        v = int(rng.integers(0, 1 << nb)) if nb else 0
        bw.write(v, nb)
        fields.append((v, nb))
    r = BitReader(bw.array())
    for v, nb in fields:
        assert r.read(nb) == v


def test_bit_io_vectorized_matches_scalar():
    rng = np.random.default_rng(1)
    for nb in (0, 1, 5, 7, 13, 31, 32, 47, 63):
        vals = rng.integers(0, (1 << nb) if nb else 1, size=257)
        bw = BitWriter()
        bw.write_many(vals, nb)
        sw = BitWriter()
        for v in vals:
            sw.write(int(v), nb)
        assert np.array_equal(bw.array(), sw.array())
        got = BitReader(bw.array()).read_many(len(vals), nb)
        assert np.array_equal(got, vals)


def test_unary_many_roundtrip():
    rng = np.random.default_rng(2)
    gaps = rng.integers(0, 9, size=300)
    bw = BitWriter()
    bw.unary_many(gaps)
    assert np.array_equal(BitReader(bw.array()).unary_many(len(gaps)), gaps)


# ---------------------------------------------------------------- ef / vbyte
@pytest.mark.parametrize("n,universe", [(0, 1), (1, 1), (1, 1 << 31),
                                        (127, 1000), (128, 1000),
                                        (129, 10**6), (500, 1 << 31)])
def test_ef_roundtrip_edges(n, universe):
    rng = np.random.default_rng(n + universe % 97)
    v = _sorted_values(rng, n, universe)
    assert np.array_equal(ef_decode(ef_encode(v)), v)


def test_ef_all_equal_and_dense():
    v = np.full(130, 42, dtype=np.int64)
    assert np.array_equal(ef_decode(ef_encode(v)), v)
    v = np.arange(256, dtype=np.int64)
    assert np.array_equal(ef_decode(ef_encode(v)), v)


@pytest.mark.parametrize("n", [0, 1, 3, 100])
def test_vbyte_roundtrip(n):
    rng = np.random.default_rng(n)
    v = _sorted_values(rng, n, 1 << 31)
    assert np.array_equal(vbyte_decode(vbyte_encode(v), n), v)


def test_size_estimators_positive():
    rng = np.random.default_rng(3)
    v = _sorted_values(rng, 1000, 10**6)
    assert pef_bits(v) > 0
    assert bitpack_bits(v) > 0


# ---------------------------------------------------------------- block format
@pytest.mark.parametrize("codec", ["ef", "bitpack"])
@pytest.mark.parametrize("n", [1, 2, 127, 128, 129, 383, 1024])
def test_pack_roundtrip_sizes(codec, n):
    rng = np.random.default_rng(n)
    v = _sorted_values(rng, n, 1 << 20)
    pk = pack_postings(v, codec)
    assert pk.n_post == n
    assert np.array_equal(unpack_postings(pk), v.astype(np.int32))


@pytest.mark.parametrize("codec", ["ef", "bitpack"])
def test_pack_roundtrip_max_universe(codec):
    v = np.array([0, 1, 2**31 - 2, 2**31 - 1] * 40, dtype=np.int64)
    v.sort()
    pk = pack_postings(v, codec)
    assert np.array_equal(unpack_postings(pk), v.astype(np.int32))


def test_pack_roundtrip_unsorted_blocks():
    # CSR concatenation is NOT globally sorted; bitpack must not care and
    # ef must fall back to bitpack payloads for unsorted blocks
    rng = np.random.default_rng(7)
    v = _csr_like(rng, 40, 60, 5000)
    for codec in ("ef", "bitpack"):
        pk = pack_postings(v, codec)
        assert np.array_equal(unpack_postings(pk), v.astype(np.int32))


def test_pack_rejects_unknown_codec():
    with pytest.raises(ValueError):
        pack_postings(np.arange(10), "snappy")


def test_ef_codec_compresses_sorted_runs():
    # clustered sorted postings: EF payloads should beat plain bitpack
    rng = np.random.default_rng(11)
    v = np.sort(rng.choice(1 << 22, size=20_000, replace=False))
    bpi_ef = pack_postings(v, "ef").bits_per_int()
    bpi_bp = pack_postings(v, "bitpack").bits_per_int()
    assert bpi_ef < bpi_bp
    assert bpi_ef < 32.0 / 2     # >= 2x vs raw int32 on this distribution


def _lookup_all(pk: PackedPostings, ptrs):
    fn = jax.jit(lambda p: packed_lookup(
        pk.words, pk.base, pk.meta, pk.wordoff, p,
        n_post=pk.n_post, ef=pk.has_ef))
    return np.asarray(fn(jnp.asarray(ptrs, jnp.int32)))


@pytest.mark.parametrize("codec", ["ef", "bitpack"])
def test_packed_lookup_parity_jit(codec):
    rng = np.random.default_rng(13)
    v = _csr_like(rng, 60, 80, 1 << 20)
    pk = pack_postings(v, codec)
    ptrs = np.arange(len(v), dtype=np.int32)
    assert np.array_equal(_lookup_all(pk, ptrs), v.astype(np.int32))
    # out-of-bounds pointers clamp exactly like XLA's gather clamp
    oob = np.array([-5, -1, len(v), len(v) + 7, 2**30], dtype=np.int32)
    want = v.astype(np.int32)[np.clip(oob, 0, len(v) - 1)]
    assert np.array_equal(_lookup_all(pk, oob), want)


def test_packed_lookup_single_element():
    pk = pack_postings(np.array([77], dtype=np.int64), "ef")
    assert np.array_equal(_lookup_all(pk, np.array([-1, 0, 1, 100])),
                          np.full(4, 77, dtype=np.int32))


# ---------------------------------------------------------------- hypothesis
@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 2**31 - 1), min_size=0, max_size=600))
def test_hyp_ef_roundtrip(vals):
    v = np.sort(np.asarray(vals, dtype=np.int64))
    assert np.array_equal(ef_decode(ef_encode(v)), v)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 2**31 - 1), min_size=0, max_size=300))
def test_hyp_vbyte_roundtrip(vals):
    v = np.sort(np.asarray(vals, dtype=np.int64))
    assert np.array_equal(vbyte_decode(vbyte_encode(v), len(v)), v)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=520),
       st.sampled_from(["ef", "bitpack"]),
       st.booleans())
def test_hyp_pack_roundtrip_and_lookup(vals, codec, sort):
    v = np.asarray(vals, dtype=np.int64)
    if sort:
        v = np.sort(v)
    pk = pack_postings(v, codec)
    assert np.array_equal(unpack_postings(pk), v.astype(np.int32))
    ptrs = np.arange(len(v), dtype=np.int32)
    assert np.array_equal(_lookup_all(pk, ptrs), v.astype(np.int32))
