"""Fault-component unit tests (ISSUE 8 satellite): StepMonitor straggler
z-score edges, HeartbeatRegistry liveness deadlines on a fake clock, and
FaultInjector deterministic schedules (step-based and time-window)."""
import pytest

from repro.runtime.fault import (FaultInjector, HeartbeatRegistry,
                                 ReplicaFault, StepMonitor)


# -------------------------------------------------------------- StepMonitor
def test_stepmonitor_first_record_never_straggler():
    m = StepMonitor(warmup=0)
    assert m.record(0, 1e9) is False        # seeds the mean, no variance yet
    assert m.mean == 1e9


def test_stepmonitor_warmup_suppresses_detection():
    m = StepMonitor(alpha=0.5, z_threshold=1.0, warmup=10)
    for i in range(8):
        m.record(i, 1.0)
    # a wild outlier inside the warmup window must not flag
    assert m.record(8, 100.0) is False
    assert m.stragglers == []


def test_stepmonitor_zero_variance_no_division():
    """Identical step times leave var == 0; the next record must not divide
    by a zero stddev (and a constant stream is by definition straggler-free)."""
    m = StepMonitor(alpha=0.1, z_threshold=3.0, warmup=2)
    for i in range(50):
        assert m.record(i, 2.0) is False
    assert m.var == 0.0
    assert m.stragglers == []


def test_stepmonitor_flags_genuine_straggler():
    m = StepMonitor(alpha=0.1, z_threshold=3.0, warmup=5)
    rng_dts = [1.0, 1.1, 0.9, 1.0, 1.05, 0.95, 1.0, 1.1, 0.9, 1.0]
    for i, dt in enumerate(rng_dts):
        m.record(i, dt)
    assert m.record(len(rng_dts), 10.0) is True
    assert m.stragglers and m.stragglers[-1][1] == 10.0


def test_stepmonitor_ewma_tracks_level_shift():
    m = StepMonitor(alpha=0.3, warmup=0)
    for i in range(40):
        m.record(i, 1.0)
    for i in range(40, 80):
        m.record(i, 5.0)
    assert abs(m.mean - 5.0) < 0.01         # converged to the new level


# -------------------------------------------------------- HeartbeatRegistry
def test_heartbeat_deadlines_on_fake_clock():
    now = [0.0]
    reg = HeartbeatRegistry(timeout_s=10.0, clock=lambda: now[0])
    reg.beat(0)
    reg.beat(1)
    now[0] = 10.0
    # exactly AT the timeout is still alive (strict > deadline)
    assert reg.dead_hosts() == []
    now[0] = 10.0 + 1e-9
    assert reg.dead_hosts() == [0, 1]
    reg.beat(1)
    assert reg.dead_hosts() == [0]
    assert reg.alive_hosts() == [1]


def test_heartbeat_unknown_host_not_listed():
    reg = HeartbeatRegistry(timeout_s=1.0, clock=lambda: 100.0)
    assert reg.dead_hosts() == []
    assert reg.alive_hosts() == []


# ------------------------------------------------------------ FaultInjector
def test_injector_step_schedule_fires_once():
    inj = FaultInjector([3, 7], kill_hosts=[1])
    inj.check(0)
    with pytest.raises(RuntimeError):
        inj.check(3)
    inj.check(3)                            # already fired: no re-raise
    with pytest.raises(RuntimeError):
        inj.check(7)
    assert inj.fired == [3, 7]


def test_injector_replica_windows():
    faults = [ReplicaFault(0, 100.0, 200.0),
              ReplicaFault(1, 150.0, kind="stall")]
    inj = FaultInjector([], replica_faults=faults)
    assert inj.down(0, 99.9) is None
    assert inj.down(0, 100.0) is faults[0]  # half-open: down AT t_down
    assert inj.down(0, 199.9) is faults[0]
    assert inj.down(0, 200.0) is None       # ... up AT t_up
    assert inj.down(1, 1e12) is faults[1]   # open-ended window
    assert inj.down(2, 150.0) is None       # un-scheduled replica
    assert inj.faults_for(0) == [faults[0]]
    assert inj.faults_for(2) == []


def test_replica_fault_validation():
    with pytest.raises(ValueError):
        ReplicaFault(0, 100.0, 100.0)       # empty window
    with pytest.raises(ValueError):
        ReplicaFault(0, 200.0, 100.0)       # inverted window
    with pytest.raises(ValueError):
        ReplicaFault(0, 0.0, kind="flake")  # unknown kind
    ReplicaFault(0, 0.0, kind="stall")      # valid kinds construct fine
    ReplicaFault(0, 0.0, kind="kill")
