"""Mutation-trace invariants (ISSUE 9 satellite): the synthetic live-update
workload must be a well-formed merge of keystroke traffic and corpus
mutations — non-decreasing timestamps, session partials that are prefixes of
the session's final query, an exact mutation count, strictly-raising trend
scores, and followers that only type a mutated query after its mutation
lands. The freshness layer's parity suite (test_freshness.py) leans on every
one of these.
"""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.text import (KeystrokeTraceConfig, MutationEvent,
                        MutationTraceConfig, SynthLogConfig,
                        generate_keystroke_trace, generate_mutation_trace,
                        generate_query_log)


def _pool(seed=3, n=120):
    qs, sc = generate_query_log(SynthLogConfig(
        n_queries=n, vocab_size=40, mean_term_chars=4.0, seed=seed))
    return qs, sc


def _cfg(seed=0, n_sessions=6, n_mutations=None, mutation_rate=0.02,
         followers=4, p_oov=0.1):
    return MutationTraceConfig(
        keystrokes=KeystrokeTraceConfig(
            n_sessions=n_sessions, queries_per_session=1,
            mean_keystroke_ms=2.0, seed=seed),
        n_mutations=n_mutations, mutation_rate=mutation_rate,
        follower_sessions=followers, p_oov_term=p_oov, seed=seed)


def test_timestamps_sorted_and_kinds_partitioned():
    qs, sc = _pool()
    events = generate_mutation_trace(qs, sc, _cfg(n_mutations=9))
    ts = [e.t_us for e in events]
    assert ts == sorted(ts)
    kinds = {e.kind for e in events}
    assert kinds <= {"request", "insert", "trend"}
    for e in events:
        assert isinstance(e, MutationEvent)
        if e.kind == "request":
            assert e.session >= 0
        else:
            assert e.session == -1 and e.score > 0


@given(seed=st.integers(0, 31), n_mut=st.integers(0, 12))
@settings(max_examples=20, deadline=None)
def test_exact_mutation_count_override(seed, n_mut):
    qs, sc = _pool(seed=seed % 4)
    events = generate_mutation_trace(
        qs, sc, _cfg(seed=seed, n_mutations=n_mut))
    assert sum(e.kind != "request" for e in events) == n_mut


@given(seed=st.integers(0, 31),
       rate=st.floats(0.0, 0.2, allow_nan=False))
@settings(max_examples=20, deadline=None)
def test_rate_derived_mutation_count(seed, rate):
    qs, sc = _pool(seed=seed % 4)
    cfg = _cfg(seed=seed, mutation_rate=rate)
    n_base = len(generate_keystroke_trace(qs, cfg.keystrokes))
    events = generate_mutation_trace(qs, sc, cfg)
    assert (sum(e.kind != "request" for e in events)
            == max(1, round(rate * n_base)))


def _check_prefixes(seed):
    # queries_per_session=1: every request a session emits is a prefix of
    # that session's final (longest) string — backspaces only retype
    # shorter prefixes of the same target, and followers type exactly one
    # mutated query
    qs, sc = _pool(seed=seed % 4)
    events = generate_mutation_trace(qs, sc, _cfg(seed=seed, n_mutations=6))
    by_session = {}
    for e in events:
        if e.kind == "request":
            by_session.setdefault(e.session, []).append(e.query)
    assert by_session, "trace emitted no requests"
    for s, partials in by_session.items():
        final = max(partials, key=len)
        for p in partials:
            assert final.startswith(p), \
                f"session {s}: {p!r} not a prefix of {final!r}"


@given(seed=st.integers(0, 63))
@settings(max_examples=25, deadline=None)
def test_session_partials_prefix_their_final_query(seed):
    _check_prefixes(seed)


def _check_trend(seed):
    qs, sc = _pool(seed=seed % 4)
    events = generate_mutation_trace(qs, sc, _cfg(seed=seed, n_mutations=10))
    best = {}
    for q, s in zip(qs, sc):
        best[q] = max(best.get(q, -np.inf), float(s))
    for e in events:
        if e.kind == "trend":
            assert e.query in best, "trend target must come from the pool"
            assert e.score > best[e.query], \
                f"trend on {e.query!r}: {e.score} <= running best {best[e.query]}"
            best[e.query] = e.score
        elif e.kind == "insert":
            assert e.query not in best, "insert must be a NEW completion"
            best[e.query] = e.score


@given(seed=st.integers(0, 63))
@settings(max_examples=25, deadline=None)
def test_trend_strictly_raises_running_best(seed):
    _check_trend(seed)


def _check_followers(seed):
    qs, sc = _pool(seed=seed % 4)
    cfg = _cfg(seed=seed, n_mutations=8, followers=6)
    events = generate_mutation_trace(qs, sc, cfg)
    mut_t = {}   # query -> earliest mutation time
    for e in events:
        if e.kind != "request":
            mut_t.setdefault(e.query, e.t_us)
    base_sessions = cfg.keystrokes.n_sessions
    followers = {}
    for e in events:
        if e.kind == "request" and e.session >= base_sessions:
            followers.setdefault(e.session, []).append(e)
    assert followers, "follower sessions must emit traffic"
    for s, evs in followers.items():
        final = max((e.query for e in evs), key=len)
        assert final in mut_t, \
            f"follower session {s} types {final!r}, which was never mutated"
        assert min(e.t_us for e in evs) > mut_t[final]


@given(seed=st.integers(0, 63))
@settings(max_examples=25, deadline=None)
def test_followers_start_after_their_mutation(seed):
    _check_followers(seed)


@pytest.mark.parametrize("seed", [0, 7, 23])
def test_invariants_fixed_seeds(seed):
    # always-on versions of the property tests (the @given runs skip when
    # hypothesis is absent)
    _check_prefixes(seed)
    _check_trend(seed)
    _check_followers(seed)


def test_deterministic_and_oov_inserts():
    qs, sc = _pool()
    cfg = _cfg(seed=9, n_mutations=20, p_oov=1.0)
    a = generate_mutation_trace(qs, sc, cfg)
    b = generate_mutation_trace(qs, sc, cfg)
    assert a == b
    vocab = {t for q in qs for t in q.split()}
    inserts = [e for e in a if e.kind == "insert"]
    assert inserts, "p_oov=1 trace should still produce inserts"
    for e in inserts:
        assert e.query.split()[-1] not in vocab, \
            "p_oov_term=1.0: every insert's last term must be out-of-vocab"


def test_config_validation():
    with pytest.raises(ValueError):
        MutationTraceConfig(trend_boost=1.0)
    with pytest.raises(ValueError):
        MutationTraceConfig(mutation_rate=-0.1)
    with pytest.raises(ValueError):
        MutationTraceConfig(tail_fraction=1.5)
    with pytest.raises(ValueError):
        MutationTraceConfig(n_mutations=-1)
    with pytest.raises(ValueError):
        MutationTraceConfig(follower_sessions=-2)
    with pytest.raises(ValueError):
        generate_mutation_trace(["a"], [1.0, 2.0])
