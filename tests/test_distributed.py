"""Distributed substrate: checkpointing, fault tolerance, compression,
striped QAC serving, codecs, embedding bags, data pipelines."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.ckpt import CheckpointManager, save_checkpoint, restore_checkpoint
from repro.runtime import (StepMonitor, HeartbeatRegistry, ElasticPolicy,
                           FaultInjector, TrainDriver)
from repro.distributed.compression import compress, decompress, compress_tree, init_ef
from repro.optim.adamw import AdamWConfig, init_opt_state, adamw_update


# ------------------------------------------------------------- checkpointing
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16), "d": jnp.int32(7)}}
    save_checkpoint(str(tmp_path), 5, tree, {"note": "x"})
    got, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(10))
    assert got["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_manager_retention_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    tree = {"w": jnp.zeros((4,))}
    for s in (10, 20, 30, 40):
        mgr.save(s, {"w": jnp.full((4,), s, jnp.float32)})
    mgr.wait()
    assert mgr.latest_step() == 40
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [30, 40]
    got, step = mgr.restore(tree)
    assert step == 40 and float(got["w"][0]) == 40


def test_checkpoint_restart_resumes_training(tmp_path):
    """Full fault-tolerance drill: train, crash, restore, converge on."""
    rng = jax.random.PRNGKey(0)
    w_true = jnp.asarray([2.0, -1.0])
    X = jax.random.normal(rng, (64, 2))
    y = X @ w_true

    def loss(w):
        return jnp.mean((X @ w - y) ** 2)

    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200,
                      clip_norm=0)
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    inject = FaultInjector(fail_at_steps=[25])

    def step_fn(state, step):
        inject.check(step)
        params, opt = state
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(cfg, params, g, opt)
        return (params, opt)

    def save_fn(state, step):
        mgr.save(step, {"params": state[0], "opt": state[1]})

    template = {"params": jnp.zeros(2), "opt": init_opt_state(jnp.zeros(2))}

    def restore_fn():
        got, step = mgr.restore(template)
        return (got["params"], got["opt"]), step

    driver = TrainDriver(step_fn, save_fn, restore_fn, ckpt_every=10)
    w0 = jnp.zeros(2)
    (w, opt), step = driver.run((w0, init_opt_state(w0)), 0, 120)
    assert step == 120
    assert driver.restarts == 1
    assert float(loss(w)) < 1e-2  # converged despite the crash


# ------------------------------------------------------------- fault tolerance
def test_step_monitor_flags_stragglers():
    mon = StepMonitor(z_threshold=3.0, warmup=3)
    for i in range(30):
        mon.record(i, 0.1 + 0.001 * (i % 3))
    assert not mon.stragglers
    assert mon.record(30, 1.5)  # 15x slower -> straggler
    assert mon.stragglers


def test_heartbeat_and_elastic_policy():
    t = [0.0]
    hb = HeartbeatRegistry(timeout_s=10, clock=lambda: t[0])
    for h in range(8):
        hb.beat(h)
    t[0] = 5.0
    for h in range(6):
        hb.beat(h)          # hosts 6,7 go silent
    t[0] = 12.0
    assert sorted(hb.dead_hosts()) == [6, 7]
    pol = ElasticPolicy(chips_per_host=32, model_axis=16)
    assert pol.propose_mesh(8) == (16, 16)     # full: 256 chips
    assert pol.propose_mesh(6) == (8, 16)      # 192 chips -> 8x16=128 used
    assert pol.propose_mesh(0) is None


# ------------------------------------------------------------- compression
@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_compression_error_feedback_bounded(seed):
    rng = np.random.default_rng(seed % 2**32)
    g = jnp.asarray(rng.normal(size=(64,)) * 10, jnp.float32)
    q, scale, ef = compress(g)
    err = np.abs(np.asarray(decompress(q, scale) + ef - g))
    assert err.max() < 1e-4  # deq + residual reconstructs exactly (fp32)
    assert np.abs(np.asarray(ef)).max() <= float(scale) * 0.5 + 1e-6


def test_compression_error_feedback_accumulates_correctly():
    """EF-SGD property: sum of dequantized grads -> sum of true grads."""
    rng = np.random.default_rng(0)
    gs = [jnp.asarray(rng.normal(size=(32,)), jnp.float32) for _ in range(50)]
    ef = jnp.zeros((32,))
    total_deq = jnp.zeros((32,))
    for g in gs:
        q, scale, ef = compress(g, ef)
        total_deq = total_deq + decompress(q, scale)
    total_true = sum(gs)
    # residual is bounded by one quantization step
    np.testing.assert_allclose(np.asarray(total_deq + ef),
                               np.asarray(total_true), rtol=1e-4, atol=1e-4)


def test_compress_tree_shapes():
    params = {"a": jnp.ones((4, 4)), "b": jnp.ones((8,))}
    ef = init_ef(params)
    deq, ef2 = compress_tree(params, ef)
    assert jax.tree_util.tree_structure(deq) == jax.tree_util.tree_structure(params)


# ------------------------------------------------------------- striped QAC
def test_striped_qac_matches_single_index():
    from repro.text import SynthLogConfig, generate_query_log
    from repro.core import build_qac_index, parse_queries
    from repro.core.builder import build_corpus
    from repro.core.striped import build_striped
    from repro.serve.qac import qac_serve_step, qac_serve_striped

    qs, sc = generate_query_log(SynthLogConfig(n_queries=600, vocab_size=150,
                                               mean_term_chars=4.0, seed=9))
    qidx, kept, _ = build_qac_index(qs, sc)
    dictionary, rows, sc2, kept2 = build_corpus(qs, sc)
    order = np.lexsort(tuple(rows[:, j] for j in range(rows.shape[1] - 1, -1, -1)) + (-sc2,))
    d_of_row = np.empty(len(rows), dtype=np.int32)
    d_of_row[order] = np.arange(len(rows), dtype=np.int32)
    for n_stripes in (2, 4):
        striped = build_striped(rows, d_of_row, dictionary.n_terms, n_stripes)
        rng = np.random.default_rng(n_stripes)
        partials = []
        for qi in rng.integers(0, len(kept), 24):
            toks = kept[qi].split()
            cut = rng.integers(1, len(toks[-1]) + 1)
            partials.append(" ".join(toks[:-1] + [toks[-1][:cut]]))
        pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, partials)
        got = qac_serve_striped(striped, qidx.dictionary, pids, plen, suf, slen, k=10)
        want = qac_serve_step(qidx, pids, plen, suf, slen, k=10)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------------- codecs
@given(st.lists(st.integers(0, 10**6), min_size=1, max_size=200))
@settings(max_examples=25, deadline=None)
def test_ef_roundtrip(vals):
    from repro.core.codecs import ef_encode, ef_decode
    v = np.unique(np.asarray(vals, dtype=np.int64))
    got = ef_decode(ef_encode(v))
    np.testing.assert_array_equal(got, v)


@given(st.lists(st.integers(0, 10**6), min_size=1, max_size=200))
@settings(max_examples=25, deadline=None)
def test_vbyte_roundtrip(vals):
    from repro.core.codecs import vbyte_encode, vbyte_decode
    v = np.unique(np.asarray(vals, dtype=np.int64))
    got = vbyte_decode(vbyte_encode(v), len(v))
    np.testing.assert_array_equal(got, v)


# ------------------------------------------------------------- embedding bags
def test_embedding_bag_padded_vs_csr():
    from repro.models.recsys import embedding_bag, embedding_bag_csr
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(50, 8)), jnp.float32)
    ids = rng.integers(0, 50, (4, 6)).astype(np.int32)
    lens = np.array([6, 3, 1, 5])
    mask = (np.arange(6)[None] < lens[:, None]).astype(np.float32)
    padded = embedding_bag(table, jnp.asarray(ids), jnp.asarray(mask))
    flat, seg = [], []
    for i in range(4):
        flat += ids[i, : lens[i]].tolist()
        seg += [i] * lens[i]
    csr = embedding_bag_csr(table, jnp.asarray(flat), jnp.asarray(seg), 4)
    # masked-matmul vs segment_sum accumulate in different orders: one-ULP
    # fp32 differences are expected, so allow a small absolute tolerance
    np.testing.assert_allclose(np.asarray(padded), np.asarray(csr),
                               rtol=1e-6, atol=1e-5)


# ------------------------------------------------------------- data pipelines
def test_neighbor_sampler_validity():
    from repro.data.graphs import random_graph, build_csr, neighbor_sample
    src, dst = random_graph(500, 4000, seed=1)
    indptr, indices = build_csr(src, dst, 500)
    rng = np.random.default_rng(0)
    seeds = rng.choice(500, 16, replace=False).astype(np.int32)
    nodes, senders, receivers = neighbor_sample(indptr, indices, seeds, (5, 3), rng)
    assert (nodes[:16] == seeds).all()
    edge_set = set(zip(src.tolist(), dst.tolist()))
    for s, r in zip(senders, receivers):
        assert (int(nodes[s]), int(nodes[r])) in edge_set


def test_lm_pipeline_shapes():
    from repro.data.lm import TokenStream, lm_batches
    stream = TokenStream.synthetic(vocab=100, n_docs=10, mean_len=128)
    it = lm_batches(stream, batch=4, seq_len=16)
    toks, tgts, mask = next(it)
    assert toks.shape == (4, 16) and tgts.shape == (4, 16)
    np.testing.assert_array_equal(toks[:, 1:], tgts[:, :-1])


@given(st.integers(0, 10**6), st.sampled_from([2, 4, 8, 16]))
@settings(max_examples=30, deadline=None)
def test_butterfly_topk_merge_equals_global_topk(seed, n_shards):
    """The §Perf butterfly merge (XOR-pair exchange, keep min-k) must equal
    the global min-k after log2(S) rounds — simulated shard-by-shard here
    exactly as serve/qac.py's ppermute loop computes it."""
    k = 10
    rng = np.random.default_rng(seed)
    INF = 2**31 - 1
    shard_vals = []
    for s in range(n_shards):
        n = rng.integers(0, 25)
        v = np.sort(rng.choice(10**6, size=n, replace=False)).astype(np.int64)
        shard_vals.append(np.pad(v[:k], (0, max(0, k - len(v[:k]))),
                                 constant_values=INF))
    cur = [np.array(v) for v in shard_vals]
    for bit in range(n_shards.bit_length() - 1):
        nxt = []
        for i in range(n_shards):
            both = np.concatenate([cur[i], cur[i ^ (1 << bit)]])
            nxt.append(np.sort(both)[:k])
        cur = nxt
    want = np.sort(np.concatenate(shard_vals))[:k]
    for i in range(n_shards):
        np.testing.assert_array_equal(cur[i], want)
