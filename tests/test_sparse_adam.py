"""Lazy sparse-row AdamW: exactness vs dense AdamW + convergence."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWConfig, init_opt_state, adamw_update
from repro.optim.sparse_adam import sparse_table_update, dedup_row_grads
from repro.models.recsys import RecsysConfig, FMModel, bce_loss
from repro.train.steps import (init_train_state, make_recsys_train_step,
                               make_fm_sparse_train_step, TrainState)


def test_dedup_row_grads_sums_duplicates():
    ids = jnp.asarray([3, 1, 3, 7, 1, 3], jnp.int32)
    g = jnp.arange(6, dtype=jnp.float32)[:, None] + 1     # rows 1..6
    uids, ug, valid = dedup_row_grads(ids, g, 10)
    got = {int(i): float(v[0]) for i, v in zip(uids, ug) if int(i) < 10}
    assert got == {1: 2 + 5, 3: 1 + 3 + 6, 7: 4}


def test_sparse_update_matches_dense_when_all_rows_touched():
    """wd=0, clip off, every row touched => bit-compatible with dense Adam."""
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=0, warmup_steps=0,
                      total_steps=100)
    rcfg = RecsysConfig(name="t", kind="fm", embed_dim=4, n_sparse=2,
                        field_vocab=3)
    model = FMModel(rcfg)
    params = model.init_params(jax.random.PRNGKey(0))
    # batch hitting every (field, id) pair exactly once per field
    ids = jnp.asarray([[0, 1], [1, 2], [2, 0]], jnp.int32)   # B=3
    labels = jnp.asarray([1.0, 0.0, 1.0])
    batch = {"feats": {"sparse_ids": ids}, "labels": labels}

    dense_step = make_recsys_train_step(model, cfg)
    sparse_step = make_fm_sparse_train_step(model, cfg)
    sd = init_train_state(params)
    ss = init_train_state(params)
    for _ in range(3):
        sd, md = dense_step(sd, batch)
        ss, ms = sparse_step(ss, batch)
    np.testing.assert_allclose(np.asarray(sd.params["tables"]),
                               np.asarray(ss.params["tables"]),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(md["loss"]), float(ms["loss"]), rtol=1e-5)


def test_sparse_fm_converges():
    rng = np.random.default_rng(0)
    rcfg = RecsysConfig(name="t", kind="fm", embed_dim=8, n_sparse=6,
                        field_vocab=50)
    model = FMModel(rcfg)
    params = model.init_params(jax.random.PRNGKey(1))
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, clip_norm=0)
    step = jax.jit(make_fm_sparse_train_step(model, cfg))
    state = init_train_state(params)
    # learnable rule: label = parity of first field id
    losses = []
    for i in range(150):
        ids = rng.integers(0, 50, (64, 6)).astype(np.int32)
        labels = (ids[:, 0] % 2).astype(np.float32)
        batch = {"feats": {"sparse_ids": jnp.asarray(ids)},
                 "labels": jnp.asarray(labels)}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-20:]) < np.mean(losses[:20]) * 0.6
