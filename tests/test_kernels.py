"""Per-kernel interpret-mode validation: shape/dtype sweeps vs ref.py oracles."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.intersect.ops import (conjunctive_scan,
                                         conjunctive_scan_packed)
from repro.kernels.intersect.ref import (conjunctive_scan_ref,
                                         conjunctive_scan_packed_ref)
from repro.core.codecs import pack_postings
from repro.kernels.rmq.ops import rmq_query
from repro.kernels.flash_attention import flash_attention, flash_decode
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.fm_pairwise.ops import fm_pairwise
from repro.kernels.fm_pairwise.ref import fm_pairwise_ref
from repro.core.rmq import RangeMin, BLOCK

INF = 2**31 - 1


# ------------------------------------------------------------- intersect
def _make_intersect_case(rng, B, T, P, L, M, universe):
    cands = np.sort(rng.choice(universe, (B, T), replace=True), axis=1).astype(np.int32)
    lists = np.full((B, P, L), INF, np.int32)
    lens = rng.integers(0, L + 1, (B, P)).astype(np.int32)
    for b in range(B):
        for p in range(P):
            vals = np.unique(rng.choice(universe, lens[b, p]))
            # force some overlap with candidates
            take = rng.integers(0, T, size=max(1, lens[b, p] // 2))
            vals = np.unique(np.concatenate([vals, cands[b, take]]))[: lens[b, p]]
            lens[b, p] = len(vals)
            lists[b, p, : len(vals)] = np.sort(vals)
    fwd = rng.integers(0, 50, (B, T, M)).astype(np.int32)
    tlo = rng.integers(0, 40, B).astype(np.int32)
    thi = (tlo + rng.integers(0, 15, B)).astype(np.int32)
    return (jnp.asarray(cands), jnp.asarray(lists), jnp.asarray(lens),
            jnp.asarray(fwd), jnp.asarray(tlo), jnp.asarray(thi))


@pytest.mark.parametrize("B,T,P,L,M", [
    (2, 128, 2, 64, 4), (3, 256, 4, 128, 8), (1, 128, 1, 16, 2),
])
def test_intersect_kernel_matches_ref(B, T, P, L, M):
    rng = np.random.default_rng(B * 100 + T)
    args = _make_intersect_case(rng, B, T, P, L, M, universe=500)
    got = conjunctive_scan(*args, use_kernel=True, interpret=True)
    want = conjunctive_scan_ref(*args)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------- intersect (packed)
def _make_packed_case(rng, B, T, P, n_lists, max_len, universe, codec):
    """CSR postings + per-slot spans; raw probe-list tiles for the oracle."""
    lists = [np.sort(rng.choice(universe, size=rng.integers(1, max_len),
                                replace=False)).astype(np.int64)
             for _ in range(n_lists)]
    postings = np.concatenate(lists)
    offs = np.concatenate([[0], np.cumsum([len(l) for l in lists])])
    pk = pack_postings(postings, codec)

    cands = np.sort(rng.choice(universe, (B, T)), axis=1).astype(np.int32)
    starts = np.zeros((B, P), np.int32)
    ends = np.zeros((B, P), np.int32)
    L = 1 << max(1, (max_len - 1).bit_length())
    raw_lists = np.full((B, P, L), INF, np.int32)
    raw_lens = np.zeros((B, P), np.int32)
    for b in range(B):
        for p in range(P):
            if rng.integers(0, 4) == 0:        # unused slot
                continue
            li = rng.integers(0, n_lists)
            starts[b, p], ends[b, p] = offs[li], offs[li + 1]
            raw_lens[b, p] = len(lists[li])
            raw_lists[b, p, : len(lists[li])] = lists[li]
            # seed overlap so some candidates are members
            take = rng.integers(0, len(lists[li]), size=T // 4)
            cands[b, rng.integers(0, T, size=T // 4)] = lists[li][take]
        cands[b] = np.sort(cands[b])
    M = 4
    fwd = rng.integers(0, 50, (B, T, M)).astype(np.int32)
    tlo = rng.integers(0, 40, B).astype(np.int32)
    thi = (tlo + rng.integers(0, 15, B)).astype(np.int32)
    j = lambda a: jnp.asarray(a)
    packed_args = (j(cands), j(starts), j(ends), j(fwd), j(tlo), j(thi), pk)
    raw_args = (j(cands), j(raw_lists), j(raw_lens), j(fwd), j(tlo), j(thi))
    return packed_args, raw_args


@pytest.mark.parametrize("codec", ["ef", "bitpack"])
@pytest.mark.parametrize("B,T,P", [(2, 128, 2), (3, 256, 4)])
def test_intersect_packed_kernel_matches_ref_and_raw(codec, B, T, P):
    """Compressed probe route: Pallas kernel == packed ref == the RAW list
    oracle on the same spans (the bit-identity contract of ISSUE 7)."""
    rng = np.random.default_rng(B * 10 + T + (codec == "ef"))
    packed_args, raw_args = _make_packed_case(
        rng, B, T, P, n_lists=12, max_len=90, universe=4000, codec=codec)
    got_k = conjunctive_scan_packed(*packed_args, use_kernel=True,
                                    interpret=True)
    got_r = conjunctive_scan_packed(*packed_args, use_kernel=False)
    want = conjunctive_scan_ref(*raw_args)
    np.testing.assert_array_equal(np.asarray(got_k), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got_r), np.asarray(want))


def test_intersect_packed_all_slots_unused():
    """start == end everywhere: membership trivially true, only the forward
    range + INF checks decide."""
    rng = np.random.default_rng(5)
    packed_args, raw_args = _make_packed_case(
        rng, 2, 128, 3, n_lists=4, max_len=30, universe=300, codec="ef")
    c, _, _, fwd, tlo, thi, pk = packed_args
    z = jnp.zeros_like(packed_args[1])
    got = conjunctive_scan_packed(c, z, z, fwd, tlo, thi, pk,
                                  use_kernel=True, interpret=True)
    want = conjunctive_scan_ref(raw_args[0], raw_args[1],
                                jnp.zeros_like(raw_args[2]), fwd, tlo, thi)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------------- rmq
@pytest.mark.parametrize("n,B", [(1000, 64), (40_000, 128)])
def test_rmq_kernel_matches_numpy(n, B):
    rng = np.random.default_rng(n)
    vals = rng.integers(0, 1_000_000, n).astype(np.int32)
    rm = RangeMin.build(vals)
    p = rng.integers(0, n, B).astype(np.int32)
    q = np.minimum(p + rng.integers(0, n, B), n - 1).astype(np.int32)
    p, q = np.minimum(p, q), np.maximum(p, q)
    pos, val = rmq_query(rm.values, rm.st_pos, jnp.asarray(p), jnp.asarray(q),
                         use_kernel=True, interpret=True)
    for i in range(B):
        want = vals[p[i] : q[i] + 1].min()
        assert int(val[i]) == want, i
        assert vals[int(pos[i])] == want


def test_rmq_kernel_matches_ref_path():
    rng = np.random.default_rng(7)
    vals = rng.integers(0, 10**6, 5000).astype(np.int32)
    rm = RangeMin.build(vals)
    p = rng.integers(0, 5000, 32).astype(np.int32)
    q = np.minimum(p + rng.integers(0, 500, 32), 4999).astype(np.int32)
    a = rmq_query(rm.values, rm.st_pos, jnp.asarray(p), jnp.asarray(q), use_kernel=True)
    b = rmq_query(rm.values, rm.st_pos, jnp.asarray(p), jnp.asarray(q), use_kernel=False)
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


# ------------------------------------------------------------- flash attention
@pytest.mark.parametrize("B,H,G,S,D,causal,window,softcap", [
    (1, 4, 4, 256, 64, True, 0, 0.0),      # MHA causal
    (2, 4, 2, 256, 64, True, 0, 0.0),      # GQA
    (1, 4, 1, 384, 64, True, 128, 0.0),    # MQA + sliding window (gemma2 local)
    (1, 2, 2, 256, 128, True, 0, 50.0),    # softcap (gemma2)
    (1, 2, 2, 128, 64, False, 0, 0.0),     # bidirectional
])
def test_flash_attention_matches_ref(B, H, G, S, D, causal, window, softcap):
    rng = np.random.default_rng(S + H)
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, G, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, G, S, D)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, use_kernel=True, interpret=True,
                          block_q=128, block_k=128)
    want = flash_attention_ref(q, k, v, causal=causal, window=window,
                               softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), dtype)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), dtype)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), dtype)
    got = flash_attention(q, k, v, use_kernel=True, interpret=True)
    want = flash_attention_ref(q, k, v)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_flash_decode_matches_full_attention():
    """Decode with a partially-filled cache == full attention's last row."""
    rng = np.random.default_rng(1)
    B, H, G, Skv, D = 2, 4, 2, 512, 64
    q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, G, Skv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, G, Skv, D)), jnp.float32)
    kv_len = jnp.asarray([300, 512], jnp.int32)
    got = flash_decode(q, k, v, kv_len, use_kernel=True, interpret=True)
    want = flash_attention_ref(q[:, :, None, :], k, v, causal=True,
                               kv_len=kv_len)[:, :, 0, :]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_window():
    rng = np.random.default_rng(2)
    B, H, G, Skv, D = 1, 2, 1, 256, 64
    q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, G, Skv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, G, Skv, D)), jnp.float32)
    kv_len = jnp.asarray([256], jnp.int32)
    got = flash_decode(q, k, v, kv_len, window=64, use_kernel=True, interpret=True)
    want = flash_attention_ref(q[:, :, None, :], k, v, causal=True, window=64,
                               kv_len=kv_len)[:, :, 0, :]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------- fm pairwise
@pytest.mark.parametrize("B,F,D,dtype", [
    (256, 39, 16, jnp.float32), (512, 8, 64, jnp.float32),
    (256, 39, 16, jnp.bfloat16),
])
def test_fm_pairwise_matches_ref(B, F, D, dtype):
    rng = np.random.default_rng(B + F)
    emb = jnp.asarray(rng.normal(size=(B, F, D)), dtype)
    got = fm_pairwise(emb, use_kernel=True, interpret=True)
    want = fm_pairwise_ref(emb)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_fm_pairwise_explicit_pairs():
    """Sum-square trick == explicit sum over pairs."""
    rng = np.random.default_rng(3)
    emb = jnp.asarray(rng.normal(size=(8, 10, 6)), jnp.float32)
    got = fm_pairwise(emb, use_kernel=True, interpret=True)
    e = np.asarray(emb)
    want = np.zeros(8)
    for i in range(10):
        for j in range(i + 1, 10):
            want += (e[:, i] * e[:, j]).sum(-1)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- xla flash (scan)
from repro.kernels.flash_attention.xla_flash import xla_flash_attention


@pytest.mark.parametrize("causal,window,softcap,G", [
    (True, 0, 0.0, 4), (True, 96, 0.0, 2), (False, 0, 30.0, 1),
])
def test_xla_flash_matches_ref(causal, window, softcap, G):
    rng = np.random.default_rng(5)
    B, H, S, D = 2, 4, 320, 32
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, G, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, G, S, D)), jnp.float32)
    got = xla_flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, block_k=128)
    want = flash_attention_ref(q, k, v, causal=causal, window=window,
                               softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_xla_flash_grads_finite():
    rng = np.random.default_rng(6)
    q = jnp.asarray(rng.normal(size=(1, 2, 256, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 16)), jnp.float32)
    g = jax.grad(lambda a, b, c: xla_flash_attention(a, b, c, block_k=64).sum(),
                 argnums=(0, 1, 2))(q, k, v)
    for x in g:
        assert np.isfinite(np.asarray(x)).all()
