"""Guarded hypothesis import (see requirements-dev.txt).

``pytest.importorskip``-style guard at per-test granularity: when hypothesis
is installed the real ``given``/``settings``/``st`` pass through and the
property tests run; when it is missing, only the ``@given`` tests skip (with
a clear reason) and every other test in the module still collects and runs —
a module-level importorskip would throw those away too.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # zero-arg replacement: keeps pytest from resolving the strategy
            # parameters as fixtures on the undecorated signature
            def skipper():
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
