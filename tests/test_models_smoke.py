"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + no NaNs (deliverable f)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch, list_archs
from repro.optim.adamw import AdamWConfig
from repro.train.steps import init_train_state, make_lm_train_step

LM_ARCHS = ["smollm-360m", "qwen3-14b", "gemma2-2b", "qwen2-moe-a2.7b",
            "qwen3-moe-235b-a22b"]


def test_registry_complete():
    assert len(list_archs()) == 11
    cells = []
    for a in list_archs():
        cells.extend(get_arch(a).cells())
    # 40 assigned cells + 2 qac cells
    assert len(cells) == 42


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke_train_step(arch_id):
    arch = get_arch(arch_id)
    model = arch.smoke_model()
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, model.cfg.vocab)
    batch = {"tokens": toks, "targets": toks, "mask": jnp.ones((B, S))}
    step = make_lm_train_step(model, AdamWConfig(total_steps=10))
    state = init_train_state(params)
    state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    logits, aux, _ = model.forward(state.params, toks)
    assert logits.shape == (B, S, model.cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke_decode(arch_id):
    arch = get_arch(arch_id)
    model = arch.smoke_model()
    params = model.init_params(jax.random.PRNGKey(0))
    B = 2
    cache = model.init_cache(B, 16)
    tok = jnp.zeros((B,), jnp.int32)
    for _ in range(4):
        logits, cache = model.decode_step(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert logits.shape == (B, model.cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_gnn_smoke_energy_and_class():
    from repro.models.mace import MACEModel, GraphBatch
    from repro.data.graphs import batch_molecules
    import dataclasses
    arch = get_arch("mace")
    rng = np.random.default_rng(0)
    # energy task
    model = MACEModel(arch.smoke_cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    pos, sp, nm, s, r, em, gi = batch_molecules(rng, 4, 8, 16, 8)
    gb = GraphBatch(jnp.asarray(pos), jnp.asarray(sp), jnp.asarray(nm),
                    jnp.asarray(s), jnp.asarray(r), jnp.asarray(em),
                    jnp.asarray(gi), 4)
    E = model.forward(params, gb)
    assert E.shape == (4,) and np.isfinite(np.asarray(E)).all()
    # node classification task
    cfg2 = dataclasses.replace(arch.smoke_cfg, d_feat=12, n_classes=5,
                               task="node_class")
    m2 = MACEModel(cfg2)
    p2 = m2.init_params(jax.random.PRNGKey(1))
    gb2 = dataclasses.replace(gb, node_feat=jnp.asarray(
        rng.normal(size=(pos.shape[0], 12)), jnp.float32))
    logits = m2.forward(p2, gb2)
    assert logits.shape == (pos.shape[0], 5)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch_id", ["fm", "din", "bst", "mind"])
def test_recsys_smoke(arch_id):
    from repro.configs.recsys_common import MODEL_CLS
    from repro.data.recsys_data import recsys_batch
    from repro.models.recsys import bce_loss
    arch = get_arch(arch_id)
    cfg = arch.smoke_cfg
    model = MODEL_CLS[cfg.kind](cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    feats, labels = recsys_batch(cfg, 16, rng)
    feats = {k: jnp.asarray(v) for k, v in feats.items()}
    logits = model.forward(params, feats)
    assert logits.shape == (16,)
    assert np.isfinite(np.asarray(logits)).all()
    g = jax.grad(lambda p: bce_loss(model.forward(p, feats), jnp.asarray(labels)))(params)
    gn = sum(float(jnp.sum(x * x)) for x in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0


def test_mace_rotation_invariance():
    """Property: E(3) invariance of predicted energies."""
    from repro.models.mace import MACEModel, GraphBatch
    from repro.data.graphs import batch_molecules
    import dataclasses
    arch = get_arch("mace")
    model = MACEModel(arch.smoke_cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    pos, sp, nm, s, r, em, gi = batch_molecules(rng, 2, 10, 24, 8)
    gb = GraphBatch(jnp.asarray(pos), jnp.asarray(sp), jnp.asarray(nm),
                    jnp.asarray(s), jnp.asarray(r), jnp.asarray(em),
                    jnp.asarray(gi), 2)
    E0 = model.forward(params, gb)
    # random rotation (Rodrigues) + translation
    axis = rng.normal(size=3)
    axis /= np.linalg.norm(axis)
    th = 1.234
    K = np.array([[0, -axis[2], axis[1]], [axis[2], 0, -axis[0]],
                  [-axis[1], axis[0], 0]])
    R = np.eye(3) + np.sin(th) * K + (1 - np.cos(th)) * (K @ K)
    pos2 = pos @ R.T + np.array([1.0, -2.0, 0.5])
    gb2 = dataclasses.replace(gb, positions=jnp.asarray(pos2, jnp.float32))
    E1 = model.forward(params, gb2)
    np.testing.assert_allclose(np.asarray(E0), np.asarray(E1), rtol=2e-4, atol=1e-5)
