"""Freshness tier (ISSUE 9): time-indexed parity across live mutations and
generation swaps.

THE acceptance gate: replay a mutation trace through ``GenerationalQAC``
(delta tier + k-way merge + >= 1 mid-trace rebuild-and-swap) and every
answer must be bit-identical to a from-scratch ``build_qac_index`` of its
own visible version ``(generation, seq)`` — the freshness extension of the
repo's parity-oracle discipline. Plus: the delta tier's insert algebra and
postings narrowing, exactly-once cache invalidation per swap, the
generation-tagged runtime contract, cluster-wide swap propagation, and
config validation end to end (``FreshnessConfig`` and
``QACArch.freshness_config``).
"""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import build_qac_index
from repro.core.delta import DeltaIndex, MainCorpusView
from repro.serve import QACFrontend
from repro.serve.cluster import (ClusterConfig, QACServingCluster,
                                 check_cluster_parity_timed)
from repro.serve.freshness import (FreshnessConfig, GenerationalQAC,
                                   parse_and_prepare)
from repro.serve.runtime import QACOnlineRuntime, RuntimeConfig
from repro.text import (KeystrokeTraceConfig, MutationTraceConfig,
                        SynthLogConfig, generate_mutation_trace,
                        generate_query_log)

_RT = dict(max_batch=8, slack_us=2_000.0)


# ------------------------------------------------------------ delta tier
@pytest.fixture(scope="module")
def tiny():
    qs = ["alpha beta", "alpha gamma", "beta gamma", "delta", "alpha",
          "gamma delta", "beta", "epsilon", "alpha delta"]
    sc = [9.0, 8.0, 7.0, 6.0, 5.0, 4.0, 3.0, 2.0, 1.0]
    qidx, kept, scores = build_qac_index(qs, sc)
    return MainCorpusView(qidx, kept, scores)


def test_delta_insert_outcome_algebra(tiny):
    d = DeltaIndex(tiny, capacity=16)
    assert d.insert("alpha epsilon", 4.0) == "applied"       # new completion
    assert d.insert("alpha epsilon", 2.0) == "noop"          # delta outranks
    assert d.insert("alpha epsilon", 6.0) == "updated"       # in-place raise
    assert d.entries[0].score == 6.0
    assert d.insert("alpha beta", 1.0) == "noop"             # main outranks
    assert d.insert("alpha beta", 99.0) == "applied"         # shadows main
    shadow = tiny.docid_of_string["alpha beta"]
    assert d.shadowed() == {shadow}
    assert d.insert("zzunknownq", 5.0) == "deferred"         # OOV term
    assert d.insert("", 5.0) == "dropped"
    assert d.insert(" ".join(["alpha"] * 9), 5.0) == "dropped"
    # seq counts VISIBLE changes only: 2 applied + 1 updated
    assert d.seq == 3 and d.n == 2
    assert d.oplog == [("alpha epsilon", 4.0), ("alpha epsilon", 6.0),
                       ("alpha beta", 99.0)]
    s = d.stats()
    assert (s["applied"], s["updated"], s["noop"],
            s["deferred"], s["dropped"]) == (2, 1, 2, 1, 2)
    dq, ds = d.fold_corpus()
    assert ("zzunknownq", 5.0) in zip(dq, ds)
    assert ("alpha beta", 99.0) in zip(dq, ds)


def test_delta_capacity_overflow(tiny):
    d = DeltaIndex(tiny, capacity=1)
    assert d.insert("alpha epsilon", 4.0) == "applied"
    with pytest.raises(OverflowError):
        d.insert("beta epsilon", 4.0)
    # noop/updated/deferred never consume capacity
    assert d.insert("alpha epsilon", 9.0) == "updated"
    assert d.insert("zzq", 1.0) == "deferred"


def test_delta_history_replays_exact_scores(tiny):
    d = DeltaIndex(tiny, capacity=8)
    d.insert("alpha epsilon", 4.0)      # seq 1
    d.insert("beta epsilon", 5.0)       # seq 2
    d.insert("alpha epsilon", 7.0)      # seq 3: raise
    e = d.entries[0]
    assert e.score_at(1) == 4.0 and e.score_at(2) == 4.0
    assert e.score_at(3) == 7.0 and e.score == 7.0
    assert d._n_visible(0) == 0 and d._n_visible(1) == 1
    assert d._n_visible(2) == 2 == d._n_visible(2)
    with pytest.raises(ValueError):
        e.score_at(0)                   # before the entry was born


def _brute_matches(d, pids, plen, lo, hi, seq):
    out = []
    for i, e in enumerate(d.entries):
        if e.born > seq:
            continue
        row = set(int(t) for t in e.row if t)
        if not any(lo <= t < hi for t in row):
            continue
        if any(int(t) not in row for t in pids[:plen]):
            continue
        out.append(i)
    return sorted(out, key=lambda i: (-d.entries[i].score_at(seq),
                                      d.entries[i].tokens))


def test_delta_matches_equals_brute_force_and_postings_narrowing(tiny):
    rng = np.random.default_rng(5)
    d = DeltaIndex(tiny, capacity=64)
    vocab = ["alpha", "beta", "gamma", "delta", "epsilon"]
    for _ in range(40):
        toks = sorted(set(rng.choice(vocab, size=int(rng.integers(1, 4)))))
        d.insert(" ".join(toks), float(rng.integers(1, 50)))
    V = tiny.qidx.dictionary.n_terms
    ids = {t: tiny.qidx.dictionary.id_of(t) for t in vocab}
    checked = 0
    for _ in range(200):
        plen = int(rng.integers(0, 3))
        pids = np.zeros(8, dtype=np.int64)
        pids[:plen] = [ids[vocab[int(i)]]
                       for i in rng.integers(0, len(vocab), plen)]
        lo = int(rng.integers(1, V + 2))
        hi = int(rng.integers(0, V + 2))
        seq = int(rng.integers(0, d.seq + 1))
        got = d.matches(pids, plen, lo, hi, upto=seq)
        assert got == _brute_matches(d, pids, plen, lo, hi, seq)
        checked += bool(got)
    assert checked > 20, "trial distribution degenerated to empty matches"
    # the engines' reject rule: unknown prefix term -> no matches
    assert d.matches(np.asarray([0, 0]), 1, 1, V + 1) == []


# ------------------------------------------------------------ config plumbing
def test_freshness_config_validation():
    FreshnessConfig(k=5, delta_capacity=8, swap_threshold=8)
    with pytest.raises(ValueError):
        FreshnessConfig(k=0)
    with pytest.raises(ValueError):
        FreshnessConfig(k=10, delta_capacity=4)       # capacity < k
    with pytest.raises(ValueError):
        FreshnessConfig(delta_capacity=64, swap_threshold=65)
    with pytest.raises(ValueError):
        FreshnessConfig(swap_threshold=0)


def test_arch_freshness_config():
    from repro.configs.qac_common import QACArch

    fc = QACArch(freshness_delta_capacity=256,
                 freshness_swap_threshold=128).freshness_config()
    assert isinstance(fc, FreshnessConfig)
    assert (fc.k, fc.delta_capacity, fc.swap_threshold) == (10, 256, 128)
    with pytest.raises(ValueError):
        QACArch(freshness_swap_threshold=0).freshness_config()


# ------------------------------------------------------------ generational QAC
@pytest.fixture(scope="module")
def corpus():
    qs, sc = generate_query_log(SynthLogConfig(n_queries=300, vocab_size=80,
                                               mean_term_chars=4.0, seed=17))
    return qs, sc


def _trace(corpus, seed, n_mut=10, sessions=8):
    qs, sc = corpus
    return generate_mutation_trace(qs, sc, MutationTraceConfig(
        keystrokes=KeystrokeTraceConfig(
            n_sessions=sessions, queries_per_session=1,
            mean_keystroke_ms=2.0, seed=seed),
        n_mutations=n_mut, follower_sessions=6, seed=seed))


def _run(corpus, seed, swap_threshold=3, n_mut=10):
    qs, sc = corpus
    gq = GenerationalQAC(qs, sc, rt_cfg=RuntimeConfig(**_RT),
                         cfg=FreshnessConfig(
                             k=10, delta_capacity=256,
                             swap_threshold=swap_threshold))
    results = gq.run_mutation_trace(_trace(corpus, seed, n_mut=n_mut))
    return gq, results


def _assert_freshness_gates(gq, results, *, sample_every=1):
    s = gq.snapshot()
    assert s["n_swaps"] >= 1, "trace must cross at least one swap"
    assert s["delta_hit_answers"] > 0, "no answer was served from the delta"
    inv = s["runtime"]["invalidations"]
    assert len(inv) == s["n_swaps"]
    for key, v in inv.items():
        assert v["count"] == 1, f"swap {key} invalidated {v['count']} times"
    # per-generation traffic on both sides of the swap
    per_gen = s["runtime"]["per_generation"]
    assert 0 in per_gen and s["generation"] in per_gen
    assert gq.check_parity(results, sample_every=sample_every) > 0


def test_mutation_trace_parity_across_swap(corpus):
    """THE gate: every answer == from-scratch build of its own visible
    (generation, seq) version, across >= 1 mid-trace swap."""
    gq, results = _run(corpus, seed=1)
    assert all(r.gen >= 1 for r in results[-5:]), \
        "late answers must come from a post-swap generation"
    _assert_freshness_gates(gq, results, sample_every=1)


@given(seed=st.integers(0, 15))
@settings(max_examples=5, deadline=None)
def test_mutation_trace_parity_property(corpus, seed):
    gq, results = _run(corpus, seed=seed, n_mut=6, swap_threshold=2)
    _assert_freshness_gates(gq, results, sample_every=3)


@pytest.mark.parametrize("seed", [2, 5])
def test_mutation_trace_parity_fixed_seeds(corpus, seed):
    # always-on versions of the property test (hypothesis may be absent)
    gq, results = _run(corpus, seed=seed, n_mut=6, swap_threshold=2)
    _assert_freshness_gates(gq, results, sample_every=3)


def test_no_swap_trace_stays_generation_zero(corpus):
    qs, sc = corpus
    gq = GenerationalQAC(qs, sc, rt_cfg=RuntimeConfig(**_RT),
                         cfg=FreshnessConfig(k=10, delta_capacity=256,
                                             swap_threshold=256))
    results = gq.run_mutation_trace(_trace(corpus, seed=3, n_mut=5))
    s = gq.snapshot()
    assert s["n_swaps"] == 0 and s["generation"] == 0
    assert s["runtime"]["invalidations"] == {}
    assert all(r.gen == 0 for r in results)
    assert gq.check_parity(results, sample_every=2) > 0


def test_replay_resets_and_reproduces(corpus):
    qs, sc = corpus
    gq = GenerationalQAC(qs, sc, rt_cfg=RuntimeConfig(**_RT),
                         cfg=FreshnessConfig(k=10, delta_capacity=256,
                                             swap_threshold=3))
    events = _trace(corpus, seed=4, n_mut=6)
    a = gq.replay(events)                 # warm pass + reset + measured
    gq.reset()                            # else b would re-mutate a's state
    b = gq.replay(events, warm=False)     # must be bit-identical
    assert [r.strings for r in a] == [r.strings for r in b]
    assert [(r.gen, r.seq) for r in a] == [(r.gen, r.seq) for r in b]


# ------------------------------------------------------ runtime generation tag
def test_install_generation_contract(corpus):
    qs, sc = corpus
    qidx, kept, _ = build_qac_index(qs, sc)
    fe = QACFrontend(qidx, k=10, specialize_list_pad=False)
    rt = QACOnlineRuntime(fe, RuntimeConfig(**_RT))
    assert rt.generation == 0
    rt.install_generation(0, fe)                      # same gen: no-op
    assert rt.telemetry.snapshot()["invalidations"] == {}
    rt.install_generation(2, fe)
    assert rt.generation == 2
    with pytest.raises(ValueError):
        rt.install_generation(1, fe)                  # never backwards
    [r] = parse_and_prepare(qidx, [(0.0, 0, kept[0][:2])], k=10)
    rt.submit(r)
    if rt.queue:                                      # undispatched request
        with pytest.raises(RuntimeError):
            rt.install_generation(3, fe)
    rt.drain()
    rt.install_generation(3, fe)
    inv = rt.telemetry.snapshot()["invalidations"]
    assert set(inv) == {"0->2", "2->3"}
    assert all(v["count"] == 1 for v in inv.values())


# ------------------------------------------------------------ cluster swaps
def test_cluster_propagate_swap_and_timed_parity(corpus):
    qs, sc = corpus
    qidx0, kept0, sc0 = build_qac_index(qs, sc)
    fe0 = QACFrontend(qidx0, k=10, specialize_list_pad=False)
    extra = ["newly trending completion", "another fresh one"]
    qidx1, _, _ = build_qac_index(list(qs) + extra,
                                  list(sc) + [99.0, 98.0])
    fe1 = QACFrontend(qidx1, k=10, specialize_list_pad=False)

    from repro.text import generate_keystroke_trace
    trace = generate_keystroke_trace(kept0, KeystrokeTraceConfig(
        n_sessions=8, mean_keystroke_ms=2.0, seed=23))
    cut = len(trace) // 2
    t_mid = (trace[cut - 1][0] + trace[cut][0]) / 2
    reqs0 = parse_and_prepare(qidx0, trace[:cut], k=10)
    reqs1 = parse_and_prepare(qidx1, trace[cut:], k=10)
    for i, r in enumerate(reqs1):
        r.idx = len(reqs0) + i            # keep result keys globally unique

    relaxed = dict(degrade_pressure_us=1e12, shed_bulk_pressure_us=1e12,
                   shed_pressure_us=1e12)
    cl = QACServingCluster(qidx0, ClusterConfig(n_replicas=2, **relaxed),
                           RuntimeConfig(**_RT), frontends=[fe0, fe0])
    with pytest.raises(ValueError):
        cl.propagate_swap(1, [fe1])       # one frontend for two replicas
    for r in reqs0:
        cl.submit(r)
    cl.propagate_swap(1, [fe1, fe1], t_us=t_mid)
    for r in reqs1:
        cl.submit(r)
    cl.drain()
    results = [cl._results[r.idx] for r in reqs0 + reqs1]
    assert all(r.status == "ok" for r in results)
    # admitted-before-swap answered by gen 0, after by gen 1
    assert {r.gen for r in results[:cut]} == {0}
    assert {r.gen for r in results[cut:]} == {1}
    n = check_cluster_parity_timed({0: fe0, 1: fe1}, reqs0 + reqs1, results)
    assert n == len(results)
    # the timed oracle hard-fails on a generation it has no frontend for
    with pytest.raises(AssertionError):
        check_cluster_parity_timed({0: fe0}, reqs0 + reqs1, results)
    assert cl.telemetry.snapshot()["swaps"] == [(t_mid, 1)]
    for rep in cl.replicas:
        inv = rep.runtime.telemetry.snapshot()["invalidations"]
        assert list(inv) == ["0->1"] and inv["0->1"]["count"] == 1
