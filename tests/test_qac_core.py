"""QAC core: JAX engines vs the paper's exact host algorithms (oracles)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.core import (
    build_qac_index, parse_queries, HostIndex, INF_DOCID,
    prefix_search_topk, conjunctive_multi, single_term_topk,
    TermDictionary, FrontCodedStore, RangeMin, topk_in_range,
)
from repro.core.builder import build_corpus
from repro.core.strings import encode_strings
from repro.text import SynthLogConfig, generate_query_log


def _mini_corpus(seed=0, n=400, vocab=120):
    qs, sc = generate_query_log(SynthLogConfig(n_queries=n, vocab_size=vocab,
                                               mean_term_chars=4.0, seed=seed))
    return qs, sc


@pytest.fixture(scope="module")
def built():
    qs, sc = _mini_corpus()
    qidx, kept, scores = build_qac_index(qs, sc)
    dictionary, rows, sc2, kept2 = build_corpus(qs, sc)
    order = np.lexsort(tuple(rows[:, j] for j in range(rows.shape[1] - 1, -1, -1)) + (-sc2,))
    d_of_row = np.empty(len(rows), dtype=np.int32)
    d_of_row[order] = np.arange(len(rows), dtype=np.int32)
    host = HostIndex(rows, d_of_row, dictionary.n_terms)
    return qidx, kept, host


# ---------------------------------------------------------------- dictionary
def test_dictionary_locate_roundtrip(built):
    qidx, kept, _ = built
    terms = sorted({t for q in kept for t in q.split()})
    sample = terms[:: max(1, len(terms) // 50)]
    chars = encode_strings(sample, qidx.dictionary.max_chars)
    ids = np.asarray(qidx.dictionary.locate(jnp.asarray(chars)))
    for t, i in zip(sample, ids):
        assert i == terms.index(t) + 1
    back = np.asarray(qidx.dictionary.extract(jnp.asarray(ids)))
    for t, row in zip(sample, back):
        assert bytes(row[: len(t)]) == t.encode()


def test_dictionary_locate_absent(built):
    qidx, _, _ = built
    chars = encode_strings(["zzzzzzzzzzzz_nope"], qidx.dictionary.max_chars)
    assert int(qidx.dictionary.locate(jnp.asarray(chars))[0]) == 0


def test_dictionary_locate_prefix_matches_bisect(built):
    qidx, kept, _ = built
    terms = sorted({t for q in kept for t in q.split()})
    rng = np.random.default_rng(0)
    prefixes = ["", "a", "z"] + [
        terms[i][: rng.integers(1, len(terms[i]) + 1)]
        for i in rng.integers(0, len(terms), 25)
    ]
    chars = encode_strings(prefixes, qidx.dictionary.max_chars)
    lens = jnp.asarray([len(p) for p in prefixes], jnp.int32)
    l, r = qidx.dictionary.locate_prefix(jnp.asarray(chars), lens)
    import bisect
    for p, li, ri in zip(prefixes, np.asarray(l), np.asarray(r)):
        lo = bisect.bisect_left(terms, p)
        hi = bisect.bisect_right(terms, p + "\xff")
        assert (li, ri) == (lo + 1, hi + 1), p


# ---------------------------------------------------------------- front coding
@pytest.mark.parametrize("bucket", [4, 16, 64])
def test_front_coding_roundtrip(built, bucket):
    _, kept, _ = built
    fc = FrontCodedStore.build(kept, bucket_size=bucket)
    ids = np.arange(0, len(kept), max(1, len(kept) // 100))
    rows = np.asarray(fc.extract(jnp.asarray(ids)))
    for i, row in zip(ids, rows):
        got = bytes(row[row != 0])
        assert got == kept[i].encode()[: fc.max_chars], i


def test_front_coding_locate(built):
    _, kept, _ = built
    fc = FrontCodedStore.build(kept, bucket_size=16)
    sample_idx = np.arange(0, len(kept), max(1, len(kept) // 40))
    chars = encode_strings([kept[i] for i in sample_idx], fc.max_chars)
    got = np.asarray(fc.locate(jnp.asarray(chars)))
    assert (got == sample_idx).all()


def test_front_coding_locate_prefix(built):
    _, kept, _ = built
    import bisect
    fc = FrontCodedStore.build(kept, bucket_size=16)
    rng = np.random.default_rng(1)
    prefixes = [kept[i][: rng.integers(1, 8)] for i in rng.integers(0, len(kept), 20)]
    chars = encode_strings(prefixes, fc.max_chars)
    lens = jnp.asarray([len(p) for p in prefixes], jnp.int32)
    l, r = fc.locate_prefix(jnp.asarray(chars), lens)
    for p, li, ri in zip(prefixes, np.asarray(l), np.asarray(r)):
        assert li == bisect.bisect_left(kept, p), p
        assert ri == bisect.bisect_right(kept, p + "\xff"), p


def test_front_coding_smaller_than_raw(built):
    _, kept, _ = built
    fc = FrontCodedStore.build(kept, bucket_size=16)
    raw = sum(len(s) + 1 for s in kept)
    assert fc.encoded_bytes() < raw


# ---------------------------------------------------------------- RMQ
@given(st.integers(1, 500), st.integers(0, 2**31 - 2), st.data())
@settings(max_examples=30, deadline=None)
def test_rmq_matches_argmin(n, _seed, data):
    rng = np.random.default_rng(_seed % 2**32)
    vals = rng.integers(0, 10_000, n).astype(np.int32)
    rmq = RangeMin.build(vals)
    p = data.draw(st.integers(0, n - 1))
    q = data.draw(st.integers(p, n - 1))
    pos, v = rmq.query(jnp.int32(p), jnp.int32(q))
    assert int(v) == vals[p : q + 1].min()
    assert vals[int(pos)] == int(v)


def test_rmq_topk_matches_sorted():
    rng = np.random.default_rng(3)
    vals = rng.permutation(5_000).astype(np.int32)
    rmq = RangeMin.build(vals)
    for p, q in [(0, 5000), (10, 11), (100, 2000), (4990, 5000), (7, 7)]:
        got, _ = topk_in_range(rmq, jnp.int32(p), jnp.int32(q), 10)
        want = np.sort(vals[p:q])[:10]
        want = np.pad(want.astype(np.int64), (0, 10 - len(want)),
                      constant_values=INF_DOCID)
        np.testing.assert_array_equal(np.asarray(got, np.int64), want)


# ---------------------------------------------------------------- engines vs oracle
def _term_range(qidx, suffix: str):
    chars = encode_strings([suffix], qidx.dictionary.max_chars)
    l, r = qidx.dictionary.locate_prefix(
        jnp.asarray(chars), jnp.asarray([len(suffix)], jnp.int32))
    return int(l[0]), int(r[0])


def test_conjunctive_multi_vs_oracle(built):
    qidx, kept, host = built
    rng = np.random.default_rng(7)
    checked = 0
    for qi in rng.integers(0, len(kept), 60):
        toks = kept[qi].split()
        if len(toks) < 2:
            continue
        cut = rng.integers(1, len(toks[-1]) + 1)
        suffix = toks[-1][:cut]
        pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, [" ".join(toks[:-1] + [suffix])])
        tl, tr = qidx.dictionary.locate_prefix(suf, slen)
        got = conjunctive_multi(qidx.index, qidx.completions, pids[0], plen[0],
                                tl[0], tr[0], 10)
        got = [int(x) for x in np.asarray(got) if x != INF_DOCID]
        prefix = [int(x) for x in np.asarray(pids[0]) if x]
        want = host.fwd_conjunctive(prefix, int(tl[0]), int(tr[0]), 10)
        assert got == want, (kept[qi], suffix)
        want_heap = host.heap_conjunctive(prefix, int(tl[0]), int(tr[0]), 10)
        assert got == want_heap
        checked += 1
    assert checked >= 20


def test_single_term_vs_oracle(built):
    qidx, kept, host = built
    rng = np.random.default_rng(11)
    terms = sorted({t for q in kept for t in q.split()})
    for t in [terms[i] for i in rng.integers(0, len(terms), 40)]:
        for cut in (1, 2, len(t)):
            suffix = t[:cut]
            tl, tr = _term_range(qidx, suffix)
            got = single_term_topk(qidx.index, qidx.rmq_minimal,
                                   jnp.int32(tl), jnp.int32(tr), 10)
            got = [int(x) for x in np.asarray(got) if x != INF_DOCID]
            want = host.single_term_rmq(tl, tr, 10)
            assert got == want, (suffix, tl, tr)
            assert want == host.single_term_classic(tl, tr, 10)


def test_prefix_search_vs_oracle(built):
    qidx, kept, host = built
    rng = np.random.default_rng(13)
    for qi in rng.integers(0, len(kept), 50):
        toks = kept[qi].split()
        cut = rng.integers(1, len(toks[-1]) + 1)
        partial = " ".join(toks[:-1] + [toks[-1][:cut]])
        pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, [partial])
        if not pok[0]:
            continue
        tl, tr = qidx.dictionary.locate_prefix(suf, slen)
        got = prefix_search_topk(qidx.completions, qidx.rmq_docids,
                                 pids[0], plen[0], tl[0], tr[0], 10)
        got = [int(x) for x in np.asarray(got) if x != INF_DOCID]
        prefix = [int(x) for x in np.asarray(pids[0]) if x]
        want = host.brute_prefix_search(prefix, int(tl[0]), int(tr[0]), 10)
        assert got == want, partial


def test_conjunctive_superset_of_prefix(built):
    """Paper §3.1 claim: conjunctive-search subsumes prefix-search results."""
    qidx, kept, host = built
    rng = np.random.default_rng(17)
    for qi in rng.integers(0, len(kept), 40):
        toks = kept[qi].split()
        if len(toks) < 2:
            continue
        partial = " ".join(toks[:-1] + [toks[-1][:1]])
        pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, [partial])
        tl, tr = qidx.dictionary.locate_prefix(suf, slen)
        prefix = [int(x) for x in np.asarray(pids[0]) if x]
        c = set(host.brute_conjunctive(prefix, int(tl[0]), int(tr[0]), 10**9))
        p = set(host.brute_prefix_search(prefix, int(tl[0]), int(tr[0]), 10**9))
        assert p <= c


# ---------------------------------------------------------------- batched serving path
def test_vmapped_engines_match_single(built):
    qidx, kept, _ = built
    rng = np.random.default_rng(23)
    partials = []
    for qi in rng.integers(0, len(kept), 16):
        toks = kept[qi].split()
        cut = rng.integers(1, len(toks[-1]) + 1)
        partials.append(" ".join(toks[:-1] + [toks[-1][:cut]]))
    pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, partials)
    tl, tr = qidx.dictionary.locate_prefix(suf, slen)
    batched = jax.vmap(
        lambda a, b, c, d: conjunctive_multi(qidx.index, qidx.completions, a, b, c, d, 10)
    )(pids, plen, tl, tr)
    for i in range(len(partials)):
        single = conjunctive_multi(qidx.index, qidx.completions, pids[i], plen[i],
                                   tl[i], tr[i], 10)
        np.testing.assert_array_equal(np.asarray(batched[i]), np.asarray(single))


def test_hyb_baseline_matches_fwd(built):
    """Bast-Weber HYB engine returns the same results as Fwd/oracle."""
    from repro.core.ref_engines import HybIndex
    qidx, kept, host = built
    hyb = HybIndex(host, c=1e-2)
    rng = np.random.default_rng(31)
    checked = 0
    for qi in rng.integers(0, len(kept), 30):
        toks = kept[qi].split()
        cut = rng.integers(1, len(toks[-1]) + 1)
        partial = " ".join(toks[:-1] + [toks[-1][:cut]])
        pids, plen, pok, suf, slen = parse_queries(qidx.dictionary, [partial])
        tl, tr = qidx.dictionary.locate_prefix(suf, slen)
        prefix = [int(x) for x in np.asarray(pids[0]) if x]
        got = hyb.conjunctive(prefix, int(tl[0]), int(tr[0]), 10)
        if prefix:
            want = host.fwd_conjunctive(prefix, int(tl[0]), int(tr[0]), 10)
        else:
            want = host.single_term_rmq(int(tl[0]), int(tr[0]), 10)
        assert got == want, partial
        checked += 1
    assert checked >= 20
