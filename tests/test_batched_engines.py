"""Batch-native engines vs the per-query vmap reference (ISSUE 2).

The batched engines must be bit-identical to ``vmap``-ing the scalar
reference across every query class — including empty suffix ranges
(``p > q`` / INF_DOCID padding), duplicate-docid runs that exhaust the
bounded trip budget, and the Pallas-kernel dispatch under interpret mode.
``RangeMin.query_batch`` has a two-part contract: ``val`` bit-identical
always, ``pos`` bit-identical whenever ``val < INF_DOCID``.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.core import (
    build_qac_index, parse_queries, INF_DOCID, RangeMin,
    topk_in_range, topk_in_range_batch,
    conjunctive_multi, conjunctive_multi_batch,
    single_term_topk, single_term_topk_batch,
    single_term_topk_bounded, single_term_topk_bounded_batch,
)
from repro.serve.qac import qac_serve_step, qac_serve_step_vmap
from repro.text import SynthLogConfig, generate_query_log


@pytest.fixture(scope="module")
def built():
    # small vocab => heavy term co-occurrence => duplicate docids across the
    # lists of a suffix range (the single-term dedup/trip-budget stressor)
    qs, sc = generate_query_log(SynthLogConfig(n_queries=500, vocab_size=80,
                                               mean_term_chars=4.0, seed=9))
    qidx, kept, _ = build_qac_index(qs, sc)
    return qidx, kept


def _mixed_batch(kept, rng, B, pct_single, pct_garbage=10):
    multis = [q for q in kept if len(q.split()) >= 2] or kept
    out = []
    for _ in range(B):
        r = rng.integers(0, 100)
        if r < pct_garbage:
            out.append("zzzzzzqx" if rng.integers(0, 2) else
                       kept[rng.integers(0, len(kept))].split()[0] + " zzzzzzqx")
        elif r < pct_garbage + pct_single:
            t = kept[rng.integers(0, len(kept))].split()[0]
            out.append(t[: rng.integers(1, len(t) + 1)])
        else:
            toks = multis[rng.integers(0, len(multis))].split()
            cut = rng.integers(1, len(toks[-1]) + 1)
            out.append(" ".join(toks[:-1] + [toks[-1][:cut]]))
    return out


def _ranges(qidx, kept, rng, B):
    """Suffix term ranges for B random partial tokens + garbage/empty cases."""
    batch = _mixed_batch(kept, rng, B, 100, pct_garbage=25)
    _, _, _, suf, slen = parse_queries(qidx.dictionary, batch)
    return qidx.dictionary.locate_prefix(suf, slen)


# ---------------------------------------------------------------- query_batch
def _query_contract(rm, p, q, **kw):
    pj, qj = jnp.asarray(p), jnp.asarray(q)
    want_pos, want_val = jax.jit(jax.vmap(rm.query))(pj, qj)
    got_pos, got_val = jax.jit(
        lambda a, b: rm.query_batch(a, b, **kw))(pj, qj)
    np.testing.assert_array_equal(np.asarray(got_val), np.asarray(want_val))
    live = np.asarray(want_val) < INF_DOCID
    np.testing.assert_array_equal(np.asarray(got_pos)[live],
                                  np.asarray(want_pos)[live])


@pytest.mark.parametrize("n,dup", [(1000, False), (40_000, False),
                                   (5_000, True)])
def test_query_batch_matches_vmap(n, dup):
    rng = np.random.default_rng(n)
    vals = (rng.integers(0, 40, n) if dup
            else rng.permutation(n)).astype(np.int32)
    rm = RangeMin.build(vals)
    B = 128
    p = rng.integers(-5, n, B).astype(np.int32)
    q = (p + rng.integers(-10, n, B)).astype(np.int32)   # includes p > q
    _query_contract(rm, p, q)


def test_query_batch_kernel_dispatch():
    rng = np.random.default_rng(1)
    vals = rng.integers(0, 60, 3000).astype(np.int32)    # duplicate-heavy
    rm = RangeMin.build(vals)
    for B in (8, 64, 200):                               # 200: pad-to-128 path
        p = rng.integers(-3, 3000, B).astype(np.int32)
        q = (p + rng.integers(-5, 2000, B)).astype(np.int32)
        _query_contract(rm, p, q, use_kernel=True, interpret=True)


@given(st.integers(2, 400), st.integers(0, 2**31 - 2))
@settings(max_examples=25, deadline=None)
def test_query_batch_property(n, seed):
    rng = np.random.default_rng(seed % 2**32)
    vals = rng.integers(0, max(n // 3, 2), n).astype(np.int32)
    rm = RangeMin.build(vals)
    B = 32
    p = rng.integers(-2, n + 2, B).astype(np.int32)
    q = rng.integers(-2, n + 2, B).astype(np.int32)
    _query_contract(rm, p, q)


# ---------------------------------------------------------------- topk_in_range
@pytest.mark.parametrize("dup", [False, True])
def test_topk_batch_matches_vmap(dup):
    rng = np.random.default_rng(17 + dup)
    n = 6_000
    vals = (rng.integers(0, 99, n) if dup
            else rng.permutation(n)).astype(np.int32)
    rm = RangeMin.build(vals)
    p = np.array([0, 10, 100, 4990, 7, 7, 30, n - 1], np.int32)
    q = np.array([n, 11, 2000, n, 7, 8, 30, 0], np.int32)  # empty + p > q
    wv, wp = jax.jit(jax.vmap(lambda a, b: topk_in_range(rm, a, b, 10)))(
        jnp.asarray(p), jnp.asarray(q))
    gv, gp = jax.jit(lambda a, b: topk_in_range_batch(rm, a, b, 10))(
        jnp.asarray(p), jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))
    np.testing.assert_array_equal(np.asarray(gp), np.asarray(wp))


@given(st.integers(0, 2**31 - 2))
@settings(max_examples=20, deadline=None)
def test_topk_batch_property(seed):
    rng = np.random.default_rng(seed % 2**32)
    n = rng.integers(2, 2000)
    vals = rng.integers(0, max(int(n) // 2, 2), n).astype(np.int32)
    rm = RangeMin.build(vals)
    B = 16
    p = rng.integers(0, n, B).astype(np.int32)
    q = rng.integers(0, n + 1, B).astype(np.int32)
    wv, wp = jax.vmap(lambda a, b: topk_in_range(rm, a, b, 5))(
        jnp.asarray(p), jnp.asarray(q))
    gv, gp = topk_in_range_batch(rm, jnp.asarray(p), jnp.asarray(q), 5)
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))
    np.testing.assert_array_equal(np.asarray(gp), np.asarray(wp))


# ---------------------------------------------------------------- single-term
def test_single_term_batch_matches_vmap(built):
    qidx, kept = built
    rng = np.random.default_rng(3)
    tl, th = _ranges(qidx, kept, rng, 64)
    want = jax.vmap(lambda a, b: single_term_topk(
        qidx.index, qidx.rmq_minimal, a, b, 10))(tl, th)
    got = single_term_topk_batch(qidx.index, qidx.rmq_minimal, tl, th, 10)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert (np.asarray(got) == INF_DOCID).any(), "expected INF padding rows"


@pytest.mark.parametrize("trips", [1, 3, 12, 20])
def test_single_term_bounded_batch_matches_vmap(built, trips):
    """Starvation budgets included: duplicate-docid runs burn pops, so small
    ``trips`` must reproduce the reference's partial out AND done flags."""
    qidx, kept = built
    rng = np.random.default_rng(trips)
    tl, th = _ranges(qidx, kept, rng, 48)
    wo, wd = jax.vmap(lambda a, b: single_term_topk_bounded(
        qidx.index, qidx.rmq_minimal, a, b, 10, trips))(tl, th)
    go, gd = single_term_topk_bounded_batch(qidx.index, qidx.rmq_minimal,
                                            tl, th, 10, trips)
    np.testing.assert_array_equal(np.asarray(go), np.asarray(wo))
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))
    if trips == 1:
        assert not np.asarray(gd).all(), "starvation budget should trip lanes"


def test_single_term_batch_kernel_dispatch(built):
    qidx, kept = built
    rng = np.random.default_rng(7)
    tl, th = _ranges(qidx, kept, rng, 32)
    want = single_term_topk_batch(qidx.index, qidx.rmq_minimal, tl, th, 10)
    got = single_term_topk_batch(qidx.index, qidx.rmq_minimal, tl, th, 10,
                                 use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------- conjunctive
def _multi_inputs(built, seed, B):
    qidx, kept = built
    rng = np.random.default_rng(seed)
    batch = _mixed_batch(kept, rng, B, 0, pct_garbage=15)
    pids, plen, _, suf, slen = parse_queries(qidx.dictionary, batch)
    tl, th = qidx.dictionary.locate_prefix(suf, slen)
    return pids, plen, tl, th


def test_conjunctive_batch_matches_vmap(built):
    qidx, _ = built
    pids, plen, tl, th = _multi_inputs(built, 11, 40)
    want = jax.vmap(lambda a, b, c, d: conjunctive_multi(
        qidx.index, qidx.completions, a, b, c, d, 10))(pids, plen, tl, th)
    got = conjunctive_multi_batch(qidx.index, qidx.completions, pids, plen,
                                  tl, th, 10)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conjunctive_batch_kernel_dispatch(built):
    qidx, _ = built
    pids, plen, tl, th = _multi_inputs(built, 13, 16)
    offs = np.asarray(qidx.index.offsets)
    list_pad = 1 << max(1, (int(np.max(np.diff(offs))) - 1).bit_length())
    want = conjunctive_multi_batch(qidx.index, qidx.completions, pids, plen,
                                   tl, th, 10)
    got = conjunctive_multi_batch(qidx.index, qidx.completions, pids, plen,
                                  tl, th, 10, use_kernel=True, interpret=True,
                                  list_pad=list_pad)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conjunctive_batch_packed_dispatch(built):
    """postings_codec="ef" routes the kernel probes through the compressed
    stream (no list gather, no list_pad bound) — bit-identical to the XLA
    reference route (ISSUE 7)."""
    qidx, _ = built
    assert qidx.index.packed is not None
    pids, plen, tl, th = _multi_inputs(built, 17, 16)
    want = conjunctive_multi_batch(qidx.index, qidx.completions, pids, plen,
                                   tl, th, 10)
    got = conjunctive_multi_batch(qidx.index, qidx.completions, pids, plen,
                                  tl, th, 10, use_kernel=True, interpret=True,
                                  postings_codec="ef")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------- striped
def test_striped_local_serve_matches_vmap(built):
    """The stripe-local batched engines == vmap of the scalar fused engine
    over the same stripe-local index (the shard_map body's contract)."""
    from repro.core.builder import build_corpus
    from repro.core.striped import build_striped, local_index
    from repro.core.search import complete_conjunctive
    from repro.serve.qac import _local_serve
    from repro.text import SynthLogConfig, generate_query_log

    qidx, kept = built
    qs, sc = generate_query_log(SynthLogConfig(n_queries=500, vocab_size=80,
                                               mean_term_chars=4.0, seed=9))
    dictionary, rows, sc2, _ = build_corpus(qs, sc)
    order = np.lexsort(tuple(rows[:, j] for j in range(rows.shape[1] - 1, -1, -1)) + (-sc2,))
    d_of_row = np.empty(len(rows), dtype=np.int32)
    d_of_row[order] = np.arange(len(rows), dtype=np.int32)
    striped = build_striped(rows, d_of_row, dictionary.n_terms, 2)
    rng = np.random.default_rng(29)
    batch = _mixed_batch(kept, rng, 24, 50)
    pids, plen, _, suf, slen = parse_queries(qidx.dictionary, batch)
    tl, th = qidx.dictionary.locate_prefix(suf, slen)
    for s in range(2):
        sub = jax.tree_util.tree_map(lambda a: a[s : s + 1], striped)
        got = _local_serve(sub, pids, plen, tl, th, 10, 128, 4096)
        idx, fwd, rmq_min = local_index(sub)
        want = jax.vmap(lambda a, b, c, d: complete_conjunctive(
            idx, fwd, rmq_min, a, b, c, d, 10))(pids, plen, tl, th)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------- fused serve
def test_fused_serve_batch_matches_vmap(built):
    qidx, kept = built
    rng = np.random.default_rng(23)
    for B, pct in [(32, 60), (17, 40), (5, 100)]:
        batch = _mixed_batch(kept, rng, B, pct)
        pids, plen, _, suf, slen = parse_queries(qidx.dictionary, batch)
        got = qac_serve_step(qidx, pids, plen, suf, slen, k=10)
        want = qac_serve_step_vmap(qidx, pids, plen, suf, slen, k=10)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
