"""Multi-replica serving cluster (ISSUE 8): the fault-drill correctness
gate — kill/stall a replica mid-trace and every non-REJECTED answer stays
bit-identical to the uncached frontend oracle while the cluster keeps
serving — plus session-affinity routing, the admission ladder
(degrade -> shed_bulk -> shed -> queue_full) driven deterministically by a
seeded pressure estimator, and construction-time config validation.
"""
import numpy as np
import pytest

from repro.core import build_qac_index
from repro.runtime.fault import FaultInjector, ReplicaFault
from repro.serve import QACFrontend
from repro.serve.cluster import (ClusterConfig, QACServingCluster,
                                 assign_sla, check_cluster_parity,
                                 rendezvous_route)
from repro.serve.runtime import RuntimeConfig, prepare_requests
from repro.text import (KeystrokeTraceConfig, SynthLogConfig,
                        generate_keystroke_trace, generate_query_log)


@pytest.fixture(scope="module")
def built():
    qs, sc = generate_query_log(SynthLogConfig(n_queries=500, vocab_size=140,
                                               mean_term_chars=4.0, seed=7))
    qidx, kept, _ = build_qac_index(qs, sc)
    fe = QACFrontend(qidx, k=10, specialize_list_pad=False)
    return qidx, kept, fe


@pytest.fixture(scope="module")
def trace_reqs(built):
    qidx, kept, _ = built
    trace = generate_keystroke_trace(kept, KeystrokeTraceConfig(
        n_sessions=10, mean_keystroke_ms=5.0, session_spread_ms=20.0,
        seed=11))
    return prepare_requests(qidx, trace, k=10)


_RT = dict(max_batch=8, slack_us=2000.0)

# parity/affinity/drill tests disable the pressure ladder (huge thresholds
# never trip on a CI box whose real wall-clock service times are arbitrary);
# the admission tests drive the ladder deterministically with a seeded EWMA
_RELAXED = dict(degrade_pressure_us=1e12, shed_bulk_pressure_us=1e12,
                shed_pressure_us=1e12)


def _cluster(built, cl_cfg, injector=None, rt=None):
    """Replicas share the module's ONE warm frontend (complete() is pure,
    so sharing cannot change results and jit variants compile once)."""
    qidx, _, fe = built
    return QACServingCluster(
        qidx, cl_cfg, RuntimeConfig(**(rt or _RT)),
        frontends=[fe] * cl_cfg.n_replicas, injector=injector)


# ------------------------------------------------------------ routing
def test_rendezvous_sticky_and_minimal_disruption():
    alive = [0, 1, 2, 3]
    routes = {s: rendezvous_route(s, alive) for s in range(500)}
    # sticky: pure function of (session, alive set)
    assert routes == {s: rendezvous_route(s, alive) for s in range(500)}
    # all replicas get traffic
    assert set(routes.values()) == set(alive)
    # minimal disruption: removing replica 2 moves ONLY its sessions
    alive2 = [0, 1, 3]
    for s, r in routes.items():
        if r != 2:
            assert rendezvous_route(s, alive2) == r
        else:
            assert rendezvous_route(s, alive2) in alive2
    assert rendezvous_route(5, []) is None


def test_assign_sla_deterministic_per_session():
    class R:
        def __init__(self, s):
            self.session = s
    reqs = [R(s % 7) for s in range(100)]
    sla = assign_sla(reqs, bulk_fraction=0.5)
    assert sla == assign_sla(reqs, bulk_fraction=0.5)
    by_sess = {}
    for r, s in zip(reqs, sla):
        assert by_sess.setdefault(r.session, s) == s   # class is per-session
    with pytest.raises(ValueError):
        assign_sla(reqs, bulk_fraction=1.5)


# ---------------------------------------------------- healthy-cluster parity
def test_healthy_cluster_parity_and_affinity(built, trace_reqs):
    _, _, fe = built
    cl = _cluster(built, ClusterConfig(n_replicas=2, **_RELAXED))
    res = cl.replay(trace_reqs)
    assert all(r.status == "ok" for r in res)
    assert check_cluster_parity(fe, trace_reqs, res) == len(trace_reqs)
    # session affinity: with no faults, every session stays on one replica
    by_sess = {}
    for q, r in zip(trace_reqs, res):
        assert by_sess.setdefault(q.session, r.replica) == r.replica
    # and with >1 session per replica expected, both replicas served
    assert len(cl.telemetry.per_replica) == 2


def test_mixed_sla_healthy_cluster_serves_everything(built, trace_reqs):
    _, _, fe = built
    cl = _cluster(built, ClusterConfig(n_replicas=2, **_RELAXED))
    res = cl.replay(trace_reqs, assign_sla(trace_reqs, bulk_fraction=0.4))
    assert all(r.status == "ok" for r in res)     # no pressure, no sheds
    assert check_cluster_parity(fe, trace_reqs, res) == len(trace_reqs)


# ------------------------------------------------------------- fault drills
def _drill_cfg():
    return ClusterConfig(n_replicas=2, heartbeat_timeout_us=50_000.0,
                         **_RELAXED)


def test_kill_drill_parity_reroute_availability(built, trace_reqs):
    """THE acceptance gate: kill a replica mid-trace; every answer stays
    bit-identical to the uncached oracle, traffic re-routes, and the
    cluster keeps serving."""
    _, _, fe = built
    t_kill = trace_reqs[len(trace_reqs) // 2].t_us
    inj = FaultInjector([], replica_faults=[ReplicaFault(0, t_kill)])
    cl = _cluster(built, _drill_cfg(), injector=inj)
    res = cl.replay(trace_reqs)
    snap = cl.telemetry.snapshot()
    # nothing lost: every request has an explicit outcome, none rejected
    # (the survivor had capacity) — and ALL served rows are bit-exact
    assert len(res) == len(trace_reqs)
    served = [r for r in res if r.status == "ok"]
    assert check_cluster_parity(fe, trace_reqs, res) == len(served)
    assert snap["rerouted"] > 0
    assert any(r.rerouted for r in served)
    assert snap["deaths"] and snap["deaths"][0][1] == 0
    # availability: requests ARRIVING after the kill still get served
    post = [r for q, r in zip(trace_reqs, res)
            if q.t_us > t_kill and r.status == "ok"]
    assert post
    assert all(r.replica == 1 for r in post)   # ... by the survivor
    assert snap["failover_p99_us"] > 0


def test_kill_recovery_readmits_replica(built, trace_reqs):
    _, _, fe = built
    t_kill = trace_reqs[len(trace_reqs) // 3].t_us
    # recover quickly: well before the trace ends, so re-admission shows
    # up as post-recovery traffic on replica 0
    inj = FaultInjector([], replica_faults=[
        ReplicaFault(0, t_kill, t_kill + 60_000.0)])
    cl = _cluster(built, _drill_cfg(), injector=inj)
    res = cl.replay(trace_reqs)
    snap = cl.telemetry.snapshot()
    assert check_cluster_parity(fe, trace_reqs, res) == snap["served"]
    assert snap["deaths"] and snap["readmissions"]
    t_re = snap["readmissions"][0][0]
    # replica 0 serves again after re-admission
    assert any(r.replica == 0 for q, r in zip(trace_reqs, res)
               if r.status == "ok" and q.t_us > t_re)


def test_stall_drill_keeps_parity(built, trace_reqs):
    """A stall freezes service without losing state; answers afterwards
    must still be exact (and the stall window must not virtually serve)."""
    _, _, fe = built
    t0 = trace_reqs[len(trace_reqs) // 2].t_us
    inj = FaultInjector([], replica_faults=[
        ReplicaFault(0, t0, t0 + 100_000.0, kind="stall")])
    cl = _cluster(built, _drill_cfg(), injector=inj)
    res = cl.replay(trace_reqs)
    assert check_cluster_parity(fe, trace_reqs, res) == sum(
        r.status == "ok" for r in res)
    assert len(res) == len(trace_reqs)      # nothing lost to the stall


# --------------------------------------------------------- admission ladder
def _ladder_reqs(built, n, k=10):
    """n requests at t=0, distinct sessions + distinct queries (no cache
    interactions), all single-term (multi-term eligibility is exercised
    separately)."""
    qidx, kept, _ = built
    uniq = sorted({q.split()[0] for q in kept})
    assert len(uniq) >= n
    trace = [(0.0, s, uniq[s]) for s in range(n)]
    return prepare_requests(qidx, trace, k=k)


def test_admission_ladder_deterministic(built):
    """Seed the pressure EWMA directly (1 ms per queued request) and pick
    thresholds so successive same-instant arrivals walk the whole ladder:
    full, full, degrade, degrade, shed. Deterministic — no wall clocks."""
    _, _, fe = built
    cfg = ClusterConfig(n_replicas=1, degrade_pressure_us=1_500.0,
                        shed_bulk_pressure_us=2_500.0,
                        shed_pressure_us=3_500.0, degraded_k=2)
    # huge slack / batch: nothing dispatches while the burst queues up
    cl = _cluster(built, cfg, rt=dict(max_batch=64, slack_us=1e9))
    cl.replicas[0].monitor.record(1, 1_000.0)
    reqs = _ladder_reqs(built, 6)
    res = cl.run_trace(reqs)
    # est at arrival i = i * 1000us (queue depth i, empty backlog)
    assert [r.status for r in res] == ["ok"] * 4 + ["rejected"] * 2
    assert [r.degraded for r in res[:4]] == [False, False, True, True]
    assert [r.k_served for r in res[:4]] == [10, 10, 2, 2]
    assert all(r.reason == "shed_overload" for r in res[4:])
    # degraded rows are still exact at their served k
    assert check_cluster_parity(fe, reqs, res) == 4
    snap = cl.telemetry.snapshot()
    assert snap["shed_rate"] == pytest.approx(2 / 6)
    assert snap["degrade_rate"] == pytest.approx(2 / 6)


def test_admission_bulk_sheds_first(built):
    _, _, fe = built
    cfg = ClusterConfig(n_replicas=1, degrade_pressure_us=1_500.0,
                        shed_bulk_pressure_us=2_500.0,
                        shed_pressure_us=3_500.0, degraded_k=2)
    cl = _cluster(built, cfg, rt=dict(max_batch=64, slack_us=1e9))
    cl.replicas[0].monitor.record(1, 1_000.0)
    reqs = _ladder_reqs(built, 5)
    res = cl.run_trace(reqs, "bulk")
    # bulk walks: full, full, degrade, shed_bulk (est 3000 >= 2500), shed
    assert [r.status for r in res] == ["ok"] * 3 + ["rejected"] * 2
    assert res[2].degraded and res[2].k_served == 2
    assert res[3].reason == "shed_bulk"
    assert res[4].reason == "shed_bulk"    # depth stuck at 3, est 3000
    assert check_cluster_parity(fe, reqs, res) == 3


def test_admission_degrade_skips_bulk_multi_term(built):
    """In the degrade tier a BULK request needing the conjunctive engine is
    rejected outright (the expensive class goes first); the same request as
    interactive is served, degraded."""
    qidx, kept, fe = built
    multi = next(q for q in kept if len(q.split()) >= 2)
    words = multi.split()
    partial = words[0] + " " + words[1][:1]
    cfg = ClusterConfig(n_replicas=1, degrade_pressure_us=500.0,
                        shed_bulk_pressure_us=1e9, shed_pressure_us=1e9,
                        degraded_k=2)
    for sla, want_status in [("bulk", "rejected"), ("interactive", "ok")]:
        cl = _cluster(built, cfg, rt=dict(max_batch=64, slack_us=1e9))
        cl.replicas[0].monitor.record(1, 1_000.0)
        reqs = prepare_requests(qidx, [(0.0, 0, kept[0].split()[0]),
                                       (0.0, 1, partial)], k=10)
        res = cl.run_trace(reqs, ["interactive", sla])
        assert res[1].status == want_status
        if want_status == "rejected":
            assert res[1].reason == "degrade_skip_multi"
        else:
            assert res[1].degraded
        check_cluster_parity(fe, reqs, res)


def test_bounded_queue_backstop(built):
    """With the pressure ladder disabled (huge thresholds) the bounded
    queue still rejects: depth can never exceed max_queue."""
    cfg = ClusterConfig(n_replicas=1, max_queue=3,
                        degrade_pressure_us=1e12,
                        shed_bulk_pressure_us=1e12, shed_pressure_us=1e12)
    cl = _cluster(built, cfg, rt=dict(max_batch=64, slack_us=1e9))
    reqs = _ladder_reqs(built, 6)
    res = cl.run_trace(reqs)
    assert [r.status for r in res] == ["ok"] * 3 + ["rejected"] * 3
    assert all(r.reason == "queue_full" for r in res[3:])


# --------------------------------------------------------------- validation
def test_cluster_config_validation():
    with pytest.raises(ValueError):
        ClusterConfig(n_replicas=0)
    with pytest.raises(ValueError):
        ClusterConfig(max_queue=0)
    with pytest.raises(ValueError):
        ClusterConfig(degraded_k=0)
    with pytest.raises(ValueError):
        ClusterConfig(degrade_pressure_us=0.0)
    with pytest.raises(ValueError):          # mis-ordered ladder
        ClusterConfig(degrade_pressure_us=5.0, shed_bulk_pressure_us=4.0)
    with pytest.raises(ValueError):
        ClusterConfig(shed_bulk_pressure_us=200_000.0,
                      shed_pressure_us=100_000.0)
    with pytest.raises(ValueError):
        ClusterConfig(heartbeat_timeout_us=0.0)


def test_runtime_config_validation():
    with pytest.raises(ValueError):
        RuntimeConfig(max_batch=0)
    with pytest.raises(ValueError):
        RuntimeConfig(slack_us=-1.0)
    with pytest.raises(ValueError):
        RuntimeConfig(cache_entries=-1)
    with pytest.raises(ValueError):
        RuntimeConfig(session_entries=-1)
    RuntimeConfig(slack_us=0.0)              # zero slack is a legal config


def test_cluster_capacity_validation(built, trace_reqs):
    qidx, _, fe = built
    cap = int(qidx.completions.n)
    with pytest.raises(ValueError):          # degraded_k beyond the corpus
        QACServingCluster(qidx, ClusterConfig(degraded_k=cap + 1),
                          frontends=[fe, fe])
    with pytest.raises(ValueError):          # fault aimed at no replica
        QACServingCluster(
            qidx, ClusterConfig(n_replicas=2), frontends=[fe, fe],
            injector=FaultInjector([], replica_faults=[ReplicaFault(7, 0.0)]))
    cl = QACServingCluster(qidx, ClusterConfig(n_replicas=2),
                           frontends=[fe, fe])
    big = [dataclasses_replace_k(r, cap + 1) for r in trace_reqs[:3]]
    with pytest.raises(ValueError):          # k beyond index capacity
        cl.run_trace(big)
    with pytest.raises(ValueError):          # wrong frontend count
        QACServingCluster(qidx, ClusterConfig(n_replicas=3),
                          frontends=[fe, fe])
    with pytest.raises(ValueError):
        cl.submit(trace_reqs[0], sla="premium")


def dataclasses_replace_k(r, k):
    import dataclasses
    return dataclasses.replace(r, k=k)


# ---------------------------------------------------------------- telemetry
def test_cluster_percentiles_pinned_to_numpy(built):
    """ClusterTelemetry quantile math is np.percentile, verbatim."""
    from repro.serve.cluster import ClusterTelemetry
    t = ClusterTelemetry()
    lats = [float(x) for x in [10, 20, 30, 1000, 55, 7, 7, 90, 300, 42]]
    t.lat_us["interactive"] = list(lats)
    snap = t.snapshot()
    for p in (50, 95, 99):
        assert snap[f"interactive_p{p}_us"] == float(np.percentile(lats, p))
    assert snap["interactive_mean_us"] == pytest.approx(np.mean(lats))
    assert snap["bulk_p99_us"] is None       # empty class: None, not NaN/0
    assert snap["bulk_mean_us"] is None
    assert snap["shed_rate"] == 0.0
